"""RFF fast tier + accuracy cascade: mixed-traffic hit fraction, certified
bands, and the modeled cascade-vs-all-exact speedup.

Two modes, mirroring ``pruning_sweep``/``streaming_throughput``:

  * **smoke** (CI): a small dataset served through the real engine with a
    mixed accuracy-target traffic stream.  Asserts the cascade contract
    end to end: a nonzero RFF-tier hit fraction, and the per-row
    certified band dominating the realized error against a from-scratch
    exact reference on every row (RFF-answered or escalated).
  * **acceptance**: the 256k gated cell.  Mixed traffic (75% @1e-2,
    15% @5e-2, 10% @1e-3 relative-accuracy targets) over a 262144-point
    fit served through the engine; the gate requires ≥70% of the stream
    to resolve at the RFF tier with realized error ≤1e-2, a modeled
    cascade qps ≥5× the all-exact pass, and zero certificate violations.
    The hit fractions are measured (seeded, deterministic); the speedup
    is modeled — the same ``autotune.modeled_cost`` currency every other
    gated cell prices in — because the CI CPU's wall clock can't see an
    MXU-shaped win.

    The emitted ``rff_cascade`` cell doubles as the planner's measured
    evidence: ``plan.BenchModel.measured_rff_hit`` reads its
    ``rff_hit_frac``/``accuracy_target`` fields, which is what licenses
    an ``ExecutionPlan.rff=True`` decision for this regime (and derives
    the pinned golden entry, like every other gated cell).

    PYTHONPATH=src python -m benchmarks.rff_cascade
    PYTHONPATH=src python -m benchmarks.rff_cascade --acceptance
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import bandwidth as bw
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.kernels import autotune, flash_rff
from repro.serve import QueryRequest, ServeConfig, ServeEngine

#: The acceptance traffic mix: (relative accuracy target, share of rows).
TRAFFIC = ((1e-2, 0.75), (5e-2, 0.15), (1e-3, 0.10))

#: Certificate slack: covers f64-vs-f32 reference dust, nothing else.
CERT_SLACK = 1e-6


def _run_traffic(eng, key: str, y: np.ndarray, mix=TRAFFIC,
                 batch: int = 4096):
    """Dispatch ``y`` through the engine as a mixed-target stream.

    Returns per-row arrays (value, certified bound, target) plus the
    engine-reported (hits, escalated) totals.  Rows are sliced into the
    traffic buckets in order — the sample is iid, so slicing IS a random
    assignment.
    """
    rows = y.shape[0]
    counts = [int(rows * frac) for _, frac in mix]
    counts[0] += rows - sum(counts)          # remainder to the head bucket
    value = np.empty(rows, np.float64)
    bounds = np.empty(rows, np.float64)
    targets = np.empty(rows, np.float64)
    hits = escalated = 0
    lo = 0
    for (target, _), cnt in zip(mix, counts):
        for start in range(lo, lo + cnt, batch):
            stop = min(start + batch, lo + cnt)
            ans = eng.query(QueryRequest(key=key, points=y[start:stop],
                                         accuracy_target=target))
            value[start:stop] = np.asarray(ans.value, np.float64)
            b = (np.asarray(ans.rel_err_bounds, np.float64)
                 if ans.rel_err_bounds is not None
                 else np.full(stop - start, ans.rel_err_bound))
            bounds[start:stop] = b
            targets[start:stop] = target
            hits += ans.rff_hits
            escalated += ans.escalated
        lo += cnt
    return value, bounds, targets, hits, escalated


def smoke(
    n: int = 8192,
    d: int = 2,
    rows: int = 1024,
    n_features: int = 4096,
    seed: int = 0,
) -> None:
    """Serve-level cascade smoke: real engine, certificate verified."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = np.asarray(mix.sample(key, n), np.float32)
    y = np.asarray(mix.sample(jax.random.fold_in(key, 7), rows), np.float32)
    h = float(bw.silverman_bandwidth(x))

    cfg = ServeConfig(backend="jnp", method="kde", rff="on",
                      rff_features=n_features, min_batch=128,
                      max_batch=1024)
    eng = ServeEngine(cfg)
    t0 = time.perf_counter()
    eng.register("cascade", x, h=h)
    fit_s = time.perf_counter() - t0

    value, bounds, targets, hits, escalated = _run_traffic(
        eng, "cascade", y, batch=1024)
    want = np.asarray(ref.kde_eval(x, y, h, block=4096), np.float64)
    state = eng.registry.get("cascade").rff.state
    realized = flash_rff.realized_error(value, want, state.p_scale)
    worst = float((realized - bounds).max())
    if worst > CERT_SLACK:
        raise RuntimeError(
            f"certified band violated by {worst:.2e} in the cascade smoke")
    if hits == 0:
        raise RuntimeError("cascade smoke answered zero rows at the RFF "
                           "tier — the fast tier never engaged")
    emit("rff_cascade_smoke", n=n, d=d, rows=rows,
         rff_features=n_features, h=round(h, 4), fit_s=round(fit_s, 2),
         rff_hits=hits, escalated=escalated,
         rff_frac=round(hits / rows, 3),
         worst_cert_slack=f"{worst:.2e}",
         max_realized_err=f"{float(realized.max()):.2e}")


def acceptance(
    n: int = 262144,
    d: int = 2,
    rows: int = 8192,
    batch: int = 4096,
    n_features: int = 8192,
    n_pilot: int = 2048,
    groups: int = 32,
    target_frac: float = 0.70,
    target_speedup: float = 5.0,
    seed: int = 0,
) -> None:
    """The 256k mixed-traffic gated cell (≥70% RFF @ ≤1e-2, ≥5× modeled)."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = np.asarray(mix.sample(key, n), np.float32)
    y = np.asarray(mix.sample(jax.random.fold_in(key, 7), rows), np.float32)
    h = float(bw.silverman_bandwidth(x))

    cfg = ServeConfig(backend="jnp", method="kde", rff="on",
                      rff_features=n_features, rff_pilot=n_pilot,
                      rff_groups=groups, min_batch=512, max_batch=batch)
    eng = ServeEngine(cfg)
    t0 = time.perf_counter()
    eng.register("traffic", x, h=h)
    fit_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    value, bounds, targets, hits, escalated = _run_traffic(
        eng, "traffic", y, batch=batch)
    serve_s = time.perf_counter() - t0

    # certificate: realized error never exceeds the per-row bound —
    # RFF-answered rows carry the band, escalated rows the exact tier's
    # documented rtol
    want = np.asarray(ref.kde_eval(x, y, h, block=4096), np.float64)
    state = eng.registry.get("traffic").rff.state
    realized = flash_rff.realized_error(value, want, state.p_scale)
    worst = float((realized - bounds).max())

    # per-row routing mask: recompute the band the engine routed on (same
    # deterministic state) and cross-check against the engine's counters
    p_rff, band = flash_rff.eval_density(state.serving(), y)
    band = np.asarray(band, np.float64)
    hit_mask = band <= targets
    if int(hit_mask.sum()) != hits:
        raise RuntimeError(
            f"routing mask disagrees with engine counters: "
            f"{int(hit_mask.sum())} vs {hits}")

    frac_rff = hits / rows
    # the headline gate: resolved at the RFF tier AND realized ≤ 1e-2,
    # as a fraction of the whole mixed stream
    frac_ok = float((hit_mask & (realized <= 1e-2)).mean())
    # the planner's evidence: hit fraction of the 1e-2-target bucket
    at_1e2 = targets == 1e-2
    hit_1e2 = float(hit_mask[at_1e2].mean())

    # modeled qps: all-exact pass vs expected cascade cost per batch
    exact_us = 1e6 * autotune.modeled_cost(
        batch, n, d, block_m=128, block_n=512, precision="f32").step_time
    rff_us = flash_rff.modeled_query_cost_us(
        batch, d, n_features=n_features, n_pilot=n_pilot)
    cascade_us = rff_us + (1.0 - frac_rff) * exact_us
    speedup = exact_us / cascade_us

    emit("rff_cascade", n=n, d=d, batch=batch, backend="jnp",
         accuracy_target=1e-2, rff_hit_frac=round(hit_1e2, 4),
         rff_features=n_features, rff_pilot=n_pilot, rff_groups=groups,
         # the Silverman bandwidth is runtime-derived, so it must stay
         # out of the gate's cell identity (check_regression ID_FIELDS
         # includes "h") — a baseline pin can't depend on a computed float
         silverman_h=round(h, 4), rows=rows,
         traffic="/".join(f"{t:g}@{f:g}" for t, f in TRAFFIC),
         mixed_rff_frac=round(frac_rff, 4),
         resolved_ok_frac=round(frac_ok, 4),
         escalated=escalated,
         worst_cert_slack=f"{worst:.2e}",
         fit_s=round(fit_s, 1), serve_s=round(serve_s, 1),
         exact_model_us=round(exact_us, 1),
         rff_model_us=round(rff_us, 1),
         cascade_model_us=round(cascade_us, 1),
         modeled_speedup=round(speedup, 2),
         target_speedup=target_speedup,
         meets_target=bool(speedup >= target_speedup
                           and frac_ok >= target_frac
                           and worst <= CERT_SLACK))
    if worst > CERT_SLACK:
        raise RuntimeError(
            f"certified band violated by {worst:.2e} at acceptance scale")
    if frac_ok < target_frac:
        raise RuntimeError(
            f"only {frac_ok:.0%} of mixed traffic resolved at the RFF tier "
            f"with error ≤1e-2 (target {target_frac:.0%})")
    if speedup < target_speedup:
        raise RuntimeError(
            f"modeled cascade speedup {speedup:.1f}x below the "
            f"{target_speedup}x target")


def main(
    smoke_n: int = 8192,
    smoke_d: int = 2,
    run_acceptance: bool = False,
    seed: int = 0,
) -> None:
    smoke(n=smoke_n, d=smoke_d, seed=seed)
    if run_acceptance:
        acceptance(seed=seed)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--acceptance", action="store_true",
                    help="run the 256k mixed-traffic gated cell")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke_n=args.n, smoke_d=args.d, run_acceptance=args.acceptance,
         seed=args.seed)
