"""Shared benchmark utilities: timing, CSV output, CPU-scaled sizes.

The paper's GPU sizes (up to 1M points) are CPU-scaled here; every harness
takes ``--scale`` so the same code reproduces the paper's exact sweep on
real hardware.  Timings use best-of-k wall clock around block_until_ready.
"""

from __future__ import annotations

import time
from typing import Callable

import jax


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-k wall-clock seconds (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, **fields):
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}")
