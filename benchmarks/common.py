"""Shared benchmark utilities: timing, CSV + JSON output, CPU-scaled sizes.

The paper's GPU sizes (up to 1M points) are CPU-scaled here; every harness
takes ``--scale`` so the same code reproduces the paper's exact sweep on
real hardware.  Timings use best-of-k wall clock around block_until_ready.

Every ``emit`` call is also captured into an in-process record list so
``benchmarks/run.py`` can dump the whole suite as machine-readable JSON
(``BENCH_flash.json``) — the per-PR perf trajectory artifact.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax

#: Every emit() of the current process, in order — dumped by write_bench_json.
RECORDS: List[Dict] = []


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-k wall-clock seconds (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _plain(v):
    """JSON-safe scalar: numpy/jax scalars → python, else str fallback."""
    if isinstance(v, (int, float, str, bool)) or v is None:
        return v
    try:
        return v.item()
    except AttributeError:
        return str(v)


def emit(name: str, **fields):
    RECORDS.append({"cell": name, **{k: _plain(v) for k, v in fields.items()}})
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{name},{kv}")


def write_bench_json(path: str, metrics: Dict | None = None,
                     **meta) -> None:
    """Dump every emitted cell (plus run metadata) as one JSON artifact.

    ``metrics`` (an ``obs.metrics_snapshot()`` dict) is embedded as a
    top-level key — already JSON-safe, kept out of ``meta`` so the
    regression gate and other meta consumers see only flat scalars.
    """
    doc = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            **{k: _plain(v) for k, v in meta.items()},
        },
        "cells": RECORDS,
    }
    if metrics is not None:
        doc["metrics"] = metrics
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
