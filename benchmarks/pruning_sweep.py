"""Cluster-pruning sweep: occupancy, certified error, speedup vs dense.

Three kinds of cells:

  * ``pruning_smoke`` — the pruned kernels actually run (interpret mode on
    CPU) against the dense kernels at a small clustered problem: max
    relative deviation at epsilon=0 (must be f32-noise-level) and the
    measured occupancy.  This is the CI gate.
  * ``pruning`` — the epsilon sweep at the acceptance scale: per epsilon,
    the measured tile-map occupancy (real bounds prepass on the real
    clustered data), the certified per-row error bound, the measured
    relative density error of the *actual pruned kernel* on a query
    subsample vs the streaming-jnp dense reference, and the modeled
    dense/pruned runtimes (kernels/autotune.py cost model with the
    occupancy term — the same model PR 3's acceptance cell used; on TPU
    hardware the smoke cells above become the measured counterpart).
  * ``pruning_acceptance`` — the issue's gate: a clustered 256k-sample
    16-d problem, the largest modeled speedup among epsilons whose
    measured relative error is ≤ 1e-6, target ≥ 5×.

    PYTHONPATH=src python -m benchmarks.pruning_sweep
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.mixtures import GaussianMixture
from repro.kernels import autotune, ops, spatial


def clustered_mixture(d: int = 16, k: int = 64, spread: float = 4.0,
                      sigma: float = 0.05, seed: int = 0) -> GaussianMixture:
    """k tight, well-separated isotropic clusters in [0, spread]^d.

    The regime DEANN-style pruning targets: bandwidths that resolve the
    cluster structure make almost every cross-cluster tile's kernel weight
    underflow, so certified skipping removes ~(1 − 1/k) of the work.
    """
    rng = np.random.default_rng(seed)
    means = rng.uniform(0.0, spread, size=(k, d))
    return GaussianMixture(
        means=means,
        stds=np.full((k,), sigma),
        weights=np.full((k,), 1.0 / k),
    )


def _modeled_times(m, n, d, dense_blocks, pruned_blocks, occ,
                   precision="f32"):
    """(dense_s, pruned_s) modeled step times, each at ITS OWN tuned tiles;
    pruned includes the per-batch bounds prepass (query row-tile stats +
    the (m/bm × n/bn) centroid-distance GEMM)."""
    dense = autotune.modeled_cost(m, n, d, block_m=dense_blocks[0],
                                  block_n=dense_blocks[1],
                                  precision=precision)
    bm, bn = pruned_blocks
    pruned = autotune.modeled_cost(m, n, d, block_m=bm, block_n=bn,
                                   precision=precision, occupancy=occ)
    from repro.kernels import tuning

    mt, nt = -(-m // bm), -(-n // bn)
    prepass_flops = 2.0 * mt * nt * d + 6.0 * m * d      # bounds GEMM + stats
    prepass_s = prepass_flops / tuning.VPU_OPS
    return dense.step_time, pruned.step_time + prepass_s


def smoke_cells(n: int = 8192, m: int = 1024, d: int = 8, h: float = 0.25,
                seed: int = 0):
    """Pruned kernels really run (interpret) and match dense at epsilon=0."""
    mix = clustered_mixture(d=d, k=16, spread=6.0, sigma=0.05, seed=seed)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    y = mix.sample(jax.random.fold_in(key, 1), m)
    bm, bn = 64, 256
    kw = dict(block_m=bm, block_n=bn, interpret=True)
    t_dense = timeit(lambda: ops.flash_kde(x, y, h, prune="off", **kw))
    t_pruned = timeit(lambda: ops.flash_kde(x, y, h, prune=0.0, **kw))
    dense = np.asarray(ops.flash_kde(x, y, h, prune="off", **kw))
    pruned = np.asarray(ops.flash_kde(x, y, h, prune=0.0, **kw))
    rel = float(np.max(np.abs(pruned - dense) / (np.abs(dense) + 1e-30)))
    occ = autotune.expected_occupancy(m, n, d)
    emit("pruning_smoke", n=n, m=m, d=d, h=h, block_m=bm, block_n=bn,
         max_rel_err_eps0=f"{rel:.2e}", occupancy=round(occ, 4),
         wall_dense_ms=round(t_dense * 1e3, 1),
         wall_pruned_ms=round(t_pruned * 1e3, 1),
         interpret=True)
    assert rel < 1e-5, f"epsilon=0 pruning deviated from dense: {rel}"
    return rel, occ


def acceptance_cells(n: int = 262144, m: int = 32768, d: int = 16,
                     k_clusters: int = 64, h: float = 0.2, seed: int = 0,
                     n_err_queries: int = 512,
                     epsilons=(0.0, 1e-12, 1e-9, 1e-6)):
    """The 256k×16-d clustered acceptance sweep (modeled runtimes).

    Error accounting: ``epsilon=0`` pruning is bitwise-identical to
    visiting every tile in the clustered layout (a skipped tile's every
    f32 term underflows to exactly 0.0), so the error *attributable to
    pruning* at epsilon>0 is measured against the epsilon=0 run.  The
    residual deviation between the epsilon=0 run and the dense kernel is
    pure f32 accumulation-order noise (the same magnitude as the dense
    kernel's own deviation from a float64 oracle) and is emitted
    separately as ``reorder_noise``.
    """
    mix = clustered_mixture(d=d, k=k_clusters, spread=4.0, sigma=0.05,
                            seed=seed)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    y = mix.sample(jax.random.fold_in(key, 1), m)
    yq = y[:n_err_queries]

    # what a dense pass would launch at this shape
    dense_blocks = autotune.resolve_blocks("auto", "auto", m, n, d,
                                           precision="f32", measure=False)
    # warm-up pruned call on the REAL traffic shape: records occupancy at
    # the launch AND fine-probe widths under the (rows, cols, d) bucket the
    # re-resolve below will consult — which then *learns* that smaller
    # column tiles skip more (the autotuner's expected-occupancy term)
    ops.flash_kde(x, y, h, block_m=dense_blocks[0], block_n=dense_blocks[1],
                  interpret=True, prune=0.0, seed=seed)
    bm, bn = autotune.resolve_blocks("auto", "auto", m, n, d,
                                     precision="f32", measure=False,
                                     pruned=True)
    emit("pruning_tiles", n=n, m=m, d=d,
         dense_block_m=dense_blocks[0], dense_block_n=dense_blocks[1],
         pruned_block_m=bm, pruned_block_n=bn,
         learned_occ_fine=round(autotune.expected_occupancy(
             m, n, d, autotune.FINE_PROBE_BLOCK), 4))

    # fit-time spatial prep at the tuned tiles (what the serve registry
    # caches per tier), plus the full-traffic bounds prepass for occupancy
    index = spatial.build_index(x, n_clusters=k_clusters, seed=seed)
    xlay = spatial.cluster_layout(jnp.asarray(x, jnp.float32), index.labels,
                                  bn)
    col_meta = spatial.tile_metadata(xlay.points, xlay.real, block=bn)
    qlay = spatial.cluster_layout(jnp.asarray(y, jnp.float32),
                                  spatial.assign(y, index), bm)
    inv2h2 = jnp.asarray(1.0 / (2.0 * h * h), jnp.float32).reshape(1, 1)

    # anchors: dense kernel (at its own tiles) and the exact-mode run
    dense_out = np.asarray(ops.flash_kde(
        x, yq, h, block_m=dense_blocks[0], block_n=dense_blocks[1],
        interpret=True, prune="off"))
    base = np.asarray(ops.flash_kde(x, yq, h, block_m=bm, block_n=bn,
                                    interpret=True, prune=0.0, seed=seed))
    noise = float(np.max(np.abs(base - dense_out)
                         / (np.abs(dense_out) + 1e-30)))

    best = None
    for eps in epsilons:
        tm = spatial.tile_map(qlay.points, col_meta, inv2h2, eps,
                              block_m=bm, kind="kde")
        vl = spatial.visit_lists(tm.keep)
        occ = vl.occupancy
        cert = float(jnp.max(tm.err_bound))
        got = np.asarray(ops.flash_kde(x, yq, h, block_m=bm, block_n=bn,
                                       interpret=True, prune=eps, seed=seed))
        rel_err = float(np.max(np.abs(got - base) / (np.abs(base) + 1e-30)))
        dense_s, pruned_s = _modeled_times(m, n, d, dense_blocks, (bm, bn),
                                           occ)
        speedup = dense_s / pruned_s
        emit("pruning", n=n, m=m, d=d, h=h, epsilon=eps,
             block_m=bm, block_n=bn,
             occupancy=round(occ, 4),
             cert_max_abs=f"{cert:.2e}",
             prune_rel_err=f"{rel_err:.2e}",
             reorder_noise=f"{noise:.2e}",
             dense_model_ms=round(dense_s * 1e3, 3),
             pruned_model_ms=round(pruned_s * 1e3, 3),
             modeled_speedup=round(speedup, 2),
             err_queries=n_err_queries)
        if rel_err <= 1e-6 and (best is None or speedup > best[0]):
            best = (speedup, eps, occ, rel_err)

    assert best is not None, "no epsilon met the 1e-6 relative-error bar"
    speedup, eps, occ, rel_err = best
    emit("pruning_acceptance", n=n, m=m, d=d, h=h,
         epsilon=eps, occupancy=round(occ, 4),
         rel_err=f"{rel_err:.2e}", modeled_speedup=round(speedup, 2),
         target_speedup=5.0, meets_target=bool(speedup >= 5.0))
    return speedup


def main(smoke_n: int = 8192, smoke_m: int = 1024,
         acceptance: bool = True, acceptance_n: int = 262144,
         acceptance_m: int = 32768):
    smoke_cells(n=smoke_n, m=smoke_m)
    if acceptance:
        acceptance_cells(n=acceptance_n, m=acceptance_m)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--no-acceptance", action="store_true",
                    help="smoke cells only (fast CI lane)")
    a = ap.parse_args()
    main(smoke_n=8192 * a.scale, smoke_m=1024 * a.scale,
         acceptance=not a.no_acceptance,
         acceptance_n=262144 * a.scale, acceptance_m=32768 * a.scale)
