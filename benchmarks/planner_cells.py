"""Planner-attributed benchmark cells: plan cost vs the default path.

One ``planner`` cell per committed gated (``modeled_speedup``) baseline
cell: the request the cell derives (``repro.plan.golden.request_for_cell``
— the same derivation the golden fixture uses), the plan the planner
chooses for it today, and the modeled cost of that plan against the
*default serve path* (f32 @ 128x512 launch tiles, the ServeConfig
defaults).  ``modeled_speedup`` = default cost / planned cost, so the
regression gate enforces the acceptance bar directly: the planner must
keep matching-or-beating the default path on every committed cell, within
the gate's 15%.

Cells also carry the plan's decision fields plus ``request_key`` so
``benchmarks/check_regression.py`` can cross-check every cell against the
pinned golden fixture (``tests/golden_plans.json``) and fail on silent
plan drift.

Everything here is deterministic — pure cost model + committed artifacts,
no hardware timing — which is what makes these cells gateable at all.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks import common
from repro.kernels import autotune
from repro.plan import (
    BenchModel,
    load_golden,
    plan,
    request_for_cell,
    request_key,
)

GATE_FIELD = "modeled_speedup"


def default_path_cost(req):
    """Modeled cost of the default serve path for one request: the f32
    tier at the ServeConfig default 128x512 launch tiles, dense.  (For
    every committed regime the measured eps=0 occupancy extrapolates to
    ~1.0 at 512-wide tiles, so dense IS the default path's model.)"""
    block_n = max(128, (min(512, req.n) // 128) * 128)
    return autotune.modeled_cost(
        req.q, req.n, req.d, block_m=128, block_n=block_n,
        precision="f32", vmem_itemsize=4,
    )


def main(baseline_path: str = "benchmarks/BENCH_baseline.json",
         golden_path: str | None = None) -> None:
    with open(baseline_path) as f:
        baseline = json.load(f)
    try:
        golden = load_golden(golden_path)["plans"]
    except FileNotFoundError:
        golden = {}

    bench = BenchModel.load()
    seen = set()
    for cell in baseline.get("cells", ()):
        if not isinstance(cell, dict) or GATE_FIELD not in cell:
            continue
        req = request_for_cell(cell)
        if req is None:
            continue
        key = request_key(req)
        if key in seen:          # several baseline cells derive one request
            continue
        seen.add(key)

        p = plan(req, bench=bench)
        default = default_path_cost(req)
        if default is None:
            common.emit("planner_error", request_key=key,
                        error="default path infeasible")
            continue
        # an rff plan's executable cost is the *expected* cascade cost:
        # every row pays the feature GEMM, escalated rows also pay the
        # exact pass the plan's modeled_cost_s prices
        plan_cost = (p.modeled_rff_cost_s
                     + (1.0 - p.rff_hit_frac) * p.modeled_cost_s
                     if p.rff else p.modeled_cost_s)
        speedup = default.step_time / plan_cost
        pinned = golden.get(key, {}).get("plan")
        common.emit(
            "planner",
            request_key=key,
            n=req.n, d=req.d, q=req.q, accuracy=req.accuracy,
            backend=p.backend, precision=p.precision,
            prune=p.prune, block_m=p.block_m, block_n=p.block_n,
            plan_id=p.plan_id,
            plan_modeled_us=round(plan_cost * 1e6, 3),
            default_modeled_us=round(default.step_time * 1e6, 3),
            modeled_speedup=round(speedup, 2),
            beats_default=bool(speedup >= 1.0),
            golden_match=(pinned == p.as_dict()) if pinned else None,
        )


if __name__ == "__main__":
    import sys

    main(*(sys.argv[1:] or ()))
    path = "BENCH_planner.json"
    common.write_bench_json(path, suite="planner-cells")
    print(f"# -> {path}", file=sys.stderr)
