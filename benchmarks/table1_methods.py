"""Table 1 reproduction: method comparison at fixed (n_train, n_test).

Paper: Flash-SD-KDE vs PyKeOps KDE vs PyKeOps SD-KDE at 32k×4k.  The
PyKeOps analogue here is the lazy/streaming formulation WITHOUT the GEMM
re-ordering (elementwise distance tiles) — the state-of-the-art kernel-
reduction pattern the paper benchmarks against; Flash is the GEMM-form
pipeline.  CPU-scaled sizes by default.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import kde
from repro.core.mixtures import benchmark_mixture_16d


def keops_style_kde(x, y, h, block=1024):
    """Streamed elementwise (non-GEMM) kernel reduction — KeOps-style."""
    def body(acc, xblk):
        diff = y[:, None, :] - xblk[None, :, :]
        sq = jnp.sum(diff * diff, -1)
        return acc + jnp.exp(-sq / (2 * h * h)).sum(1)

    from repro.core.kde import _stream_blocks, PAD_VALUE  # noqa: F401
    from repro.core.bandwidth import gaussian_norm_const

    n, d = x.shape
    s = _stream_blocks(x, block, body, jnp.zeros(y.shape[0]))
    return s / (n * gaussian_norm_const(d, 1.0) * h**d)


def keops_style_sdkde(x, y, h, block=1024):
    def body(carry, xblk):
        s0, s1 = carry
        diff = x[:, None, :] - xblk[None, :, :]
        sq = jnp.sum(diff * diff, -1)
        phi = jnp.exp(-sq / (2 * h * h))
        return s0 + phi.sum(1), s1 + jnp.einsum("ij,jd->id", phi, xblk)

    from repro.core.kde import _stream_blocks

    n, d = x.shape
    s0, s1 = _stream_blocks(
        x, block, body, (jnp.zeros(n), jnp.zeros((n, d)))
    )
    score = (s1 - x * s0[:, None]) / (h * h * s0[:, None])
    x_sd = x + 0.5 * h * h * score
    return keops_style_kde(x_sd, y, h, block)


def main(n: int = 8192):
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(0)
    x = mix.sample(key, n)
    y = mix.sample(jax.random.fold_in(key, 1), n // 8)
    h = 0.5

    t_flash = timeit(jax.jit(
        lambda a, b: kde.kde_eval(kde.sdkde_shift(a, h, block=2048),
                                  b, h, block=2048)), x, y)
    t_keops_kde = timeit(jax.jit(
        lambda a, b: keops_style_kde(a, b, h, block=512)), x, y)
    t_keops_sd = timeit(jax.jit(
        lambda a, b: keops_style_sdkde(a, b, h, block=512)), x, y)

    emit("table1", method="flash_sdkde", n=n,
         runtime_ms=round(t_flash * 1e3, 2), rel="1.00x")
    emit("table1", method="keops_style_kde", n=n,
         runtime_ms=round(t_keops_kde * 1e3, 2),
         rel=f"{t_keops_kde / t_flash:.2f}x")
    emit("table1", method="keops_style_sdkde", n=n,
         runtime_ms=round(t_keops_sd * 1e3, 2),
         rel=f"{t_keops_sd / t_flash:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    main(ap.parse_args().n)
