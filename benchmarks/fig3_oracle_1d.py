"""Fig. 3 reproduction: oracle MISE/MIAE on the 1-D mixture vs n_train.

Grid-integrated errors (exact in 1-D).  Expected orderings from the paper:
Laplace-corrected lowest MISE; fused == non-fused; negative mass logged.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.core import kde
from repro.core.bandwidth import silverman_bandwidth
from repro.core.metrics import oracle_errors
from repro.core.mixtures import benchmark_mixture_1d


def main(ns=(512, 1024, 2048, 4096, 8192), seeds=(0, 1, 2)):
    mix = benchmark_mixture_1d()
    for n in ns:
        acc = {m: {"mise": 0.0, "miae": 0.0, "neg": 0.0}
               for m in ("kde", "sdkde", "laplace", "laplace_nonfused")}
        for seed in seeds:
            key = jax.random.fold_in(jax.random.PRNGKey(seed), n)
            x = mix.sample(key, n)
            h = float(silverman_bandwidth(x))
            fns = {
                "kde": lambda g: kde.kde_eval(x, g, h, block=1024),
                "sdkde": lambda g: kde.sdkde_eval(x, g, h, block=1024),
                "laplace": lambda g: kde.laplace_kde_eval(x, g, h,
                                                          block=1024),
                "laplace_nonfused": lambda g: kde.laplace_kde_eval_nonfused(
                    x, g, h, block=1024),
            }
            for name, fn in fns.items():
                e = oracle_errors(fn, mix)
                acc[name]["mise"] += e.mise / len(seeds)
                acc[name]["miae"] += e.miae / len(seeds)
                acc[name]["neg"] += e.neg_mass / len(seeds)
        for name, v in acc.items():
            emit("fig3", n=n, method=name, mise=f"{v['mise']:.3e}",
                 miae=f"{v['miae']:.3e}", neg_mass=f"{v['neg']:.3e}")


if __name__ == "__main__":
    argparse.ArgumentParser().parse_args()
    main()
