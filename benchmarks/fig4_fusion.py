"""Fig. 4 reproduction: fused vs non-fused Laplace-correction runtime.

The non-fused baseline runs TWO quadratic passes (plain KDE + the squared-
moment pass, recomputing distances); the fused kernel applies the Laplace
factor inside the single pass.  The speedup ratio is the fusion win; the
Flash-SD-KDE / Flash-Laplace ratio is also reported for context (paper
right panel).  1-D sweep, as in the paper.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, timeit
from repro.core import kde
from repro.core.mixtures import benchmark_mixture_1d


def main(ns=(4096, 8192, 16384, 32768)):
    mix = benchmark_mixture_1d()
    key = jax.random.PRNGKey(0)
    h = 0.3
    for n in ns:
        x = mix.sample(jax.random.fold_in(key, n), n)
        y = mix.sample(jax.random.fold_in(key, n + 1), n // 8)
        t_fused = timeit(
            jax.jit(lambda a, b: kde.laplace_kde_eval(a, b, h, block=4096)),
            x, y)
        t_nonfused = timeit(
            jax.jit(lambda a, b: kde.laplace_kde_eval_nonfused(
                a, b, h, block=4096)), x, y)
        t_sdkde = timeit(
            jax.jit(lambda a, b: kde.sdkde_eval(a, b, h, block=4096)), x, y)
        emit("fig4", n=n,
             fused_ms=round(t_fused * 1e3, 2),
             nonfused_ms=round(t_nonfused * 1e3, 2),
             fusion_speedup=round(t_nonfused / t_fused, 2),
             sdkde_over_laplace=round(t_sdkde / t_fused, 2))


if __name__ == "__main__":
    argparse.ArgumentParser().parse_args()
    main()
