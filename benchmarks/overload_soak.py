"""Overload soak: open-loop burst traffic through the admission frontend.

The acceptance story of the admission layer (PR 9), run as a benchmark
cell so CI tracks it per PR.  A steady → 4× burst → recovery arrival arc
is replayed open-loop (arrivals do NOT wait for answers — the only
regime where admission control matters) against an ``AsyncFrontend``
over a plain ``ServeEngine``:

  1. **Probe** — an open-loop submit-all-then-drain burst measures the
     pipeline's serving capacity (requests/s) and its p99; both
     calibrate the arc.
  2. **Steady** — arrivals at half the measured capacity: everything
     should be answered, no shedding.
  3. **Burst** — arrivals at 4× capacity: the queue fills, the state
     machine walks accepting → backpressure → shedding, AIMD collapses
     the admitted rate, excess arrivals get *typed* ``Overloaded``.
  4. **Settle** — steady-rate arrivals while the burst backlog (bounded
     by the queue) drains and AIMD climbs back; counted for the
     silent-drop ledger but excluded from the goodput bars.  The
     recovery clock then holds until the queue actually empties (a
     bounded wait — queued entries expire, typed, at their deadline),
     so recovery measures the recovered steady state, never the drain
     transient.
  5. **Recovery** — back to the steady rate: admission is reopened and
     goodput must be back at the steady level.

The probe is a wall-clock measurement at CPU scale, so it can under-read
the true serving rate (cold dispatcher thread, scheduler noise mid
suite) — and a "4× capacity" burst computed from an under-read is no
burst at all.  When a burst sheds nothing the arc is replayed with the
burst factor doubled (4× → 8× → … up to ``max_burst_factor``) until
overload actually engages; the acceptance bars are judged on the arc
that engaged.  Only if the ceiling factor *still* sheds nothing does the
cell fail — at that point the queue genuinely never filled and the cell
proved nothing.

The bars also assume the probed capacity still holds when the arc runs;
on shared CPU the machine's real capacity can swing several-fold within
one run.  When a bar would fail, the capacity is re-probed: if it
drifted more than 25% the miss indicts the environment rather than the
policy, and the arc is re-run (loudly, at most twice) against the fresh
probe.  A failure with a *stable* re-probe stands — that one is the
admission layer's fault.

HARD-FAILS (raises, which fails the suite and therefore the regression
gate) when the overload contract is violated:

  * **any silent drop** — every submitted request must resolve as an
    answer, ``Overloaded``, or ``DeadlineExceeded`` (certified Degraded
    counts as answered); the frontend's own ledger must balance too;
  * **unbounded tail** — answered-request p99 above ``2 ×
    max(steady_p99, probe_p99, p99_floor_s)``.  Every request carries
    exactly that value as its deadline and both the queue and the engine
    raise typed ``DeadlineExceeded`` past it, so this bar is enforced
    *structurally*, not statistically.  The floor exists because
    CPU-scale latencies are milliseconds and a 2× ratio of scheduler
    noise means nothing (same reasoning as chaos_soak's floored ratio);
  * **goodput collapse** — answered requests/s through the burst AND the
    recovery phase each below ``goodput_frac`` (80%) of the measured
    steady-phase goodput;
  * **overload never engaged** — a burst that sheds nothing even at the
    escalation ceiling means the arc never exceeded capacity and the
    cell proved nothing.

The gated ``overload_acceptance`` cell follows the streaming_acceptance
precedent: its ratio is wall-clock-derived, so the committed baseline
pins ``modeled_speedup`` at the *target* (1.0 ≡ goodput exactly at the
80% bar) and the gate enforces "still past target", with the hard raises
above as the real teeth.
"""

from __future__ import annotations

import time
from concurrent.futures import Future
from typing import Dict, List

import jax
import numpy as np

from benchmarks import common
from repro.core.mixtures import mixture_for_dim
from repro.serve import (AsyncFrontend, DeadlineExceeded, FrontendConfig,
                         QueryRequest,
                         Overloaded, ServeConfig, ServeEngine)

#: Goodput through burst + recovery, as a fraction of steady goodput.
GOODPUT_FRAC = 0.8
#: Answered p99 bar: 2 × the (floored) steady p99.
P99_RATIO_MAX = 2.0
#: Latency floor under the p99 bar AND the per-request deadline — below
#: this, CPU-scale ratios measure the scheduler, not the policy.
P99_FLOOR_S = 0.1
#: Burst arrival rate, as a multiple of measured capacity (ISSUE 9).
BURST_FACTOR = 4.0
#: Escalation ceiling: the burst factor doubles while nothing sheds,
#: so a probe that under-read capacity cannot produce a vacuous cell.
MAX_BURST_FACTOR = 64.0


def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def run_overload(
    n: int = 2048,
    d: int = 4,
    probe_requests: int = 96,
    phase_s: float = 0.6,
    max_rows: int = 8,
    max_queue: int = 64,
    max_burst_arrivals: int = 2000,
    seed: int = 0,
    goodput_frac: float = GOODPUT_FRAC,
    max_burst_factor: float = MAX_BURST_FACTOR,
) -> dict:
    """The steady → 4× burst → recovery arc.  Returns the stats dict
    (also emitted as cells); raises on any violated overload bar."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    pool = np.asarray(mix.sample(jax.random.fold_in(key, 1), 1024),
                      np.float32)
    rng = np.random.default_rng(seed)

    cfg = ServeConfig(backend="jnp", method="sdkde",
                      min_batch=16, max_batch=64)
    eng = ServeEngine(cfg)
    eng.register("soak", x)
    for b in cfg.bucket_sizes():          # warm: measure policy, not JIT
        eng.query(QueryRequest(key="soak", points=pool[:b]))
        eng.query(QueryRequest(key="soak", points=pool[:b],
                               precision="bf16"))  # brownout tier

    # -- probe: OPEN-loop capacity + dispatch p99 -------------------------
    # Capacity must be measured the way the arc will load the system:
    # all-at-once submission through the continuous batcher (a closed
    # loop would measure per-request round-trip overhead and report a
    # "capacity" the fused path beats 10x over — making a 4x burst of it
    # no burst at all).
    def _probe() -> tuple:
        fe = AsyncFrontend(eng, FrontendConfig(
            workers=1, max_queue=probe_requests + 8, batch_wait_ms=1.0,
            default_deadline_ms=60_000.0))
        lats: List[float] = []
        t0 = time.perf_counter()
        for _ in range(probe_requests):
            m = int(rng.integers(1, max_rows + 1))
            off = int(rng.integers(0, pool.shape[0] - m))
            f = fe.submit(QueryRequest(key="soak",
                                       points=pool[off:off + m]))
            f.add_done_callback(
                lambda f, ts=time.perf_counter():
                lats.append(time.perf_counter() - ts))
        fe.drain(timeout=60.0)
        wall = time.perf_counter() - t0
        fe.close()
        # p99 includes probe queueing: the saturated-pipeline round trip
        return probe_requests / wall, _pct(lats, 99)

    capacity, probe_p99 = _probe()

    # every request's deadline IS the p99 bar: late answers become typed
    # DeadlineExceeded (queue expiry or engine post-check), so the
    # answered-p99 acceptance bar holds by construction
    deadline_s = P99_RATIO_MAX * max(probe_p99, P99_FLOOR_S)

    def _arc(burst_factor: float) -> tuple:
        """One steady → burst → recovery replay at the given factor."""
        fe = AsyncFrontend(eng, FrontendConfig(
            workers=1, max_queue=max_queue, batch_wait_ms=1.0,
            default_deadline_ms=1e3 * deadline_s,
            rate=capacity, burst=max(8.0, capacity / 8),
            # the AIMD floor must clear the steady/recovery arrival rate
            # (0.5 x capacity): the bucket's job is to clip the burst,
            # and a floor below the steady rate lets a collapsed
            # controller lawfully shed recovery traffic it could serve,
            # failing the goodput bar on controller hysteresis alone
            min_rate=0.55 * capacity,
            aimd_increase=max(8.0, capacity / 4),
            p99_slo_ms=1e3 * deadline_s))
        burst_s = min(phase_s,
                      max_burst_arrivals / (burst_factor * capacity))
        # the settle window absorbs the backlog drain (bounded by
        # max_queue entries) and AIMD's additive climb back, so
        # "recovery" measures the post-recovery steady state rather
        # than the drain transient; settle traffic still counts for the
        # silent-drop ledger, just not for the goodput bars
        phases = (("steady", 0.5 * capacity, phase_s),
                  ("burst", burst_factor * capacity, burst_s),
                  ("settle", 0.5 * capacity,
                   min(max_queue / capacity, phase_s)),
                  ("recovery", 0.5 * capacity, phase_s))
        durations = {name: dur for name, _, dur in phases}
        done_at: Dict[int, float] = {}
        futs: List[tuple] = []            # (phase, submit_t, i, future)
        counts = {p: {"arrived": 0, "answered": 0, "shed": 0,
                      "expired": 0, "degraded": 0} for p, _, _ in phases}

        i = 0
        offset = 0.0                      # schedule origin of the phase
        clock0 = time.perf_counter()
        for name, rate, dur in phases:
            t = 0.0
            while t < dur:
                at = offset + t
                while (now := time.perf_counter() - clock0) < at:
                    time.sleep(min(2e-3, at - now))
                m = int(rng.integers(1, max_rows + 1))
                off = int(rng.integers(0, pool.shape[0] - m))
                counts[name]["arrived"] += 1
                t += 1.0 / rate
                i += 1
                try:
                    f = fe.submit(QueryRequest(
                        key="soak", points=pool[off:off + m],
                        deadline_s=deadline_s))
                except Overloaded:
                    counts[name]["shed"] += 1
                    continue
                f.add_done_callback(
                    lambda f, j=i:
                    done_at.__setitem__(j, time.perf_counter()))
                futs.append((name, time.perf_counter(), i, f))
            offset += dur
            if name == "settle":
                # "recovery" must measure the recovered steady state,
                # not the backlog drain: hold the recovery clock until
                # the queue actually empties.  Bounded — every queued
                # entry expires (typed) at its deadline, so the wait
                # cannot exceed roughly one deadline
                limit = time.perf_counter() + deadline_s + phase_s
                while (fe._heap or fe._inflight) and \
                        time.perf_counter() < limit:
                    time.sleep(2e-3)
                offset = max(offset, time.perf_counter() - clock0)
        if not fe.drain(timeout=30.0):
            # a wedged queue is its own failure mode — do not let the
            # still-pending futures read as silent drops below
            raise RuntimeError(
                "overload soak: frontend failed to drain within 30s — "
                f"{len(fe._heap)} queued, {fe._inflight} inflight")

        unresolved = 0
        answered: List[tuple] = []        # (phase, latency_s)
        for phase, ts, i, f in futs:
            if not f.done():
                unresolved += 1
                continue
            if f.exception() is None:
                counts[phase]["answered"] += 1
                counts[phase]["degraded"] += int(f.result().degraded)
                answered.append((phase, done_at[i] - ts))
            elif isinstance(f.exception(), DeadlineExceeded):
                counts[phase]["expired"] += 1
            elif isinstance(f.exception(), Overloaded):
                counts[phase]["shed"] += 1
            else:
                raise f.exception()       # a real bug is a real failure
        rep = fe.report()
        silent = fe.unaccounted() + unresolved
        fe.close()
        return counts, answered, durations, rep, silent

    for attempt in range(3):
        # the probe wall can under-read capacity at CPU scale; escalate
        # the burst until the overload contract is actually exercised
        burst_factor = BURST_FACTOR
        while True:
            counts, answered, durations, rep, silent = _arc(burst_factor)
            if silent or counts["burst"]["shed"] or \
                    burst_factor * 2 > max_burst_factor:
                break
            burst_factor *= 2
            print(f"# overload: {burst_factor / 2:g}x burst shed nothing "
                  f"(probe under-read capacity?) — escalating to "
                  f"{burst_factor:g}x")

        answered_lat = [l for _, l in answered]
        steady_p99 = _pct([l for p, l in answered if p == "steady"], 99)
        p99_bar = P99_RATIO_MAX * max(steady_p99, probe_p99, P99_FLOOR_S)
        answered_p99 = _pct(answered_lat, 99)
        goodput = {p: counts[p]["answered"] / durations[p] for p in counts}
        # floor: an idle steady phase (tiny test sizes) cannot make the
        # ratio degenerate
        ratio = min(goodput["burst"], goodput["recovery"]) / max(
            goodput["steady"], 1e-9)

        if silent:
            break            # a ledger hole is a bug in any environment
        if (answered_p99 <= p99_bar and ratio >= goodput_frac
                and counts["burst"]["shed"]) or attempt == 2:
            break
        # the bars assume the probed capacity still holds; on shared CPU
        # the machine's real capacity can swing several-fold mid-arc.
        # Re-probe: if capacity drifted, the miss indicts the
        # environment, not the policy — re-run against the fresh probe.
        # A stable re-probe lets the failure stand.
        cap2, p99_2 = _probe()
        drift = abs(cap2 / capacity - 1.0)
        if drift <= 0.25:
            break
        print(f"# overload: capacity drifted {capacity:.1f} -> "
              f"{cap2:.1f} rps ({drift:.0%}) across the arc — "
              f"nonstationary environment, re-running on the fresh probe")
        capacity, probe_p99 = cap2, p99_2
        deadline_s = P99_RATIO_MAX * max(probe_p99, P99_FLOOR_S)

    out = {
        "capacity_rps": round(capacity, 1),
        "burst_factor": burst_factor,
        "probe_p99_ms": round(1e3 * probe_p99, 3),
        "deadline_ms": round(1e3 * deadline_s, 1),
        "answered_p99_ms": round(1e3 * answered_p99, 3),
        "p99_bar_ms": round(1e3 * p99_bar, 3),
        "goodput_steady_rps": round(goodput["steady"], 1),
        "goodput_burst_rps": round(goodput["burst"], 1),
        "goodput_recovery_rps": round(goodput["recovery"], 1),
        "goodput_ratio": round(ratio, 3),
        "silent_drops": silent,
        "shed_burst": counts["burst"]["shed"],
        "admit_rate_final": rep["admit_rate"],
        "transitions": len(rep["transitions"]),
        **{f"{p}_{k}": v for p, c in counts.items() for k, v in c.items()},
    }
    common.emit("overload_soak", n=n, d=d, **out)
    common.emit(
        "overload_acceptance", n=n, d=d,
        modeled_speedup=round(ratio / goodput_frac, 2), target_speedup=1.0,
        goodput_ratio=round(ratio, 3), p99_ok=answered_p99 <= p99_bar,
        note="baseline pinned at target_speedup: ratio is "
             "wall-clock-derived (see check_regression docstring)")

    if silent:
        raise RuntimeError(
            f"overload soak lost {silent} requests without a typed outcome "
            f"— every request must resolve as answered, Overloaded, or "
            f"DeadlineExceeded")
    if answered_p99 > p99_bar:
        raise RuntimeError(
            f"answered p99 {1e3 * answered_p99:.1f}ms exceeds the bar "
            f"{1e3 * p99_bar:.1f}ms (2x floored steady p99) — the deadline "
            f"machinery failed to cap the tail")
    if ratio < goodput_frac:
        raise RuntimeError(
            f"goodput through burst/recovery is {ratio:.0%} of steady "
            f"(bar: >= {goodput_frac:.0%}) — admission control is "
            f"collapsing throughput instead of protecting it")
    if not out["shed_burst"]:
        raise RuntimeError(
            f"nothing shed even at a {burst_factor:g}x burst — overload "
            f"never engaged, the cell measured an underloaded system")
    return out


def main(n: int = 2048, d: int = 4, phase_s: float = 0.6,
         seed: int = 0) -> None:
    run_overload(n=n, d=d, phase_s=phase_s, seed=seed)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--phase-s", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(n=args.n, d=args.d, phase_s=args.phase_s, seed=args.seed)
