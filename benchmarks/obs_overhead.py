"""Observability overhead: serve p50 with telemetry off vs fully on.

The acceptance bar for the obs layer is <=2% added latency on the serve
hot path.  This harness times ``ServeEngine.query`` *externally* (the
engine's own LatencyRecorder is bucket-quantized at ~1.47x resolution —
far too coarse to resolve a 2% delta) on ONE warmed engine, alternating
obs-off and obs-on passes over the same request schedule so CPU-frequency
drift and allocator state cancel out of the comparison.  The "on" passes
run with metrics AND tracing enabled (tracing is off by default in
production, so this is the worst case, not the default config).

On a shared CPU runner even the paired ratio carries a few percent of
noise; the cell records the measured ratio for trend tracking, and the CI
regression gate treats ``obs_overhead`` as informational (it is not a
speedup cell).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import obs
from repro.core.mixtures import mixture_for_dim
from repro.serve import QueryRequest, ServeConfig, ServeEngine


def main(n: int = 2048, d: int = 4, n_requests: int = 24,
         repeats: int = 6) -> None:
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(0)
    x = mix.sample(key, n)
    pool = mix.sample(jax.random.fold_in(key, 1), 2048)
    rng = np.random.default_rng(0)
    sizes = np.exp(rng.uniform(np.log(4), np.log(512),
                               n_requests)).astype(int).clip(1)
    offs = [int(rng.integers(0, pool.shape[0] - m)) for m in sizes]

    eng = ServeEngine(ServeConfig(backend="jnp"))
    eng.register("obs", x)
    for m, off in zip(sizes, offs):       # warm every bucket before timing
        eng.query(QueryRequest(key="obs", points=pool[off:off + m]))

    def pass_lats() -> list:
        lats = []
        for m, off in zip(sizes, offs):
            t0 = time.perf_counter()
            eng.query(QueryRequest(key="obs", points=pool[off:off + m]))
            lats.append(time.perf_counter() - t0)
        return lats

    metrics0, trace0 = obs.state.metrics_on, obs.state.trace_on
    lats_off, lats_on = [], []
    try:
        for _ in range(repeats):          # paired A/B: drift hits both arms
            obs.configure(metrics=False, trace=False)
            lats_off += pass_lats()
            obs.configure(metrics=True, trace=True)
            lats_on += pass_lats()
    finally:
        obs.configure(metrics=metrics0, trace=trace0)

    p50_off = 1e3 * float(np.percentile(lats_off, 50))
    p50_on = 1e3 * float(np.percentile(lats_on, 50))
    emit("obs_overhead", n=n, d=d, requests=len(sizes) * repeats,
         p50_off_ms=round(p50_off, 4), p50_on_ms=round(p50_on, 4),
         ratio=round(p50_on / p50_off, 4))


if __name__ == "__main__":
    main()
