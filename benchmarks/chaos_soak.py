"""Chaos soak: sustained serving traffic across shard kill + recovery.

The acceptance story of the resilience layer (PR 8), run as a benchmark
cell so CI tracks it per PR:

  1. **Soak** — sustained traffic through a ``ResilientEngine``
     (S shards × R replicas) with a scheduled sustained kill of one
     replica across the middle third of the run, then recovery.  Records
     qps, steady-state vs chaos-window p99, retries/hedges/fence/readmit
     activity — and HARD-FAILS (raises) if any query is dropped or the
     chaos-window p99 exceeds 5× the steady-state p99.
  2. **Degraded cell** — every replica of one shard killed: answers come
     from the surviving shards, renormalized, with the certified
     relative-error bound attached.  The cell records the bound vs the
     actual error against the full-data oracle and HARD-FAILS if any
     answer's actual error exceeds its certificate, or any certificate
     exceeds the configured accuracy target.

Both phases are deterministic under the seed (scheduled ``ChaosEvent``
windows, seeded jitter), so a CI failure replays locally bit-for-bit.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.fault_injection import ChaosConfig, ChaosEvent
from repro.serve import (QueryRequest, ResilienceConfig, ResilientEngine,
                         ServeConfig)

#: Acceptance bars (ISSUE 8): zero drops, bounded tail under chaos.
P99_RATIO_MAX = 5.0
#: Degraded-cell certified budget — partial-shard answers are coarse by
#: construction (renormalization alone costs ~n_missing/n_live), so the
#: budget is loose; the *certificate* is what must hold exactly.
DEGRADED_ACCURACY = 10.0


def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def run_soak(
    n: int = 2048,
    d: int = 4,
    requests: int = 48,
    shards: int = 2,
    replicas: int = 2,
    max_batch: int = 128,
    seed: int = 0,
    pace_s: float = 0.005,
    heartbeat_timeout_s: float = 0.5,
) -> dict:
    """Phase 1: the kill + recovery soak.  Returns the stats dict (also
    emitted as cells); raises on a dropped query or an unbounded tail."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    pool = mix.sample(jax.random.fold_in(key, 1), 4 * max_batch)

    kill_lo, kill_hi = requests // 3, 2 * requests // 3
    chaos = ChaosConfig(events=(
        ChaosEvent("shard_kill", shard=0, replica=0,
                   start=kill_lo, stop=kill_hi),
    ), seed=seed)
    cfg = ServeConfig(backend="jnp", method="sdkde",
                      min_batch=16, max_batch=max_batch)
    rcfg = ResilienceConfig(
        shards=shards, replicas=replicas, deadline_ms=30_000.0,
        backoff_ms=1.0, heartbeat_timeout_s=heartbeat_timeout_s,
        probe_every=4, seed=seed,
    )
    eng = ResilientEngine(cfg, rcfg, chaos=chaos)
    table = eng.register("soak", x)

    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(np.log(1), np.log(max_batch),
                               requests)).astype(int).clip(1)
    # warm every bucket the traffic will hit, so the soak measures
    # dispatch policy, not first-compile storms
    for b in cfg.bucket_sizes():
        eng.query(QueryRequest(key="soak", points=pool[:b],
                               deadline_s=120.0))
    eng.latency.reset()

    lat = {"steady": [], "chaos": [], "recovery": []}
    t0 = time.perf_counter()
    for i, m in enumerate(sizes):
        phase = ("steady" if i < kill_lo else
                 "chaos" if i < kill_hi else "recovery")
        off = int(rng.integers(0, pool.shape[0] - m))
        ans = eng.query(QueryRequest(key="soak",
                                     points=pool[off:off + m]))
        lat[phase].append(ans.latency_s)
        if pace_s:
            time.sleep(pace_s)   # sustained traffic, not a tight loop
    wall = time.perf_counter() - t0

    st = dict(eng.stats)
    steady_p99 = _pct(lat["steady"], 99)
    chaos_p99 = _pct(lat["chaos"], 99)
    # floor the denominator: at millisecond-scale steady latencies the
    # ratio is scheduler noise, not a tail-latency regression signal
    ratio = chaos_p99 / max(steady_p99, 5e-3)
    out = {
        "qps": int(sizes.sum() / wall),
        "steady_p99_ms": round(1e3 * steady_p99, 3),
        "chaos_p99_ms": round(1e3 * chaos_p99, 3),
        "recovery_p99_ms": round(1e3 * _pct(lat["recovery"], 99), 3),
        "p99_ratio": round(ratio, 3),
        "dropped": st["dropped"],
        "retries": st["retries"],
        "hedges": st["hedges"],
        "fenced": st["fenced"],
        "readmits": st["readmits"],
        "faults_injected": eng.injector.snapshot()["shard_kill"],
    }
    common.emit("chaos_soak", n=n, d=d, requests=requests,
                shards=table.n_shards, replicas=replicas, **out)
    eng.close()
    if out["dropped"]:
        raise RuntimeError(
            f"chaos soak dropped {out['dropped']} queries — the replicated "
            f"dispatch layer must survive a single-replica kill losslessly"
        )
    if ratio >= P99_RATIO_MAX:
        raise RuntimeError(
            f"chaos-window p99 {out['chaos_p99_ms']}ms is {ratio:.1f}x the "
            f"steady-state p99 {out['steady_p99_ms']}ms (bar: "
            f"< {P99_RATIO_MAX}x)"
        )
    return out


def run_degraded(
    n: int = 2048,
    d: int = 4,
    requests: int = 8,
    query_rows: int = 64,
    seed: int = 0,
) -> dict:
    """Phase 2: total loss of one shard — certified degraded answers.

    Every answer's certificate is checked against the full-data oracle;
    a bound that lies (actual error above it) or that exceeds the
    accuracy target is a hard failure.
    """
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    pool = mix.sample(jax.random.fold_in(key, 1), 8 * query_rows)

    chaos = ChaosConfig(events=(
        ChaosEvent("shard_kill", shard=1, start=0, stop=1 << 30),
    ), seed=seed)
    cfg = ServeConfig(backend="jnp", method="sdkde",
                      min_batch=16, max_batch=query_rows)
    rcfg = ResilienceConfig(
        shards=2, replicas=2, deadline_ms=30_000.0, backoff_ms=1.0,
        max_retries=1, degraded_accuracy=DEGRADED_ACCURACY, seed=seed,
    )
    eng = ResilientEngine(cfg, rcfg, chaos=chaos)
    table = eng.register("degraded", x)

    rng = np.random.default_rng(seed + 1)
    worst_bound = worst_actual = 0.0
    served = violations = 0
    for _ in range(requests):
        off = int(rng.integers(0, pool.shape[0] - query_rows))
        y = pool[off:off + query_rows]
        ans = eng.query(QueryRequest(key="degraded", points=y))
        assert ans.degraded and ans.missing_shards == (1,)
        oracle = np.asarray(
            ref.sdkde_eval(x, y, table.h, block=1024), np.float64)
        actual = np.abs(
            np.asarray(ans.densities, np.float64) - oracle) / oracle
        bounds = np.asarray(ans.rel_err_bounds, np.float64)
        served += 1
        # per-query domination: the certificate must hold pointwise
        # (small f32 slack on the answer itself)
        violations += int((actual > bounds + 1e-5).sum())
        worst_bound = max(worst_bound, float(bounds.max()))
        worst_actual = max(worst_actual, float(actual.max()))
    out = {
        "served": served,
        "missing_shard_points": table.shard_n[1],
        "rel_err_bound_max": round(worst_bound, 4),
        "rel_err_actual_max": round(worst_actual, 4),
        "bound_violations": violations,
        "accuracy_target": DEGRADED_ACCURACY,
    }
    common.emit("chaos_degraded", n=n, d=d, **out)
    eng.close()
    if violations:
        raise RuntimeError(
            f"{violations} degraded answers exceeded their certified "
            f"relative-error bound — the certificate must dominate"
        )
    if worst_bound > DEGRADED_ACCURACY:
        raise RuntimeError(
            f"certified bound {worst_bound:.3g} exceeds the accuracy "
            f"target {DEGRADED_ACCURACY:g} yet the answer was served"
        )
    return out


def main(n: int = 2048, d: int = 4, requests: int = 48,
         seed: int = 0) -> None:
    run_soak(n=n, d=d, requests=requests, seed=seed)
    run_degraded(n=n, d=d, seed=seed)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(n=args.n, d=args.d, requests=args.requests, seed=args.seed)
