"""Benchmark aggregator: one harness per paper figure/table.

``python -m benchmarks.run`` runs every harness at CPU-scaled sizes and
prints ``name,key=value,...`` CSV.  Individual harnesses accept flags for
the paper's full sizes on real hardware.
"""

from __future__ import annotations

import time

from benchmarks import (
    fig1_runtime,
    fig2_oracle_16d,
    fig3_oracle_1d,
    fig4_fusion,
    fig5_utilization,
    serve_throughput,
    table1_methods,
)


def main() -> None:
    t0 = time.time()
    print("# Flash-SD-KDE benchmark suite (CPU-scaled; see EXPERIMENTS.md)")
    print("# fig1: 16-D runtime, naive vs GEMM vs flash (paper Fig. 1)")
    fig1_runtime.main(ns=(1024, 2048, 4096))
    print("# fig2: 16-D oracle MISE/MIAE (paper Fig. 2)")
    fig2_oracle_16d.main(ns=(512, 1024, 2048), seeds=(0, 1), n_mc=2048)
    print("# fig3: 1-D oracle MISE/MIAE (paper Fig. 3)")
    fig3_oracle_1d.main(ns=(512, 1024, 2048, 4096), seeds=(0, 1))
    print("# fig4: Laplace fusion speedup (paper Fig. 4)")
    fig4_fusion.main(ns=(4096, 8192, 16384))
    print("# fig5: utilization / roofline terms (paper Fig. 5/7)")
    fig5_utilization.main(ns=(1024, 2048, 4096))
    print("# table1: method comparison at fixed size (paper Table 1)")
    table1_methods.main(n=8192)
    print("# serve: query-serving qps / tail latency (repro.serve)")
    serve_throughput.main(
        n=1024, d=8, backends=("jnp", "pallas"),
        batch_sizes=(8, 32), n_requests=8,
    )
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
