"""Benchmark aggregator: one harness per paper figure/table.

``python -m benchmarks.run`` runs every harness at CPU-scaled sizes,
prints ``name,key=value,...`` CSV, and dumps the whole suite as
machine-readable JSON to ``BENCH_flash.json`` (per-cell runtime, config,
precision tier, tuned launch tiles) so the perf trajectory is tracked
across PRs.  Individual harnesses accept flags for the paper's full sizes
on real hardware.

A harness that raises does NOT abort the suite — the remaining harnesses
still run and the JSON artifact is still written (with the failure
recorded in its cells and meta) — but the process exits nonzero, so CI
can never upload a partial BENCH_flash.json as if it were healthy.
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    chaos_soak,
    common,
    fig1_runtime,
    fig2_oracle_16d,
    fig3_oracle_1d,
    fig4_fusion,
    fig5_utilization,
    obs_overhead,
    overload_soak,
    planner_cells,
    precision_sweep,
    pruning_sweep,
    rff_cascade,
    serve_throughput,
    streaming_throughput,
    table1_methods,
)
from repro import obs

BENCH_JSON = "BENCH_flash.json"

#: Harnesses whose run raised, in order (nonzero exit + JSON meta).
FAILURES: list = []


def _run(name: str, desc: str, fn, *args, **kw) -> None:
    print(f"# {name}: {desc}")
    t0 = time.time()
    try:
        fn(*args, **kw)
        ok = True
    except Exception as e:  # noqa: BLE001 - record and keep the suite going
        ok = False
        FAILURES.append(name)
        traceback.print_exc()
        common.emit("harness_error", harness=name,
                    error=f"{type(e).__name__}: {e}")
    common.emit("harness", harness=name, wall_s=round(time.time() - t0, 2),
                ok=ok)


def main() -> None:
    t0 = time.time()
    print("# Flash-SD-KDE benchmark suite (CPU-scaled; see EXPERIMENTS.md)")
    _run("fig1", "16-D runtime, naive vs GEMM vs flash (paper Fig. 1)",
         fig1_runtime.main, ns=(1024, 2048, 4096))
    _run("fig2", "16-D oracle MISE/MIAE (paper Fig. 2)",
         fig2_oracle_16d.main, ns=(512, 1024, 2048), seeds=(0, 1),
         n_mc=2048)
    _run("fig3", "1-D oracle MISE/MIAE (paper Fig. 3)",
         fig3_oracle_1d.main, ns=(512, 1024, 2048, 4096), seeds=(0, 1))
    _run("fig4", "Laplace fusion speedup (paper Fig. 4)",
         fig4_fusion.main, ns=(4096, 8192, 16384))
    _run("fig5", "utilization / roofline terms (paper Fig. 5/7)",
         fig5_utilization.main, ns=(1024, 2048, 4096))
    _run("table1", "method comparison at fixed size (paper Table 1)",
         table1_methods.main, n=8192)
    _run("precision", "f32/bf16/bf16x2 accuracy-vs-runtime + autotuner "
         "acceptance cell (kernels/precision.py, kernels/autotune.py)",
         precision_sweep.main, ns=(1024,))
    _run("serve", "query-serving qps / tail latency (repro.serve)",
         serve_throughput.main,
         n=1024, d=8, backends=("jnp", "pallas"),
         batch_sizes=(8, 32), n_requests=8)
    _run("pruning", "cluster-pruned vs dense: occupancy, certified error, "
         "and the 256k×16d acceptance cell (kernels/spatial.py)",
         pruning_sweep.main, smoke_n=8192, smoke_m=1024, acceptance=True)
    _run("streaming", "incremental append/evict serving: appends/sec, "
         "staleness, and the 256k×16d amortized-vs-refit cell "
         "(repro.stream)",
         streaming_throughput.main, smoke_n=2048, smoke_d=8,
         run_acceptance=True)
    _run("rff_cascade", "RFF fast tier + accuracy cascade: mixed-traffic "
         "hit fraction, certified bands, and the 256k modeled "
         "cascade-vs-exact acceptance cell (kernels/flash_rff.py, "
         "serve/cascade.py)",
         rff_cascade.main, smoke_n=8192, smoke_d=2, run_acceptance=True)
    _run("planner", "execution-planner decisions per committed gated cell: "
         "plan cost vs the default serve path + golden-fixture cross-check "
         "(repro.plan, benchmarks/planner_cells.py)",
         planner_cells.main)
    _run("obs_overhead", "serve p50 with telemetry off vs fully on "
         "(repro.obs; informational, not a speedup cell)",
         obs_overhead.main)
    _run("chaos", "resilient serving soak: injected shard kill + recovery "
         "under sustained traffic, plus certified degraded answers — "
         "HARD-FAILS on any dropped query or a lying error certificate "
         "(serve/resilience.py, fault_injection.py)",
         chaos_soak.main, n=2048, d=4, requests=48)
    _run("overload", "admission frontend soak: open-loop steady -> 4x "
         "burst -> recovery through the continuous batcher — HARD-FAILS "
         "on any silent drop, an uncapped tail, or collapsed goodput "
         "(serve/frontend.py, benchmarks/overload_soak.py)",
         overload_soak.main, n=2048, d=4, phase_s=0.6)
    total = time.time() - t0
    # embed the process-wide metrics snapshot the suite itself produced —
    # cache hit rates, prune occupancies, tuner decisions — so the perf
    # artifact carries its own telemetry alongside the timing cells
    common.write_bench_json(BENCH_JSON, suite="cpu-scaled",
                            total_s=round(total, 1),
                            failed_harnesses=",".join(FAILURES) or None,
                            metrics=obs.metrics_snapshot())
    print(f"# total {total:.1f}s  → {BENCH_JSON}")
    if FAILURES:
        print(f"# FAILED harnesses: {', '.join(FAILURES)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
