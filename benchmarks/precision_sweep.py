"""Precision-tier sweep: accuracy vs runtime for f32 / bf16 / bf16x2.

Two kinds of cells:

  * ``precision`` — the full flash_sdkde pipeline per tier at CPU-scaled
    sizes: wall time, max relative error against the f32 pipeline, and the
    autotuned launch tile the dispatch actually used (on CPU the kernels
    run in interpret mode, so wall times are validation-only; on TPU they
    are the real thing).
  * ``precision_model`` — the acceptance cell: the paper-scale 32k-sample
    16-d problem (n_test = n/8), comparing the *modeled* step time of the
    fixed f32 128×512 launch against the autotuned bf16 configuration
    (kernels/autotune.py).  This is the number the issue gates on; on TPU
    hardware the ``precision`` cells above provide the measured
    counterpart.

    PYTHONPATH=src python -m benchmarks.precision_sweep
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.mixtures import benchmark_mixture_16d
from repro.kernels import autotune, ops

TIERS = ("f32", "bf16", "bf16x2")


def pipeline_cells(ns=(1024, 2048), d: int = 16, seed: int = 0,
                   interpret: bool | None = None):
    """flash_sdkde per tier: wall ms + max rel err vs the f32 pipeline."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(seed)
    h = 0.5
    for n in ns:
        x = mix.sample(jax.random.fold_in(key, n), n)
        y = mix.sample(jax.random.fold_in(key, n + 1), max(n // 8, 1))
        base = None
        for tier in TIERS:
            fn = lambda a, b: ops.flash_sdkde(  # noqa: E731
                a, b, h, precision=tier, interpret=interpret
            )
            t = timeit(fn, x, y)
            dens = np.asarray(fn(x, y))
            if tier == "f32":
                base, err = dens, 0.0
            else:
                err = float(np.max(np.abs(dens - base)
                                   / (np.abs(base) + 1e-30)))
            bm, bn = autotune.resolve_blocks(
                "auto", "auto", rows=y.shape[0], cols=n, d=d,
                precision=tier, measure=False,
            )
            emit("precision", n=n, d=d, tier=tier,
                 wall_ms=round(t * 1e3, 2),
                 max_rel_err_vs_f32=f"{err:.2e}",
                 block_m=bm, block_n=bn,
                 interpret=interpret)


def model_cell(n: int = 32768, d: int = 16):
    """The §6.2 acceptance cell: autotuned bf16 vs the fixed f32 128×512."""
    m = n // 8
    fixed = autotune.modeled_cost(m, n, d, block_m=128, block_n=512,
                                  precision="f32")
    tuned_blocks = autotune.autotune_blocks(m, n, d, precision="bf16",
                                            measure=False)
    tuned = autotune.modeled_cost(m, n, d, block_m=tuned_blocks[0],
                                  block_n=tuned_blocks[1], precision="bf16")
    emit("precision_model", n=n, d=d,
         f32_fixed_us=round(fixed.step_time * 1e6, 2),
         f32_fixed_bound=fixed.bound,
         bf16_auto_us=round(tuned.step_time * 1e6, 2),
         bf16_auto_bound=tuned.bound,
         bf16_block_m=tuned.block_m, bf16_block_n=tuned.block_n,
         modeled_speedup=round(fixed.step_time / tuned.step_time, 2))


def main(ns=(1024, 2048), d: int = 16, seed: int = 0):
    pipeline_cells(ns=ns, d=d, seed=seed)
    model_cell()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    a = ap.parse_args()
    main(ns=tuple(1024 * a.scale * 2**i for i in range(2)))
