"""Fig. 2 reproduction: oracle MISE/MIAE on the 16-D mixture vs n_train.

Estimators: KDE, Flash-SD-KDE, fused Flash-Laplace-KDE, non-fused Laplace
(the fused/non-fused curves must overlap — fusion is an implementation
optimization, not an estimator change).  Signed-density errors + negative
mass logged separately, exactly as the paper does.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.core import kde
from repro.core.bandwidth import silverman_bandwidth
from repro.core.metrics import oracle_errors
from repro.core.mixtures import benchmark_mixture_16d


def main(ns=(512, 1024, 2048, 4096), seeds=(0, 1), n_mc: int = 4096):
    mix = benchmark_mixture_16d()
    for n in ns:
        acc = {m: {"mise": 0.0, "miae": 0.0, "neg": 0.0}
               for m in ("kde", "sdkde", "sdkde_oracle_score", "laplace",
                         "laplace_nonfused")}
        for seed in seeds:
            key = jax.random.fold_in(jax.random.PRNGKey(seed), n)
            x = mix.sample(key, n)
            h = float(silverman_bandwidth(x))
            fns = {
                "kde": lambda g: kde.kde_eval(x, g, h, block=512),
                "sdkde": lambda g: kde.sdkde_eval(x, g, h, block=512),
                # ablation: oracle ∇log p isolates score-estimation error
                "sdkde_oracle_score": lambda g: kde.sdkde_eval_oracle(
                    x, g, h, mix.score, block=512),
                "laplace": lambda g: kde.laplace_kde_eval(x, g, h, block=512),
                "laplace_nonfused": lambda g: kde.laplace_kde_eval_nonfused(
                    x, g, h, block=512),
            }
            for name, fn in fns.items():
                e = oracle_errors(fn, mix, key, n_mc=n_mc)
                acc[name]["mise"] += e.mise / len(seeds)
                acc[name]["miae"] += e.miae / len(seeds)
                acc[name]["neg"] += e.neg_mass / len(seeds)
        for name, v in acc.items():
            emit("fig2", n=n, method=name, mise=f"{v['mise']:.3e}",
                 miae=f"{v['miae']:.3e}", neg_mass=f"{v['neg']:.3e}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    a = ap.parse_args()
    main(ns=tuple(512 * a.scale * 2**i for i in range(4)))
