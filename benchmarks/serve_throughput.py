"""Serve-path throughput: queries/sec and p50/p99 latency per backend.

Exercises the ``repro.serve`` engine the way an online deployment would:
one expensive ``register`` (the SD-KDE debias pass) per backend, then a
stream of fixed-size query requests per batch size, timed individually so
tail latency is visible.  Also cross-checks the served densities against the
pure-jnp reference path (rtol 1e-5 at the default 4k-sample, 8-d problem).

    PYTHONPATH=src python -m benchmarks.serve_throughput
    PYTHONPATH=src python -m benchmarks.serve_throughput --backends jnp pallas ring
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.serve import QueryRequest, ServeConfig, ServeEngine


def main(
    n: int = 4096,
    d: int = 8,
    backends=("jnp", "pallas"),
    batch_sizes=(8, 64, 256),
    n_requests: int = 24,
    method: str = "sdkde",
    seed: int = 0,
    verify: bool = True,
    rtol: float = 1e-5,
    precision: str = "f32",
) -> None:
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = mix.sample(key, n)
    y_all = mix.sample(jax.random.fold_in(key, 1), max(batch_sizes) * 2)
    h = 0.5  # fixed so every backend serves the identical estimator

    for backend in backends:
        cfg = ServeConfig(
            backend=backend, method=method, interpret=True,
            block_m=min(128, max(8, min(batch_sizes))),
            block_n=min(512, n),
            precision=precision,
            min_batch=min(batch_sizes), max_batch=max(batch_sizes),
        )
        eng = ServeEngine(cfg)
        t0 = time.perf_counter()
        prep = eng.register("bench", x, h=h)
        emit("serve_fit", backend=backend, method=method, n=n, d=d,
             precision=precision,
             block_m=prep.block_m, block_n=prep.block_n,
             ms=f"{1e3 * (time.perf_counter() - t0):.1f}")

        if verify:
            yv = y_all[: max(batch_sizes)]
            got = np.asarray(
                eng.query(QueryRequest(key="bench", points=yv)).value)
            ref_fn = {"kde": ref.kde_eval, "sdkde": ref.sdkde_eval,
                      "laplace": ref.laplace_kde_eval}[method]
            want = np.asarray(ref_fn(x, yv, h, block=1024))
            # atol floor: deep-tail densities (≥1e6× below peak) accumulate
            # f32 ordering noise through the flash debias pass.  Reduced
            # precision tiers get their documented tolerance floors
            # (rtol + peak-relative atol, as in tests/test_precision_autotune).
            tier_rtol = max(rtol, {"f32": 0.0, "bf16": 5e-2,
                                   "bf16x2": 5e-4}[precision])
            atol_frac = {"f32": 1e-6, "bf16": 5e-3,
                         "bf16x2": 1e-5}[precision]
            np.testing.assert_allclose(
                got, want, rtol=tier_rtol, atol=atol_frac * float(want.max())
            )
            emit("serve_verify", backend=backend, n=n, d=d,
                 precision=precision, rtol=tier_rtol, status="ok")

        rng = np.random.default_rng(seed)
        for b in batch_sizes:
            for _ in range(2):  # warm the shape bucket (compile outside timing)
                eng.query(QueryRequest(key="bench", points=y_all[:b]))
            eng.latency.reset()
            for _ in range(n_requests):
                off = int(rng.integers(0, y_all.shape[0] - b + 1))
                eng.query(QueryRequest(key="bench",
                                       points=y_all[off:off + b]))
            s = eng.latency.summary()
            emit("serve", backend=backend, method=method, n=n, d=d, batch=b,
                 precision=precision,
                 qps=f"{s.qps:.1f}", p50_ms=f"{s.p50_ms:.2f}",
                 p99_ms=f"{s.p99_ms:.2f}")
        emit("serve_cache", backend=backend, hits=eng.cache.hits,
             misses=eng.cache.misses, evictions=eng.cache.evictions)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--backends", nargs="+", default=["jnp", "pallas"])
    ap.add_argument("--batch-sizes", nargs="+", type=int,
                    default=[8, 64, 256])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--method", default="sdkde",
                    choices=["kde", "sdkde", "laplace"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "bf16x2"])
    args = ap.parse_args()
    main(n=args.n, d=args.d, backends=tuple(args.backends),
         batch_sizes=tuple(args.batch_sizes), n_requests=args.requests,
         method=args.method, seed=args.seed, verify=not args.no_verify,
         precision=args.precision)
