"""Streaming SD-KDE: append throughput, staleness, amortized cost vs refit.

Two modes, mirroring ``pruning_sweep``:

  * **smoke** (CI): a small streaming estimator served through the real
    engine — sliding-window updates interleaved with query traffic, with
    appends/sec, served-staleness percentiles, and an allclose cross-check
    of the post-update densities against a from-scratch jnp refit.
  * **acceptance**: the paper-scale 256k×16-d cell.  The amortized cost of
    one append update is *measured* (the O(n·b·d) delta score pass at full
    scale + the layout/column maintenance flush of a real 256k stream);
    the full-refit cost it replaces is *modeled* — the O(n²·d) score pass
    measured at a feasible size and scaled by (n/n₀)², plus the measured
    re-prepare — because actually running a 256k² score pass on the CI CPU
    is exactly what streaming exists to avoid.  The gate: amortized
    per-append-batch cost ≥ 10× below the full refit.

    PYTHONPATH=src python -m benchmarks.streaming_throughput
    PYTHONPATH=src python -m benchmarks.streaming_throughput --acceptance
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.serve import QueryRequest, ServeConfig, ServeEngine
from repro.stream import StreamConfig, StreamingSDKDE, delta


def smoke(
    n: int = 2048,
    d: int = 8,
    batch: int = 64,
    updates: int = 6,
    staleness_budget: int = 2,
    seed: int = 0,
    verify: bool = True,
) -> None:
    """Serve-level streaming smoke: real engine, real updates, verified."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = np.asarray(mix.sample(key, n), np.float32)
    y = np.asarray(mix.sample(jax.random.fold_in(key, 1), 256), np.float32)
    h = 0.5

    cfg = ServeConfig(
        backend="pallas", method="sdkde", interpret=True,
        block_m=8, block_n=min(512, n), min_batch=64, max_batch=256,
        stream=True, staleness_budget=staleness_budget,
    )
    eng = ServeEngine(cfg)
    t0 = time.perf_counter()
    eng.register("stream", x, h=h)
    fit_s = time.perf_counter() - t0
    eng.query(QueryRequest(key="stream", points=y))   # warm the bucket

    append_s, appended = 0.0, 0
    for i in range(updates):
        fresh = np.asarray(
            mix.sample(jax.random.fold_in(key, 100 + i), batch), np.float32
        )
        t0 = time.perf_counter()
        eng.registry.slide("stream", fresh)     # append batch + evict oldest
        append_s += time.perf_counter() - t0
        appended += batch
        eng.query(QueryRequest(key="stream", points=y))
    st = eng.registry.get("stream").stream
    stale = eng.staleness_summary()
    emit("streaming_smoke", n=n, d=d, batch=batch, updates=updates,
         fit_s=round(fit_s, 3),
         appends_per_s=round(appended / append_s, 1),
         amortized_append_ms=round(1e3 * append_s / appended, 3),
         staleness_p50=stale.get("p50", 0), staleness_p99=stale.get("p99", 0),
         staleness_budget=staleness_budget, rebuilds=st.rebuilds)

    if verify:
        # flush before comparing: the engine may legally serve up to
        # staleness_budget generations behind the live reference set
        st.ensure(0)
        got = np.asarray(
            eng.query(QueryRequest(key="stream", points=y)).value)
        want = np.asarray(ref.sdkde_eval(st.x, y, h, block=1024))
        np.testing.assert_allclose(got, want, rtol=1e-5,
                                   atol=1e-6 * float(want.max()))
        emit("streaming_verify", n=n, d=d, live=st.n_live,
             rel_err=f"{float(np.abs(got - want).max() / want.max()):.2e}",
             status="ok")


def acceptance(
    n: int = 262144,
    d: int = 16,
    batch: int = 256,
    refit_n: int = 8192,
    target_ratio: float = 10.0,
    seed: int = 0,
) -> None:
    """The 256k×16-d amortized-append-vs-refit cell (CI gate ≥ 10×)."""
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = np.asarray(mix.sample(key, n), np.float32)
    fresh = np.asarray(mix.sample(jax.random.fold_in(key, 1), batch),
                       np.float32)
    h = 0.2

    # measured: the O(n·b·d) delta score pass at FULL scale (the sdkde
    # streaming append's dominant cost) — warm the jit on a small slice
    delta.append_delta(x[:4096], fresh, h)
    t0 = time.perf_counter()
    delta.append_delta(x, fresh, h)
    delta_s = time.perf_counter() - t0

    # measured: layout/column maintenance at full scale via a real 256k
    # stream (kde mode: same layout machinery, no O(n²) constructor)
    t0 = time.perf_counter()
    stream = StreamingSDKDE(x, h, method="kde", backend="pallas",
                            block_n=512, config=StreamConfig(slack=0.25))
    prep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stream.append(fresh)
    stream.flush()
    flush_s = time.perf_counter() - t0

    # modeled: the full refit this append replaces = the O(n²·d) score
    # pass (measured at refit_n, scaled quadratically) + the measured
    # re-cluster/re-prepare at full scale
    x0 = x[:refit_n]
    delta.initial_stats(x0[:2048], h)           # warm
    t0 = time.perf_counter()
    delta.initial_stats(x0, h)
    score_small_s = time.perf_counter() - t0
    refit_s = score_small_s * (n / refit_n) ** 2 + prep_s

    append_batch_s = delta_s + flush_s
    ratio = refit_s / append_batch_s
    emit("streaming_acceptance", n=n, d=d, batch=batch,
         delta_pass_ms=round(1e3 * delta_s, 1),
         flush_ms=round(1e3 * flush_s, 1),
         amortized_append_ms=round(1e3 * append_batch_s / batch, 3),
         refit_model_ms=round(1e3 * refit_s, 1),
         refit_measured_at=refit_n,
         prep_measured_ms=round(1e3 * prep_s, 1),
         modeled_speedup=round(ratio, 1),
         target_speedup=target_ratio,
         meets_target=bool(ratio >= target_ratio))
    if ratio < target_ratio:
        raise RuntimeError(
            f"streaming amortized append only {ratio:.1f}x below the "
            f"modeled full refit (target {target_ratio}x)"
        )


def main(
    smoke_n: int = 2048,
    smoke_d: int = 8,
    run_acceptance: bool = False,
    seed: int = 0,
) -> None:
    smoke(n=smoke_n, d=smoke_d, seed=seed)
    if run_acceptance:
        acceptance(seed=seed)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--acceptance", action="store_true",
                    help="run the 256k×16-d amortized-vs-refit cell")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke_n=args.n, smoke_d=args.d, run_acceptance=args.acceptance,
         seed=args.seed)
