"""Benchmark regression gate: modeled-speedup cells vs committed baseline.

Compares every ``modeled_speedup`` cell of a fresh ``BENCH_flash.json``
against the committed ``benchmarks/BENCH_baseline.json`` and exits nonzero
when any cell regressed more than the tolerance (default 15%) — CI runs
this right after the benchmark suite, so a PR that silently halves a
modeled speedup fails instead of uploading a healthy-looking artifact.

Only *modeled* speedups are gated: they are deterministic functions of the
cost model, tuned tiles and (seeded) measured occupancies, so a 15% drop
is a code change, not machine noise.  Wall-clock cells (qps, p99, raw ms)
are tracked in the artifact but not gated.  One deliberate exception: the
baseline pins ``streaming_acceptance`` at its *target* ratio rather than a
measured value, because that cell's ratio is wall-clock-derived and varies
across runners — the gate then enforces "still comfortably past target"
instead of "within 15% of one machine's timing".

A suite that recorded failed harnesses (``meta.failed_harnesses``) fails
the gate outright, partial artifact or not.

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --tolerance 0.25
"""

from __future__ import annotations

import argparse
import json
import sys

GATE_FIELD = "modeled_speedup"
#: Fields that identify a cell across runs (whichever are present).
ID_FIELDS = ("n", "m", "d", "h", "epsilon", "batch", "precision", "backend")


def cell_key(cell: dict) -> tuple:
    return (cell.get("cell"),) + tuple(
        (k, cell[k]) for k in ID_FIELDS if k in cell
    )


def check(current: dict, baseline: dict, tolerance: float):
    """Returns (rows, failures): one row per gated baseline cell.

    Only ``modeled_speedup`` cells participate.  Anything else in either
    document — telemetry cells (``obs_overhead``), the embedded
    ``metrics`` snapshot, malformed/non-dict cells from a future schema —
    is ignored rather than an error, so adding observability data to the
    artifact can never break the gate.
    """
    cur_cells = {cell_key(c): c for c in current.get("cells", ())
                 if isinstance(c, dict) and GATE_FIELD in c}
    rows, failures = [], []
    for b in baseline.get("cells", ()):
        if not isinstance(b, dict) or GATE_FIELD not in b:
            continue
        key = cell_key(b)
        floor = float(b[GATE_FIELD]) * (1.0 - tolerance)
        c = cur_cells.get(key)
        if c is None:
            failures.append(f"gated cell missing from current run: {key}")
            rows.append((key, float(b[GATE_FIELD]), None, False))
            continue
        got = float(c[GATE_FIELD])
        ok = got >= floor
        rows.append((key, float(b[GATE_FIELD]), got, ok))
        if not ok:
            failures.append(
                f"{key}: {GATE_FIELD} {got:.2f} < floor {floor:.2f} "
                f"(baseline {float(b[GATE_FIELD]):.2f}, "
                f"tolerance {tolerance:.0%})"
            )
    failed = (current.get("meta") or {}).get("failed_harnesses")
    if failed:
        failures.append(f"current run recorded failed harnesses: {failed}")
    return rows, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_flash.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.15)
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, failures = check(current, baseline, args.tolerance)
    for key, base, got, ok in rows:
        name = key[0] + " " + " ".join(f"{k}={v}" for k, v in key[1:])
        got_s = "MISSING" if got is None else f"{got:.2f}"
        print(f"{'ok  ' if ok else 'FAIL'} {name}: baseline {base:.2f} "
              f"current {got_s}")
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} gated cells within {args.tolerance:.0%} "
          "of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
