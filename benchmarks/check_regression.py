"""Benchmark regression gate: modeled-speedup cells vs committed baseline.

Compares every ``modeled_speedup`` cell of a fresh ``BENCH_flash.json``
against the committed ``benchmarks/BENCH_baseline.json`` and exits nonzero
when any cell regressed more than the tolerance (default 15%) — CI runs
this right after the benchmark suite, so a PR that silently halves a
modeled speedup fails instead of uploading a healthy-looking artifact.

Only *modeled* speedups are gated: they are deterministic functions of the
cost model, tuned tiles and (seeded) measured occupancies, so a 15% drop
is a code change, not machine noise.  Wall-clock cells (qps, p99, raw ms)
are tracked in the artifact but not gated.  One deliberate exception: the
baseline pins ``streaming_acceptance`` at its *target* ratio rather than a
measured value, because that cell's ratio is wall-clock-derived and varies
across runners — the gate then enforces "still comfortably past target"
instead of "within 15% of one machine's timing".

``planner`` cells (benchmarks/planner_cells.py) get a second check on top
of the speedup floor: every decision field of the emitted plan is compared
against the pinned golden fixture (``tests/golden_plans.json``) by
``request_key``, and ANY drift fails the gate unless the run passes the
deliberate ``--regen-golden`` marker — the same contract as the conformance
suite in tests/test_planner.py, enforced again at artifact time so a CI
run can never upload a plan that silently diverged from review.

A suite that recorded failed harnesses (``meta.failed_harnesses``) fails
the gate outright, partial artifact or not.

    PYTHONPATH=src python -m benchmarks.check_regression
    PYTHONPATH=src python -m benchmarks.check_regression --tolerance 0.25
"""

from __future__ import annotations

import argparse
import json
import sys

GATE_FIELD = "modeled_speedup"
#: Fields that identify a cell across runs (whichever are present).
ID_FIELDS = ("n", "m", "d", "h", "epsilon", "batch", "precision", "backend",
             "q", "accuracy", "request_key")

#: Plan decision fields cross-checked against the golden fixture.
PLAN_FIELDS = ("backend", "precision", "prune", "block_m", "block_n")


def cell_key(cell: dict) -> tuple:
    # A request_key fully identifies a planner cell; the other ID fields a
    # planner cell carries (backend, precision, ...) are decision OUTPUTS,
    # and folding those into the identity would turn plan drift into a
    # "missing cell" failure that --regen-golden could not mark deliberate.
    if "request_key" in cell:
        return (cell.get("cell"), ("request_key", cell["request_key"]))
    return (cell.get("cell"),) + tuple(
        (k, cell[k]) for k in ID_FIELDS if k in cell
    )


def check(current: dict, baseline: dict, tolerance: float):
    """Returns (rows, failures): one row per gated baseline cell.

    Only ``modeled_speedup`` cells participate.  Anything else in either
    document — telemetry cells (``obs_overhead``), the embedded
    ``metrics`` snapshot, malformed/non-dict cells from a future schema —
    is ignored rather than an error, so adding observability data to the
    artifact can never break the gate.
    """
    cur_cells = {cell_key(c): c for c in current.get("cells", ())
                 if isinstance(c, dict) and GATE_FIELD in c}
    rows, failures = [], []
    for b in baseline.get("cells", ()):
        if not isinstance(b, dict) or GATE_FIELD not in b:
            continue
        key = cell_key(b)
        floor = float(b[GATE_FIELD]) * (1.0 - tolerance)
        c = cur_cells.get(key)
        if c is None:
            failures.append(f"gated cell missing from current run: {key}")
            rows.append((key, float(b[GATE_FIELD]), None, False))
            continue
        got = float(c[GATE_FIELD])
        ok = got >= floor
        rows.append((key, float(b[GATE_FIELD]), got, ok))
        if not ok:
            failures.append(
                f"{key}: {GATE_FIELD} {got:.2f} < floor {floor:.2f} "
                f"(baseline {float(b[GATE_FIELD]):.2f}, "
                f"tolerance {tolerance:.0%})"
            )
    failed = (current.get("meta") or {}).get("failed_harnesses")
    if failed:
        failures.append(f"current run recorded failed harnesses: {failed}")
    return rows, failures


def check_plan_drift(current: dict, golden: dict,
                     regen_marker: bool = False):
    """Failures for ``planner`` cells whose decision left the golden pin.

    Every planner cell in the current artifact is matched to the fixture
    entry with the same ``request_key`` and compared field-by-field over
    :data:`PLAN_FIELDS` plus ``plan_id``.  A cell whose request has no
    fixture entry is itself a failure — new requests must be pinned via
    the regen CLI before they can pass the gate.  ``regen_marker=True``
    (the ``--regen-golden`` flag) downgrades every drift to an announced,
    deliberate rewrite: nothing fails, but each mismatch is still listed
    on stdout so the diff is reviewable.
    """
    plans = (golden or {}).get("plans", {})
    failures, notes = [], []
    for c in current.get("cells", ()):
        if not isinstance(c, dict) or c.get("cell") != "planner":
            continue
        key = c.get("request_key")
        pinned = (plans.get(key) or {}).get("plan")
        if pinned is None:
            (notes if regen_marker else failures).append(
                f"planner cell has no golden entry: {key!r} — pin it with "
                f"`python -m repro.plan --regen-golden`")
            continue
        drift = []
        for f in PLAN_FIELDS:
            if c.get(f) != pinned.get(f):
                drift.append(f"{f}: golden {pinned.get(f)!r} "
                             f"current {c.get(f)!r}")
        if c.get("plan_id") != _plan_id_of(pinned):
            drift.append(f"plan_id: golden {_plan_id_of(pinned)!r} "
                         f"current {c.get('plan_id')!r}")
        if drift:
            msg = (f"plan drift vs golden for {key!r}: "
                   + "; ".join(drift)
                   + " — rerun `python -m repro.plan --regen-golden` and "
                     "commit the fixture if this change is intended")
            (notes if regen_marker else failures).append(msg)
    return failures, notes


def _plan_id_of(pinned: dict) -> str:
    """The plan_id a golden ``plan`` record implies (mirrors
    ExecutionPlan.plan_id without importing repro)."""
    pr = pinned.get("prune")
    pr = pr if isinstance(pr, str) else f"{pr:g}"
    blocks = ("-" if pinned.get("block_m") is None
              else f"{pinned.get('block_m')}x{pinned.get('block_n')}")
    base = (f"{pinned.get('backend')}/{pinned.get('precision')}"
            f"/prune={pr}/{blocks}")
    return f"rff+{base}" if pinned.get("rff") else base


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_flash.json")
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.15)
    ap.add_argument("--golden", default="tests/golden_plans.json",
                    help="pinned planner-decision fixture; planner cells "
                         "are cross-checked against it ('' disables)")
    ap.add_argument("--regen-golden", action="store_true",
                    help="deliberate-rewrite marker: report plan-vs-golden "
                         "drift without failing the gate (pair with "
                         "`python -m repro.plan --regen-golden`)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    golden = {}
    if args.golden:
        try:
            with open(args.golden) as f:
                golden = json.load(f)
        except FileNotFoundError:
            golden = {}

    rows, failures = check(current, baseline, args.tolerance)
    if args.golden:   # missing fixture file still fails: plans must be pinned
        drift, notes = check_plan_drift(current, golden,
                                        regen_marker=args.regen_golden)
        failures.extend(drift)
        for msg in notes:
            print(f"note (--regen-golden): {msg}")
    for key, base, got, ok in rows:
        name = key[0] + " " + " ".join(f"{k}={v}" for k, v in key[1:])
        got_s = "MISSING" if got is None else f"{got:.2f}"
        print(f"{'ok  ' if ok else 'FAIL'} {name}: baseline {base:.2f} "
              f"current {got_s}")
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} gated cells within {args.tolerance:.0%} "
          "of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
