"""Fig. 5 / Fig. 7 reproduction: utilization from the paper's flop model.

Two parts:
  1. CPU-measured:   utilization = FLOPs_model(k) / (runtime × peak).  The
     peak is a rough single-socket CPU estimate — the point is the TREND
     (utilization rising with n, the compute-bound signature), matching the
     paper's Fig. 5 shape.
  2. TPU dry-run:    the three roofline terms for the flash_sdkde_* cells
     from results/dryrun_single.json (if present) — the v5e equivalent of
     the paper's utilization bars, derived from the compiled program.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.common import emit, timeit
from repro.analysis.flops import sdkde_flops, sdkde_flops_1d
from repro.core import kde
from repro.core.mixtures import benchmark_mixture_16d

CPU_PEAK_FLOPS = 100e9   # rough: a few cores × AVX2 f32 — trend, not truth


def main(ns=(1024, 2048, 4096, 8192)):
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(0)
    h = 0.5
    for n in ns:
        x = mix.sample(jax.random.fold_in(key, n), n)
        y = mix.sample(jax.random.fold_in(key, n + 1), n // 8)
        t = timeit(jax.jit(
            lambda a, b: kde.kde_eval(kde.sdkde_shift(a, h, block=2048),
                                      b, h, block=2048)), x, y)
        model_flops = sdkde_flops(n, 16, n_test=n // 8)
        emit("fig5_cpu", n=n, runtime_ms=round(t * 1e3, 2),
             model_flops=f"{model_flops:.3e}",
             util_pct=round(100 * model_flops / (t * CPU_PEAK_FLOPS), 2))

    for path in ("results/dryrun_single.json", "results/dryrun_multi.json"):
        if not os.path.exists(path):
            continue
        for rec in json.load(open(path)):
            if rec.get("status") == "ok" and "sdkde" in rec["arch"]:
                emit("fig5_tpu", arch=rec["arch"], mesh=rec["mesh"],
                     t_comp_ms=round(rec["t_compute_s"] * 1e3, 2),
                     t_mem_ms=round(rec["t_memory_s"] * 1e3, 2),
                     t_coll_ms=round(rec["t_collective_s"] * 1e3, 2),
                     bound=rec["bound"],
                     mfu_pct=round(100 * rec["mfu"], 1))


if __name__ == "__main__":
    argparse.ArgumentParser().parse_args()
    main()
