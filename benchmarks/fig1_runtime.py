"""Fig. 1 reproduction: 16-D KDE / SD-KDE runtime across n_train.

Three implementations, mirroring the paper's three bars per n:
  * naive      — O(n·m·d) elementwise pairwise distances (the sklearn-KDE
                 analogue: no GEMM re-ordering),
  * gemm       — streaming GEMM form in pure XLA (the "SD-KDE (Torch)"
                 analogue: the re-ordering without kernel-level fusion),
  * flash      — the full Flash-SD-KDE pipeline (GEMM re-ordering + fused
                 score/shift/eval path; on TPU this is the Pallas kernel —
                 on this CPU container it runs the same fused XLA program).

n_test = n_train/8 as in the paper.  CPU-scaled n by default (--scale).
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, timeit
from repro.core import kde
from repro.core.mixtures import benchmark_mixture_16d


def naive_sdkde(x, y, h):
    import jax.numpy as jnp

    diff = x[:, None, :] - x[None, :, :]
    sq = jnp.sum(diff * diff, -1)
    phi = jnp.exp(-sq / (2 * h * h))
    s0 = phi.sum(1)
    s1 = jnp.einsum("ij,jd->id", phi, x)
    score = (s1 - x * s0[:, None]) / (h * h * s0[:, None])
    x_sd = x + 0.5 * h * h * score
    return kde.kde_eval_naive(x_sd, y, h)


def main(ns=(1024, 2048, 4096), d: int = 16, seed: int = 0):
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(seed)
    h = 0.5
    for n in ns:
        x = mix.sample(jax.random.fold_in(key, n), n)
        y = mix.sample(jax.random.fold_in(key, n + 1), max(n // 8, 1))

        t_naive = timeit(jax.jit(lambda a, b: naive_sdkde(a, b, h)), x, y) \
            if n <= 4096 else float("nan")
        t_gemm = timeit(
            jax.jit(lambda a, b: kde.sdkde_eval(a, b, h, block=1024)), x, y
        )
        t_flash = timeit(
            jax.jit(lambda a, b: kde.kde_eval(
                kde.sdkde_shift(a, h, block=1024), b, h, block=1024)), x, y
        )
        emit("fig1", n=n, d=d,
             naive_ms=round(t_naive * 1e3, 2),
             gemm_ms=round(t_gemm * 1e3, 2),
             flash_ms=round(t_flash * 1e3, 2),
             speedup_naive_over_flash=round(t_naive / t_flash, 1)
             if t_naive == t_naive else "nan")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=1)
    a = ap.parse_args()
    main(ns=tuple(1024 * a.scale * 2**i for i in range(3)))
