"""Render EXPERIMENTS.md §Roofline tables from results/dryrun_*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir results]
"""

from __future__ import annotations

import argparse
import json
import os


def _fmt_ms(s):
    return f"{s*1e3:,.1f}"


def render(path: str) -> str:
    recs = json.load(open(path))
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound "
        "| model/HLO | MFU@roofline | GB/dev | fits 16G? |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skip "
                f"| — | — | — | ({r['reason'][:40]}…) |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL |||||||| ")
            continue
        gb = r["bytes_per_device"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_ms(r['t_compute_s'])} "
            f"| {_fmt_ms(r['t_memory_s'])} | {_fmt_ms(r['t_collective_s'])} "
            f"| {r['bound']} | {r['useful_ratio']:.2f} "
            f"| {r['mfu']*100:.1f}% | {gb:.1f} "
            f"| {'yes' if gb <= 16 else 'NO'} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results")
    args = ap.parse_args()
    for mesh in ("single", "multi"):
        p = os.path.join(args.dir, f"dryrun_{mesh}.json")
        if os.path.exists(p):
            print(f"\n### {mesh} mesh\n")
            print(render(p))


if __name__ == "__main__":
    main()
