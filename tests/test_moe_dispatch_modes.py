"""All three MoE dispatch modes agree with the dense reference.

  * train shard-local (tokens stay in their data shards, local capacity)
  * decode weights-stationary (tokens replicated, weights never move)
  * dense fallback (no mesh — smoke-test path)

Covers EP (experts over model), TPE (d_ff over model, expert count
indivisible) and the 2-D kimi layout (EP + d_ff over data, FSDP gather in
train / pure-partial in decode), with shared experts.
"""

import os
import subprocess
import sys

import pytest

_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.compat import make_auto_mesh
mesh = make_auto_mesh((2, 2, 2), ('pod', 'data', 'model'))
from repro.models.common import ModelConfig, init_params
from repro.models import moe

for ename, (E, e2d) in {'tpe': (5, False), 'ep': (8, False),
                        'ep2d': (8, True)}.items():
    cfg = ModelConfig(name='m', family='moe', n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, n_experts=E, top_k=2, moe_dff=32,
                      n_shared_experts=1, capacity_factor=8.0,
                      expert_2d_sharding=e2d, dtype=jnp.float32,
                      remat='none', loss_chunk=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    lp = {k.split('/', 1)[1]: v[0] for k, v in params.items()
          if k.startswith('layers/')}
    lp = {k: v for k, v in lp.items() if k in moe._MOE_WEIGHTS}

    # decode-scale: stationary path
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    moe.set_moe_mesh(None)
    ref, _ = moe._moe_ffn_body(x, lp, cfg)
    moe.set_moe_mesh(mesh)
    st, _ = jax.jit(lambda a, w: moe.moe_ffn(a, w, cfg))(x, lp)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ref),
                               rtol=3e-4, atol=2e-5)

    # train-scale: shard-local path (per-shard capacity == dense at equal
    # per-shard token count; no drops at cf=8)
    xt = jax.device_put(
        jax.random.normal(jax.random.PRNGKey(2), (4096, 64)),
        NamedSharding(mesh, P(('pod', 'data'), None)))
    moe.set_moe_mesh(None)
    ref2, _ = moe._moe_ffn_body(np.asarray(xt)[:1024], lp, cfg)
    moe.set_moe_mesh(mesh)
    sh, _ = jax.jit(lambda a, w: moe.moe_ffn(a, w, cfg))(xt, lp)
    np.testing.assert_allclose(np.asarray(sh)[:1024], np.asarray(ref2),
                               rtol=3e-4, atol=2e-5)

    # gradients flow through both sharded paths
    jax.jit(jax.grad(lambda w, a: moe.moe_ffn(a, w, cfg)[0].sum()))(lp, xt)
    print(f'{ename} OK')
moe.set_moe_mesh(None)
print('ALL_OK')
"""


@pytest.mark.slow
def test_all_dispatch_modes_agree():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], env=env, capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=1800,
    )
    assert "ALL_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-2500:]
