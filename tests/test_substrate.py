"""Substrate tests: optimizers, schedules, checkpointing, data pipeline,
density weighting, estimator API."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.core.estimator import KDE, SDKDE, LaplaceKDE, EstimatorConfig
from repro.data.density import DensityWeighting, density_weights
from repro.data.synthetic import PrefetchLoader, lm_batch
from repro.models.common import ModelConfig, init_params, param_shapes
from repro.models.transformer import loss_fn
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    opt_state_pspecs,
)
from repro.optim.adafactor import (
    adafactor_init,
    adafactor_state_pspecs,
    adafactor_update,
)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, dtype=jnp.float32, remat="none", loss_chunk=0)


# -- optimizers ----------------------------------------------------------------


def _run_steps(opt_init, opt_update, n=8):
    params = init_params(CFG, jax.random.PRNGKey(0))
    batch = lm_batch(CFG, 0, 0, 4, 16)
    state = opt_init(params)
    losses = []
    for step in range(n):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, CFG)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, state = opt_update(grads, state, params, 1e-2)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _run_steps(adamw_init, adamw_update)
    assert losses[-1] < losses[0] - 0.5, losses


def test_adafactor_converges():
    losses = _run_steps(adafactor_init, adafactor_update)
    assert losses[-1] < losses[0] - 0.5, losses


def test_adamw_bf16_params_keep_f32_master():
    import dataclasses

    cfg16 = dataclasses.replace(CFG, param_dtype=jnp.bfloat16)
    params = init_params(cfg16, jax.random.PRNGKey(0))
    state = adamw_init(params)
    assert state["master"]["embed"].dtype == jnp.float32
    batch = lm_batch(cfg16, 0, 0, 2, 8)
    _, grads = jax.value_and_grad(loss_fn)(params, batch, cfg16)
    new_params, state = adamw_update(grads, state, params, 1e-3)
    assert new_params["embed"].dtype == jnp.bfloat16


def test_zero1_pspecs_extend_over_data():
    from jax.sharding import PartitionSpec as P

    specs = opt_state_pspecs(param_shapes(CFG), 4)
    # embed is P('model', None) -> master gains 'data' on the free dim
    assert specs["master"]["embed"] == P("model", "data")
    # tuple axis (multi-pod)
    specs = opt_state_pspecs(param_shapes(CFG), 8, axis=("pod", "data"))
    assert specs["master"]["embed"] == P("model", ("pod", "data"))


def test_adafactor_pspecs_structure():
    specs = adafactor_state_pspecs(param_shapes(CFG), 4)
    assert "vr" in specs["v"]["embed"]
    assert "v" in specs["v"]["final_norm"]


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), 1e-3, 10, 100))
           for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-9
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-6  # final_frac * peak


# -- checkpoint ------------------------------------------------------------------


def test_checkpoint_roundtrip_and_rotation():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"p": {"w": jnp.arange(12.0).reshape(3, 4)},
                "step": jnp.int32(7)}
        for s in (10, 20, 30):
            mgr.save(s, tree, blocking=True)
        assert mgr.committed_steps() == [20, 30]
        out = mgr.restore()
        np.testing.assert_array_equal(out["p"]["w"], tree["p"]["w"])
        assert int(out["step"]) == 7


def test_checkpoint_ignores_torn_writes():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": jnp.ones(3)}, blocking=True)
        # torn: directory without _COMMITTED marker
        os.makedirs(os.path.join(d, "step_000000002"))
        assert mgr.latest_step() == 1


def test_checkpoint_restore_with_sharding():
    from repro.distributed.compat import make_auto_mesh

    mesh = make_auto_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    with tempfile.TemporaryDirectory() as d:
        save_pytree({"w": jnp.ones((4, 4))}, d)
        out = restore_pytree(
            d, {"w": NamedSharding(mesh, P("data", None))}
        )
        assert out["w"].sharding.spec == P("data", None)


# -- data ------------------------------------------------------------------------


def test_batches_deterministic_and_step_dependent():
    b1 = lm_batch(CFG, 3, 7, 4, 16)
    b2 = lm_batch(CFG, 3, 7, 4, 16)
    b3 = lm_batch(CFG, 3, 8, 4, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < CFG.vocab_size


def test_zipf_tokens_skewed():
    toks = np.asarray(lm_batch(CFG, 0, 0, 64, 64)["tokens"]).ravel()
    # Zipf: low ids much more frequent than high ids
    low = (toks < 16).mean()
    high = (toks >= 128).mean()
    assert low > 5 * high, (low, high)


def test_prefetch_loader_orders_steps():
    loader = PrefetchLoader(lambda s: s * 10, start_step=3, depth=2)
    steps = [next(loader) for _ in range(4)]
    loader.close()
    assert steps == [(3, 30), (4, 40), (5, 50), (6, 60)]


def test_modality_batches():
    import dataclasses

    vlm = dataclasses.replace(CFG, family="vlm", n_patches=8)
    b = lm_batch(vlm, 0, 0, 2, 16)
    assert b["patches"].shape == (2, 8, 64)
    audio = dataclasses.replace(CFG, family="audio", n_enc_layers=2,
                                enc_frames=12)
    b = lm_batch(audio, 0, 0, 2, 16)
    assert b["frames"].shape == (2, 12, 64)


# -- density weighting (the paper's technique as a data feature) -----------------


def test_density_weights_upweight_tails():
    key = jax.random.PRNGKey(0)
    dense = jax.random.normal(key, (400, 4)) * 0.1        # tight cluster
    sparse = jax.random.normal(jax.random.fold_in(key, 1), (40, 4)) * 3 + 5
    emb = jnp.concatenate([dense, sparse])
    w = density_weights(emb, alpha=0.5)
    assert float(w[400:].mean()) > 2.0 * float(w[:400].mean())
    assert abs(float(w.mean()) - 1.0) < 1e-3


def test_density_weighting_pipeline_stage():
    key = jax.random.PRNGKey(1)
    corpus = jax.random.normal(key, (500, 8))
    stage = DensityWeighting(alpha=0.5).fit(corpus)
    batch = jax.random.normal(jax.random.fold_in(key, 2), (64, 8))
    w = stage(batch)
    assert w.shape == (64,) and np.isfinite(np.asarray(w)).all()
    idx = stage.resample_indices(batch, jax.random.PRNGKey(3), 16)
    assert idx.shape == (16,) and len(set(np.asarray(idx).tolist())) == 16


# -- estimator API -----------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_estimator_backends_agree(backend):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (200, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (50, 8))
    cfg = EstimatorConfig(backend=backend, block_m=32, block_n=64,
                          interpret=True)
    ref_cfg = EstimatorConfig(backend="jnp")
    for cls in (KDE, SDKDE, LaplaceKDE):
        a = cls(0.5, cfg).fit(x).evaluate(y)
        b = cls(0.5, ref_cfg).fit(x).evaluate(y)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4)


def test_estimator_auto_bandwidth():
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 4))
    est = SDKDE().fit(x)
    assert est.h is not None and float(est.h) > 0
