"""Cell-builder compile tests: every family × shape kind on a mini mesh.

The full production meshes are exercised by launch/dryrun.py; this locks
the same code paths into the test suite at 8 forced host devices with
reduced configs (subprocess, so the main process keeps one device).
"""

import os
import subprocess
import sys

import pytest

_CHILD = r"""
import jax, jax.numpy as jnp, dataclasses
from repro.distributed.compat import make_auto_mesh
mesh = make_auto_mesh((2, 2, 2), ('pod', 'data', 'model'))
from repro.configs import get_arch, ShapeCfg
from repro.launch.steps import build_cell

ARCHS = ['gemma2_2b', 'kimi_k2_1t_a32b', 'granite_moe_3b_a800m',
         'falcon_mamba_7b', 'hymba_1p5b', 'llava_next_34b',
         'whisper_large_v3']
SHAPES = [ShapeCfg('train', 'train', 128, 16, microbatches=2),
          ShapeCfg('prefill', 'prefill', 256, 8),
          ShapeCfg('decode', 'decode', 256, 8),
          ShapeCfg('long', 'decode', 1024, 1)]
for arch_id in ARCHS:
    arch = get_arch(arch_id)
    small = arch.model.reduced(dtype=jnp.bfloat16, remat='full',
                               loss_chunk=64)
    arch = dataclasses.replace(arch, model=small, train_microbatches=None)
    for shape in SHAPES:
        fn, abstract, donate = build_cell(arch, shape, mesh)
        jax.jit(fn, donate_argnums=donate).lower(*abstract).compile()
        print(f'{arch_id}/{shape.name} OK')
print('ALL_OK')
"""


@pytest.mark.slow
def test_all_cell_kinds_compile_on_mini_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], env=env, capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=3000,
    )
    assert "ALL_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
