"""Cluster-pruning correctness: exact mode, certificates, integration.

Three layers of guarantees, each tested directly:

  1. **Exact mode** (``epsilon=0``): the pruned kernels are allclose (rtol
     1e-6 — f32 accumulation-order noise only) to the dense kernels for
     KDE, score stats and Laplace, across every precision tier.
  2. **Certificates** (``epsilon>0``): the per-row-tile error bound emitted
     by the bounds prepass dominates the *true* dropped mass, computed in
     float64 against the same padded layouts — including adversarial
     cluster geometries (huge common offsets, duplicated points, lone
     outliers, off-manifold queries).
  3. **Integration**: the prune knob threads through ops wrappers, the
     serving engine, and the occupancy-aware autotuner.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, spatial
from repro.kernels import precision as prec


def _q(eng, key, y, **kw):
    from repro.serve import QueryRequest
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

TIERS = ("f32", "bf16", "bf16x2")


def _clustered(n, d, k=8, spread=8.0, sigma=0.05, seed=0, offset=0.0):
    key = jax.random.PRNGKey(seed)
    kc, kl, kn = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (k, d), minval=0.0, maxval=spread)
    lab = jax.random.randint(kl, (n,), 0, k)
    x = centers[lab] + sigma * jax.random.normal(kn, (n, d))
    return x + offset


# ---------------------------------------------------------------------------
# Spatial building blocks.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["kmeans", "morton"])
def test_cluster_layout_roundtrip(method):
    n, d, block = 500, 4, 64
    x = _clustered(n, d)
    idx = spatial.build_index(x, method=method)
    assert np.asarray(idx.labels).shape == (n,)
    lay = spatial.cluster_layout(jnp.asarray(x, jnp.float32), idx.labels,
                                 block)
    assert lay.points.shape[0] % block == 0
    assert int(jnp.sum(lay.real)) == n
    # scatter/gather roundtrip: every point lands in its slot
    np.testing.assert_array_equal(np.asarray(lay.points[lay.slots]),
                                  np.asarray(x, np.float32))
    # cluster alignment: every tile holds at most one label
    labels = np.full(lay.points.shape[0], -1)
    labels[np.asarray(lay.slots)] = np.asarray(idx.labels)
    for i in range(lay.points.shape[0] // block):
        tl = labels[i * block:(i + 1) * block]
        assert len(set(tl[tl >= 0])) <= 1


def test_tile_metadata_masks_sentinels():
    n, d, block = 300, 4, 128
    x = _clustered(n, d)
    idx = spatial.build_index(x)
    lay = spatial.cluster_layout(jnp.asarray(x, jnp.float32), idx.labels,
                                 block)
    meta = spatial.tile_metadata(lay.points, lay.real, block=block)
    t = lay.points.shape[0] // block
    assert meta.centroids.shape == (t, d)
    counts = np.asarray(meta.counts)
    assert counts.sum() == n
    # radius covers every real point of its tile
    x3 = np.asarray(lay.points).reshape(t, block, d)
    mask = np.asarray(lay.real).reshape(t, block)
    for i in range(t):
        if counts[i] == 0:
            continue
        dist = np.linalg.norm(
            x3[i][mask[i]] - np.asarray(meta.centroids)[i], axis=1)
        assert dist.max() <= np.asarray(meta.radii)[i] * (1 + 1e-5) + 1e-6
    # sentinel coordinates never leak into max_abs
    assert np.asarray(meta.max_abs).max() < ops.PAD_VALUE / 2


def test_visit_lists_layout():
    keep = jnp.asarray([[True, False, True, False],
                        [False, False, False, False],
                        [True, True, True, True]])
    vl = spatial.visit_lists(keep)
    counts = np.asarray(vl.counts)
    np.testing.assert_array_equal(counts, [2, 0, 4])
    assert vl.max_visits == 4                      # pow2-bucketed max
    tmap = np.asarray(vl.tile_map)
    np.testing.assert_array_equal(tmap[0, :2], [0, 2])
    np.testing.assert_array_equal(tmap[0, 2:], [0, 0])   # fill = first kept
    np.testing.assert_array_equal(tmap[2], [0, 1, 2, 3])
    assert vl.occupancy == pytest.approx(6 / 12)


# ---------------------------------------------------------------------------
# Certificates vs float64 ground truth (adversarial geometries included).
# ---------------------------------------------------------------------------

GEOMETRIES = {
    "clustered": lambda: (_clustered(900, 6, seed=1),
                          _clustered(250, 6, seed=2)),
    "huge_offset": lambda: (_clustered(900, 6, seed=3, offset=1000.0),
                            _clustered(250, 6, seed=4, offset=1000.0)),
    "duplicates": lambda: (jnp.tile(_clustered(90, 6, seed=5), (10, 1)),
                           _clustered(250, 6, seed=6)),
    "outlier": lambda: (
        jnp.concatenate([_clustered(899, 6, seed=7),
                         jnp.full((1, 6), 250.0)]),
        _clustered(250, 6, seed=8),
    ),
    "far_queries": lambda: (_clustered(900, 6, seed=9),
                            _clustered(250, 6, seed=10) + 500.0),
}


# f32 exp(-x) is exactly 0.0 for x > 150*ln2 — the f64 oracles below model
# the f32 kernel's arithmetic, so mass the kernel NEVER accumulates (it
# underflows to an exact zero) is not "dropped" by pruning.
F32_EXP_UNDERFLOW = 103.97


def _prepass(x, y, h, eps, kind, bm=64, bn=128):
    """Replicate the pruned wrappers' prepass; return f64 layouts + map."""
    index = spatial.build_index(x, seed=0)
    xlay = spatial.cluster_layout(jnp.asarray(x, jnp.float32), index.labels,
                                  bn)
    col_meta = spatial.tile_metadata(xlay.points, xlay.real, block=bn)
    labels_q = spatial.assign(y, index)
    qlay = spatial.cluster_layout(jnp.asarray(y, jnp.float32), labels_q, bm)
    inv2h2 = jnp.asarray(1.0 / (2 * h * h), jnp.float32).reshape(1, 1)
    tm = spatial.tile_map(qlay.points, col_meta, inv2h2, eps, block_m=bm,
                          kind=kind)
    return (np.asarray(xlay.points, np.float64), np.asarray(xlay.real),
            np.asarray(qlay.points, np.float64),
            np.asarray(tm.keep), np.asarray(tm.err_bound), bm, bn)


@pytest.mark.parametrize("geometry", sorted(GEOMETRIES))
@pytest.mark.parametrize("kind", ["kde", "laplace"])
def test_certificate_dominates_true_dropped_mass(geometry, kind):
    x, y = GEOMETRIES[geometry]()
    h, eps = 0.4, 1e-7
    xp, xreal, yp, keep, err, bm, bn = _prepass(x, y, h, eps, kind)
    d = xp.shape[1]
    sq = ((yp[:, None, :] - xp[None, :, :]) ** 2).sum(-1)
    scaled = sq / (2 * h * h)
    phi = np.where(scaled > F32_EXP_UNDERFLOW, 0.0, np.exp(-scaled))
    contrib = np.abs(phi * (1 + d / 2 - scaled)) if kind == "laplace" else phi
    contrib[:, ~xreal] = 0.0    # sentinel columns carry no mass
    mt, t = keep.shape
    for i in range(mt):
        rows = contrib[i * bm:(i + 1) * bm]
        dropped = np.zeros(rows.shape[0])
        for j in range(t):
            if not keep[i, j]:
                dropped += rows[:, j * bn:(j + 1) * bn].sum(axis=1)
        assert dropped.max() <= err[i] * (1 + 1e-5) + 1e-300, (geometry, i)


def test_score_certificate_dominates_s1aug_error():
    x = _clustered(600, 5, seed=11)
    h, eps, bm, bn = 0.4, 1e-7, 64, 128
    index = spatial.build_index(x, seed=0)
    lay = spatial.cluster_layout(jnp.asarray(x, jnp.float32), index.labels,
                                 bn, total_multiple=math.lcm(bm, bn))
    col_meta = spatial.tile_metadata(lay.points, lay.real, block=bn)
    inv2h2 = jnp.asarray(1.0 / (2 * h * h), jnp.float32).reshape(1, 1)
    tm = spatial.tile_map(lay.points, col_meta, inv2h2, eps, block_m=bm,
                          kind="score")
    keep, err = np.asarray(tm.keep), np.asarray(tm.err_bound)
    x64 = np.asarray(lay.points, np.float64)
    real = np.asarray(lay.real)
    scaled = ((x64[:, None] - x64[None]) ** 2).sum(-1) / (2 * h * h)
    phi = np.where(scaled > F32_EXP_UNDERFLOW, 0.0, np.exp(-scaled))
    phi[:, ~real] = 0.0
    aug = np.concatenate([x64, np.ones((x64.shape[0], 1))], axis=1)
    w = np.abs(aug)     # per-point |weight| of each S1aug component
    mt, t = keep.shape
    for i in range(mt):
        rows = phi[i * bm:(i + 1) * bm]
        dropped = np.zeros(bm)
        for j in range(t):
            if not keep[i, j]:
                sl = slice(j * bn, (j + 1) * bn)
                dropped = np.maximum(
                    dropped, (rows[:, sl] @ w[sl]).max(axis=1)
                )
        assert dropped.max() <= err[i] * (1 + 1e-5) + 1e-300, i


# ---------------------------------------------------------------------------
# Exact mode (epsilon=0) == dense, across kernels and precision tiers.
# ---------------------------------------------------------------------------


def _tol(tier):
    # pruned-vs-dense at the SAME tier differs only by f32 accumulation
    # order; the atol floor covers deep-tail sums near the underflow edge
    return dict(rtol=1e-6, atol=1e-20)


@pytest.mark.parametrize("tier", TIERS)
def test_exact_mode_kde_matches_dense(tier):
    x, y = _clustered(900, 6, seed=20), _clustered(300, 6, seed=21)
    kw = dict(precision=tier, block_m=32, block_n=128, interpret=True)
    dense = ops.flash_kde(x, y, 0.35, prune="off", **kw)
    pruned = ops.flash_kde(x, y, 0.35, prune=0.0, **kw)
    np.testing.assert_allclose(np.asarray(pruned), np.asarray(dense),
                               **_tol(tier))


@pytest.mark.parametrize("tier", TIERS)
def test_exact_mode_laplace_matches_dense(tier):
    x, y = _clustered(900, 6, seed=22), _clustered(300, 6, seed=23)
    kw = dict(precision=tier, block_m=32, block_n=128, interpret=True)
    dense = ops.flash_laplace_kde(x, y, 0.35, prune="off", **kw)
    pruned = ops.flash_laplace_kde(x, y, 0.35, prune=0.0, **kw)
    # Laplace sums cross zero; bound the deviation against the row scale
    scale = float(np.max(np.abs(np.asarray(dense)))) + 1e-30
    np.testing.assert_allclose(np.asarray(pruned) / scale,
                               np.asarray(dense) / scale,
                               rtol=0, atol=2e-6)


@pytest.mark.parametrize("tier", TIERS)
def test_exact_mode_score_stats_match_dense(tier):
    x = _clustered(700, 5, seed=24)
    kw = dict(precision=tier, block_m=32, block_n=128, interpret=True)
    s0d, s1d = ops.flash_score_stats(x, 0.5, prune="off", **kw)
    s0p, s1p = ops.flash_score_stats(x, 0.5, prune=0.0, **kw)
    np.testing.assert_allclose(np.asarray(s0p), np.asarray(s0d), rtol=1e-6,
                               atol=1e-20)
    scale = float(np.max(np.abs(np.asarray(s1d)))) + 1e-30
    np.testing.assert_allclose(np.asarray(s1p) / scale,
                               np.asarray(s1d) / scale, rtol=0, atol=2e-6)


def test_exact_mode_far_queries_underflow_consistent():
    """Queries whose true density is exactly 0 in f32: both paths say 0."""
    x = _clustered(600, 4, seed=25)
    y = _clustered(100, 4, seed=26) + 500.0
    kw = dict(block_m=32, block_n=128, interpret=True)
    dense = np.asarray(ops.flash_kde(x, y, 0.3, prune="off", **kw))
    pruned = np.asarray(ops.flash_kde(x, y, 0.3, prune=0.0, **kw))
    np.testing.assert_array_equal(dense, 0.0)
    np.testing.assert_array_equal(pruned, 0.0)


def test_epsilon_error_within_loose_budget():
    """|pruned − dense| ≤ the documented n·epsilon mass bound + f32 noise."""
    x, y = _clustered(1200, 6, seed=27), _clustered(400, 6, seed=28)
    n, d, h = x.shape[0], x.shape[1], 0.35
    kw = dict(block_m=32, block_n=128, interpret=True)
    dense = np.asarray(ops.flash_kde(x, y, h, prune="off", **kw))
    for eps in (1e-12, 1e-8, 1e-5):
        pruned = np.asarray(ops.flash_kde(x, y, h, prune=eps, **kw))
        budget = eps * n / (n * (2 * math.pi) ** (d / 2) * h**d)
        slack = 1e-5 * np.abs(dense) + 1e-20
        assert np.all(np.abs(pruned - dense) <= budget + slack), eps


def test_sdkde_pipeline_pruned_matches_dense():
    x, y = _clustered(800, 5, seed=29), _clustered(200, 5, seed=30)
    kw = dict(block_m=32, block_n=128, interpret=True)
    dense = ops.flash_sdkde(x, y, 0.4, prune="off", **kw)
    pruned = ops.flash_sdkde(x, y, 0.4, prune=0.0, **kw)
    # exact-mode score noise is amplified through the shift's exponentials
    np.testing.assert_allclose(np.asarray(pruned), np.asarray(dense),
                               rtol=2e-4, atol=1e-12)


# ---------------------------------------------------------------------------
# Property test: random geometry, certificate + exact mode (hypothesis).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # the property test degrades to a fixed-seed sweep
    _HAVE_HYPOTHESIS = False


def _certificate_case(seed, k, spread, sigma, h, eps):
    x = _clustered(260, 3, k=k, spread=spread, sigma=sigma, seed=seed)
    y = _clustered(70, 3, k=k, spread=spread, sigma=sigma, seed=seed + 1)
    xp, xreal, yp, keep, err, bm, bn = _prepass(
        x, y, h, eps, "kde", bm=32, bn=64
    )
    scaled = ((yp[:, None] - xp[None]) ** 2).sum(-1) / (2 * h * h)
    phi = np.where(scaled > F32_EXP_UNDERFLOW, 0.0, np.exp(-scaled))
    phi[:, ~xreal] = 0.0
    mt, t = keep.shape
    for i in range(mt):
        rows = phi[i * bm:(i + 1) * bm]
        dropped = np.zeros(bm)
        for j in range(t):
            if not keep[i, j]:
                dropped += rows[:, j * bn:(j + 1) * bn].sum(axis=1)
        assert dropped.max() <= err[i] * (1 + 1e-5) + 1e-300


if _HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        k=st.integers(1, 6),
        spread=st.floats(0.5, 50.0),
        sigma=st.floats(0.01, 1.0),
        h=st.floats(0.05, 1.0),
        eps=st.sampled_from([0.0, 1e-10, 1e-6, 1e-3]),
    )
    def test_certificate_property(seed, k, spread, sigma, h, eps):
        _certificate_case(seed, k, spread, sigma, h, eps)

else:

    @pytest.mark.parametrize("seed,k,spread,sigma,h,eps", [
        (0, 1, 0.5, 1.0, 0.05, 0.0),
        (1, 4, 20.0, 0.05, 0.3, 1e-10),
        (2, 6, 50.0, 0.5, 1.0, 1e-6),
        (3, 3, 5.0, 0.01, 0.1, 1e-3),
        (4, 2, 2.0, 0.2, 0.5, 1e-6),
    ])
    def test_certificate_property(seed, k, spread, sigma, h, eps):
        _certificate_case(seed, k, spread, sigma, h, eps)


# ---------------------------------------------------------------------------
# Dispatch policy, autotuner occupancy, VMEM widths.
# ---------------------------------------------------------------------------


def test_resolve_prune_policy():
    assert ops.resolve_prune("off", 10**6, 512) is None
    assert ops.resolve_prune("auto", 1024, 512) is None       # too small
    assert ops.resolve_prune("auto", 10**6, 512) == 0.0
    assert ops.resolve_prune(1e-8, 64, 512) == 1e-8           # explicit: on
    assert ops.resolve_prune(0.0, 64, 512) == 0.0
    with pytest.raises(ValueError):
        ops.resolve_prune(-1.0, 10**6, 512)
    with pytest.raises(ValueError):
        ops.resolve_prune("both", 10**6, 512)


def test_occupancy_learning_feeds_the_tuner():
    autotune.clear_cache()
    try:
        assert autotune.expected_occupancy(4096, 10**6, 16) == 1.0
        autotune.record_occupancy(4096, 10**6, 16, 0.1, block_n=128)
        assert autotune.expected_occupancy(
            4096, 10**6, 16, block_n=128) == pytest.approx(0.1)
        autotune.record_occupancy(4096, 10**6, 16, 0.3, block_n=128)  # EMA
        assert autotune.expected_occupancy(
            4096, 10**6, 16, block_n=128) == pytest.approx(0.2)
        # tile-width extrapolation: wider tiles prune worse, linearly
        assert autotune.expected_occupancy(
            4096, 10**6, 16, block_n=512) == pytest.approx(0.8)
        assert autotune.expected_occupancy(
            4096, 10**6, 16, block_n=4096) == 1.0        # capped
        dense = autotune.modeled_cost(4096, 10**6, 16, block_m=128,
                                      block_n=512)
        sparse = autotune.modeled_cost(4096, 10**6, 16, block_m=128,
                                       block_n=512, occupancy=0.2)
        assert sparse.step_time < dense.step_time / 2
    finally:
        autotune.clear_cache()


def test_pruned_wrappers_record_occupancy():
    autotune.clear_cache()
    try:
        x, y = _clustered(1024, 4, seed=31), _clustered(128, 4, seed=32)
        ops.flash_kde(x, y, 0.2, block_m=32, block_n=128, interpret=True,
                      prune=0.0)
        assert autotune.expected_occupancy(128, 1024, 4, block_n=128) < 1.0
        # and the next auto-resolve for this regime consults the record
        bm, bn = autotune.resolve_blocks("auto", "auto", 128, 1024, 4,
                                         measure=False, pruned=True)
        assert bn in autotune.DEFAULT_BLOCK_NS
    finally:
        autotune.clear_cache()


def test_vmem_is_out_width_aware():
    d = 256
    score_b = ops.vmem_tile_bytes(128, 1024, d, out_width=d + 1)
    kde_b = ops.vmem_tile_bytes(128, 1024, d, out_width=1)
    legacy = ops.vmem_tile_bytes(128, 1024, d)        # None = conservative
    assert kde_b < score_b == legacy
    # exactly the xaug operand tile + the accumulator width difference
    assert score_b - kde_b == 4 * (1024 * (d + 1)) + 4 * 128 * d
    # a tile the score budget rejects fits on the KDE path
    bm, bn, dd = 128, 2048, 700
    with pytest.raises(ValueError, match="VMEM"):
        ops._check_vmem(bm, bn, dd, out_width=dd + 1)
    ops._check_vmem(bm, bn, dd, out_width=1)


def test_prepare_train_columns_auto_block_and_annotation():
    x = _clustered(600, 4, seed=33)
    cols = ops.prepare_train_columns(x, block_n="auto", precision="f32")
    assert cols.xt.shape[0] == 4
    assert cols.xt.shape[1] % 128 == 0    # padded to a real resolved tile
    assert cols.meta is None and cols.index is None
    spatialized = ops.prepare_train_columns(x, block_n=128, clustered=True)
    assert spatialized.meta is not None and spatialized.index is not None
    assert np.asarray(spatialized.meta.counts).sum() == 600


# ---------------------------------------------------------------------------
# Serving integration.
# ---------------------------------------------------------------------------


def test_serve_pruned_matches_reference():
    from repro.core import kde as refkde
    from repro.serve import ServeConfig, ServeEngine

    x = _clustered(2048, 6, seed=34)
    y = _clustered(300, 6, seed=35)
    cfg = ServeConfig(backend="pallas", method="sdkde", interpret=True,
                      block_m=32, block_n=256, prune=0.0,
                      min_batch=64, max_batch=512)
    eng = ServeEngine(cfg)
    prep = eng.register("clustered", x, h=0.4)
    got = np.asarray(_q(eng, "clustered", y))
    want = np.asarray(refkde.sdkde_eval(x, y, 0.4, block=1024))
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-6 * float(np.max(np.abs(want))))
    # the clustered columns are fit-time state, shared across tiers
    cols_f32 = prep.columns_for("f32")
    cols_bf16 = prep.columns_for("bf16")
    assert cols_f32.meta is not None and cols_bf16.meta is not None
    assert cols_bf16.index is cols_f32.index


def test_serve_prune_off_unchanged():
    from repro.serve import ServeConfig, ServeEngine

    x = _clustered(512, 4, seed=36)
    y = _clustered(64, 4, seed=37)
    on = ServeEngine(ServeConfig(backend="pallas", method="kde",
                                 interpret=True, block_m=32, block_n=128,
                                 prune=0.0, min_batch=32, max_batch=128))
    off = ServeEngine(ServeConfig(backend="pallas", method="kde",
                                  interpret=True, block_m=32, block_n=128,
                                  prune="off", min_batch=32, max_batch=128))
    on.register("k", x, h=0.3)
    off.register("k", x, h=0.3)
    np.testing.assert_allclose(np.asarray(_q(on, "k", y)),
                               np.asarray(_q(off, "k", y)),
                               rtol=1e-6, atol=1e-20)


def test_serve_config_validates_prune():
    from repro.serve import ServeConfig

    with pytest.raises(ValueError, match="prune"):
        ServeConfig(prune="sometimes")
    with pytest.raises(ValueError, match="prune"):
        ServeConfig(prune=-0.5)
    ServeConfig(prune=1e-9)
    ServeConfig(prune="off")


def test_public_wrappers_stay_jittable():
    """Under jit tracing the wrappers fall back to dense (the pruned path
    host-syncs) instead of crashing with a tracer-conversion error."""
    x, y = _clustered(600, 4, seed=50), _clustered(80, 4, seed=51)
    kw = dict(block_m=32, block_n=128, interpret=True)
    jitted = jax.jit(lambda a, b: ops.flash_kde(a, b, 0.3, prune=0.0, **kw))
    dense = ops.flash_kde(x, y, 0.3, prune="off", **kw)
    np.testing.assert_allclose(np.asarray(jitted(x, y)), np.asarray(dense),
                               rtol=1e-6, atol=1e-20)


def test_one_shot_columns_cache_amortizes_prep():
    """Repeated evaluation on the SAME train array reuses one spatial prep."""
    x = _clustered(700, 4, seed=52)
    c1 = ops._cached_columns(x, block_n=128, precision="f32", seed=0)
    c2 = ops._cached_columns(x, block_n=128, precision="f32", seed=0)
    assert c1 is c2
    # different array identity -> fresh prep
    x2 = x + 0.0
    c3 = ops._cached_columns(x2, block_n=128, precision="f32", seed=0)
    assert c3 is not c1


def test_prepared_prune_rejects_mismatched_block_n():
    """Visit lists address prepare-width tiles; a different launch width
    must be rejected, and "auto" must resolve to the prepared width."""
    x = _clustered(900, 5, seed=40)
    y = _clustered(64, 5, seed=41)
    cols = ops.prepare_train_columns(x, block_n=128, clustered=True)
    assert cols.block_n == 128
    yp = ops._pad_to(jnp.asarray(y, jnp.float32), 32)
    with pytest.raises(ValueError, match="block_n"):
        ops.flash_kde_prepared(yp, cols.xt, cols.nrm_x, 0.35,
                               prune=0.0, columns=cols, n_real=64,
                               block_m=32, block_n=64, interpret=True)
    # "auto" snaps to the prepared width instead of misaddressing tiles
    ops.flash_kde_prepared(yp, cols.xt, cols.nrm_x, 0.35,
                           prune=0.0, columns=cols, n_real=64,
                           block_m=32, block_n="auto", interpret=True)


@pytest.mark.parametrize("tier", TIERS)
def test_prepared_prune_tiers(tier):
    """flash_kde_prepared's pruned path across tiers, with sentinel rows."""
    x = _clustered(900, 5, seed=38)
    y = _clustered(100, 5, seed=39)
    cols = ops.prepare_train_columns(x, block_n=128, precision=tier,
                                     clustered=True)
    yp = ops._pad_to(jnp.asarray(y, jnp.float32), 64)
    kw = dict(precision=tier, block_m=64, block_n=128, interpret=True)
    dense = ops.flash_kde_prepared(yp, cols.xt, cols.nrm_x, 0.35,
                                   cols.xt_lo, **kw)
    pruned = ops.flash_kde_prepared(yp, cols.xt, cols.nrm_x, 0.35,
                                    cols.xt_lo, prune=0.0, columns=cols,
                                    n_real=100, **kw)
    np.testing.assert_allclose(np.asarray(pruned)[:100],
                               np.asarray(dense)[:100], rtol=1e-6,
                               atol=1e-20)
