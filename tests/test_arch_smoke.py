"""Per-architecture smoke tests (brief requirement).

For EVERY assigned architecture: instantiate the REDUCED config of the same
family, run one forward/train step AND one prefill+decode step on CPU,
assert output shapes and finiteness.  The full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data.synthetic import lm_batch
from repro.models.common import init_params, param_count
from repro.models.transformer import (
    decode_step,
    init_cache,
    loss_fn,
    prefill,
)

ARCHS = list(list_archs())


def _reduced(arch_id):
    arch = get_arch(arch_id)
    return arch, arch.model.reduced(dtype=jnp.float32)


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_train_step(arch_id):
    arch, cfg = _reduced(arch_id)
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert param_count(cfg) > 0
    batch = lm_batch(cfg, seed=0, step=0, batch=2, seq=16)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss)), arch_id
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in grads.values())
    assert np.isfinite(gn) and gn > 0, arch_id


@pytest.mark.parametrize("arch_id", ARCHS)
def test_prefill_then_decode(arch_id):
    arch, cfg = _reduced(arch_id)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_batch(cfg, seed=0, step=0, batch=2, seq=8)
    logits, cache = prefill(
        params, batch["tokens"], cfg,
        patches=batch.get("patches"), frames=batch.get("frames"),
    )
    assert logits.shape == (2, cfg.padded_vocab), arch_id
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch_id

    # extend the cache by a slot and decode one token
    max_len = 8 + 4 + (cfg.n_patches if cfg.family == "vlm" else 0)
    full = init_cache(cfg, 2, max_len)
    for k, v in cache.items():
        if k == "pos":
            continue
        if k in ("conv", "ssm"):
            full[k] = v
        else:
            full[k] = jax.lax.dynamic_update_slice(
                full[k], v.astype(full[k].dtype), (0,) * full[k].ndim
            )
    full["pos"] = cache["pos"]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, full = decode_step(params, full, tok, cfg)
    assert logits2.shape == (2, cfg.padded_vocab), arch_id
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch_id
    assert int(full["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch_id", ["gemma2_2b", "falcon_mamba_7b",
                                     "hymba_1p5b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Greedy decode logits == teacher-forced forward on the same tokens."""
    arch, cfg = _reduced(arch_id)
    from repro.models.transformer import forward_hidden
    from repro.models.layers import logits_head

    params = init_params(cfg, jax.random.PRNGKey(1))
    tokens = lm_batch(cfg, 0, 0, 2, 8)["tokens"]

    # full forward logits at every position
    hidden, _ = forward_hidden(params, tokens, cfg)
    logits_tf = logits_head(params, hidden, cfg)

    # incremental: prefill 4, decode the next 4 with teacher forcing
    logits_p, cache = prefill(params, tokens[:, :4], cfg)
    full = init_cache(cfg, 2, 8)
    for k, v in cache.items():
        if k == "pos":
            continue
        if k in ("conv", "ssm"):
            full[k] = v
        else:
            full[k] = jax.lax.dynamic_update_slice(
                full[k], v.astype(full[k].dtype), (0,) * full[k].ndim
            )
    full["pos"] = cache["pos"]
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(logits_tf[:, 3]),
        rtol=2e-3, atol=2e-3,
    )
    for t in range(4, 8):
        logits_d, full = decode_step(params, full, tokens[:, t:t+1], cfg)
        if t < 7:
            np.testing.assert_allclose(
                np.asarray(logits_d), np.asarray(logits_tf[:, t]),
                rtol=2e-3, atol=2e-3, err_msg=f"{arch_id} pos {t}",
            )


def test_gemma2_softcap_and_window_active():
    _, cfg = _reduced("gemma2_2b")
    assert cfg.attn_softcap and cfg.final_softcap
    from repro.models.transformer import layer_windows

    w = layer_windows(cfg)
    assert int(w[0]) == cfg.sliding_window          # even layers local
    assert int(w[1]) > 10**6                        # odd layers global


def test_int8_kv_cache_decode_close_to_fp():
    """kv_quant decode tracks full-precision logits (serving option)."""
    _, cfg = _reduced("gemma2_2b")
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = lm_batch(cfg, 0, 0, 2, 6)["tokens"]

    def run(c):
        cache = init_cache(c, 2, 8)
        logits = None
        for t in range(6):
            logits, cache = decode_step(params, cache, tokens[:, t:t+1], c)
        return np.asarray(logits, np.float32)

    lf, lq = run(cfg), run(cfgq)
    # int8 cache perturbs logits slightly; rankings stay aligned
    np.testing.assert_allclose(lq, lf, rtol=0.1, atol=0.15)
    top_f = np.argsort(lf, -1)[:, -5:]
    top_q = np.argsort(lq, -1)[:, -5:]
    overlap = np.mean([len(set(a) & set(b)) for a, b in zip(top_f, top_q)])
    assert overlap >= 3.0, overlap


def test_moe_capacity_drops_pass_through():
    """Tokens over expert capacity keep their residual (output finite)."""
    arch, cfg = _reduced("granite_moe_3b_a800m")
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)  # force drops
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = lm_batch(cfg, 0, 0, 2, 16)
    loss = loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
