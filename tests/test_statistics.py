"""Statistical validation: SD-KDE / Laplace-KDE must actually debias.

Reproduces the paper's core statistical claims at CPU scale:
  * On the benchmark mixtures, SD-KDE and Laplace-KDE beat vanilla KDE's
    MISE at equal n (Fig. 2/3 direction).
  * Bias scaling on a standard Gaussian: the debiased estimators' bias
    shrinks ~O(h⁴) vs KDE's O(h²) (Section 5 operator analysis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde
from repro.core.bandwidth import sdkde_bandwidth, silverman_bandwidth
from repro.core.metrics import oracle_errors
from repro.core.mixtures import benchmark_mixture_1d, benchmark_mixture_16d


def test_sdkde_beats_kde_mise_1d():
    """At equal (Silverman) bandwidth the bias-corrected estimators must
    beat vanilla KDE, with the paper's Fig-3 ordering: Laplace lowest MISE.

    (The wider n^{-1/(d+8)} SD-rate bandwidth needs a re-calibrated
    constant on multimodal targets — Silverman's constant is tuned to
    near-Gaussian densities; see bandwidth.sdkde_bandwidth's ``scale``.)
    """
    mix = benchmark_mixture_1d()
    key = jax.random.PRNGKey(0)
    mises = {"kde": [], "sdkde": [], "laplace": []}
    for seed in range(3):
        x = mix.sample(jax.random.fold_in(key, seed), 2000)
        h = float(silverman_bandwidth(x))
        e_kde = oracle_errors(lambda g: kde.kde_eval(x, g, h, block=256), mix)
        e_sd = oracle_errors(
            lambda g: kde.sdkde_eval(x, g, h, block=256), mix
        )
        e_lc = oracle_errors(
            lambda g: kde.laplace_kde_eval(x, g, h, block=256), mix
        )
        mises["kde"].append(e_kde.mise)
        mises["sdkde"].append(e_sd.mise)
        mises["laplace"].append(e_lc.mise)
    kde_m = np.mean(mises["kde"])
    assert np.mean(mises["sdkde"]) < kde_m, mises
    assert np.mean(mises["laplace"]) < kde_m, mises
    # Fig 3: the Laplace-corrected estimator attains the lowest MISE.
    assert np.mean(mises["laplace"]) < np.mean(mises["sdkde"]), mises


def test_sdkde_beats_kde_mise_16d():
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(1)
    x = mix.sample(key, 4096)
    h = float(silverman_bandwidth(x))
    e_kde = oracle_errors(
        lambda g: kde.kde_eval(x, g, h, block=512), mix, key, n_mc=4096
    )
    e_sd = oracle_errors(
        lambda g: kde.sdkde_eval(x, g, h, block=512), mix, key, n_mc=4096
    )
    assert e_sd.mise < e_kde.mise, (e_sd, e_kde)
    assert e_sd.miae < e_kde.miae, (e_sd, e_kde)  # Fig 2: SD-KDE lowest MIAE


def test_bias_scaling_order():
    """At a fixed point of a known Gaussian, KDE bias ~ h², corrected ~ h⁴.

    Use the analytic expectation (the estimators are linear in the data for
    KDE/Laplace): E[p̂] is a Gaussian convolution, evaluated by massive
    sampling; we verify the bias RATIO between h and h/2 — ~4 for KDE
    (order h²) and ~16 for Laplace (order h⁴).
    """
    key = jax.random.PRNGKey(2)
    n = 200_000
    x = jax.random.normal(key, (n, 1))
    y = jnp.zeros((1, 1))
    p_true = 1.0 / np.sqrt(2 * np.pi)

    def bias(fn, h):
        return abs(float(fn(x, y, h, block=8192)[0]) - p_true)

    b_kde_h, b_kde_h2 = bias(kde.kde_eval, 0.5), bias(kde.kde_eval, 0.25)
    ratio_kde = b_kde_h / max(b_kde_h2, 1e-12)
    # O(h²): halving h divides bias by ~4
    assert 2.5 < ratio_kde < 6.5, (b_kde_h, b_kde_h2)

    b_lc_h = bias(kde.laplace_kde_eval, 0.5)
    b_lc_h2 = bias(kde.laplace_kde_eval, 0.25)
    ratio_lc = b_lc_h / max(b_lc_h2, 1e-12)
    # O(h⁴): ratio ≈ 16, noisy at finite n — just require clearly super-h².
    assert ratio_lc > 7.0, (b_lc_h, b_lc_h2)
    # and the corrected estimator is less biased at equal h
    assert b_lc_h < b_kde_h


def test_negative_mass_is_small_but_nonzero_for_laplace():
    """The signed-estimator diagnostic the paper logs (§5, §6.1)."""
    mix = benchmark_mixture_1d()
    x = mix.sample(jax.random.PRNGKey(3), 1000)
    h = float(silverman_bandwidth(x)) * 1.5
    e = oracle_errors(
        lambda g: kde.laplace_kde_eval(x, g, h, block=256), mix
    )
    assert e.neg_mass >= 0.0
    assert e.neg_mass < 0.05  # small relative to unit mass
