"""repro.obs: metrics semantics, span reconstruction, serve integration.

The acceptance test at the bottom runs the streaming soak from ISSUE —
register → appends → queries → flush under tracing — and reconstructs
every request's bucket / cache hit-miss / staleness / prune-occupancy
chain purely from the buffered span events.
"""

import json
import math

import jax
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import Histogram, log_bucket_bounds
from repro.serve import QueryRequest, ServeConfig, ServeEngine
from repro.serve.stats import LatencyRecorder


def _q(eng, key, y, **kw):
    """One typed query, densities out."""
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

D, H = 4, 0.5


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Every test sees default flags and leaves no trace events behind."""
    m0, t0 = obs.state.metrics_on, obs.state.trace_on
    obs.configure(metrics=True, trace=False)
    yield
    obs.configure(metrics=m0, trace=t0)
    obs.clear_trace()


@pytest.fixture(scope="module")
def data():
    kx, ka, ky = jax.random.split(jax.random.PRNGKey(0), 3)
    return (np.asarray(jax.random.normal(kx, (256, D)), np.float32),
            np.asarray(jax.random.normal(ka, (32, D)), np.float32),
            np.asarray(jax.random.normal(ky, (64, D)), np.float32))


# ---------------------------------------------------------------------------
# Histogram core: bucket boundaries, quantile edge cases, bounded state.
# ---------------------------------------------------------------------------


def test_log_bucket_bounds_spacing():
    b = log_bucket_bounds(1e-3, 1.0, per_decade=6)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] >= 1.0
    for lo, hi in zip(b, b[1:]):
        assert hi / lo == pytest.approx(10 ** (1 / 6))


def test_histogram_boundary_value_lands_in_its_edge_bucket():
    h = Histogram("t.edges", lo=1e-3, hi=1.0, per_decade=6)
    edge = h.bounds[3]
    h.observe(edge)                       # exactly ON an upper edge
    assert h.counts[3] == 1               # bisect_left: le-inclusive
    h.observe(edge * 1.0001)              # just past it
    assert h.counts[4] == 1
    h.observe(1e-9)                       # below lo -> first bucket
    assert h.counts[0] == 1
    h.observe(1e9, k=5)                   # past hi -> overflow, weighted
    assert h.counts[-1] == 5 and h.count == 8


def test_histogram_quantile_empty_and_single():
    h = Histogram("t.q", lo=1e-3, hi=1.0)
    assert h.quantile(0.5) == 0.0 and h.quantile(0.99) == 0.0
    h.observe(0.0123)
    for q in (0.01, 0.5, 0.99):           # 1 sample: exact at every q
        assert h.quantile(q) == pytest.approx(0.0123)


def test_histogram_quantile_resolution_bar():
    h = Histogram("t.res", lo=1e-5, hi=1e3, per_decade=6)
    samples = [0.001, 0.002, 0.004, 1.5]
    for s in samples:
        h.observe(s)
    edge_ratio = 10 ** (1 / 6)
    p50, exact = h.quantile(0.5), 0.002
    assert exact / edge_ratio <= p50 <= exact * edge_ratio
    # min/max clamping is exact regardless of bucket resolution
    assert h.quantile(0.999) <= 1.5 and h.quantile(0.001) >= 0.001


def test_histogram_state_is_bounded():
    h = Histogram("t.bounded", lo=1e-5, hi=1e3)
    n_buckets = len(h.counts)
    for i in range(10_000):
        h.observe(1e-4 * (1 + i % 997))
    assert len(h.counts) == n_buckets and h.count == 10_000


def test_counter_and_disabled_fast_path():
    c = obs.counter("t.obs.ctr")
    c.reset()
    c.inc(); c.inc(2.0)
    assert c.value == 3.0
    with pytest.raises(ValueError):
        c.inc(-1)
    obs.configure(metrics=False)
    c.inc(100)
    obs.histogram("t.obs.h").observe(1.0)
    obs.gauge("t.obs.g").set(7)
    assert c.value == 3.0
    assert obs.histogram("t.obs.h").count == 0
    assert obs.gauge("t.obs.g").value == 0.0


# ---------------------------------------------------------------------------
# LatencyRecorder (satellite 1): bounded, JSON-safe, exact small-n.
# ---------------------------------------------------------------------------


def test_latency_recorder_empty_summary_json_safe():
    s = LatencyRecorder().summary()
    assert s.count == 0 and s.queries == 0
    assert s.qps == 0.0 and s.p50_ms == 0.0 and s.p99_ms == 0.0
    # allow_nan=False raises on any bare NaN/Inf — the downstream contract
    doc = json.dumps(s.as_dict(), allow_nan=False)
    assert "NaN" not in doc
    for v in s.as_dict().values():
        assert not (isinstance(v, float) and math.isnan(v))


def test_latency_recorder_single_sample_exact():
    r = LatencyRecorder()
    r.record(0.020, n_queries=64)
    s = r.summary()
    assert s.count == 1 and s.queries == 64
    assert s.p50_ms == pytest.approx(20.0)
    assert s.p99_ms == pytest.approx(20.0)
    assert s.qps == pytest.approx(64 / 0.020)


def test_latency_recorder_bounded_and_coalesce_weighting():
    r = LatencyRecorder()
    n_buckets = len(r._hist.counts)
    for _ in range(5000):
        r.record(0.001, n_queries=3, n_requests=4)
    assert len(r._hist.counts) == n_buckets
    s = r.summary()
    assert s.count == 20_000 and s.queries == 15_000
    r.reset()
    assert r.summary().count == 0


# ---------------------------------------------------------------------------
# Registry: snapshot stability across reset, prometheus exposition.
# ---------------------------------------------------------------------------


def test_snapshot_stable_across_reset():
    obs.counter("t.stab.c").inc(5)
    obs.gauge("t.stab.g").set(2.5)
    obs.histogram("t.stab.h", lo=1e-3, hi=1.0).observe(0.1, k=3)
    before = obs.metrics_snapshot()
    obs.registry.reset()
    after = obs.metrics_snapshot()
    assert set(after) == set(before)      # instrument set survives reset
    assert after["t.stab.c"]["value"] == 0.0
    assert after["t.stab.g"]["value"] == 0.0
    assert after["t.stab.h"]["count"] == 0
    assert before["t.stab.c"]["value"] == 5.0
    json.dumps(after, allow_nan=False)    # still JSON-safe when zeroed


def test_prometheus_exposition_lints_clean():
    obs.counter("t.prom.requests", "requests").inc()
    obs.histogram("t.prom.lat_s", lo=1e-4, hi=10.0).observe(0.02)
    obs.counter("t.prom.labeled", labels={"mode": "a b"}).inc()
    text = obs.prometheus_text()
    assert obs.lint_prometheus(text) == []
    assert "t_prom_lat_s_bucket" in text and 'le="+Inf"' in text


def test_prometheus_lint_catches_problems():
    bad = "\n".join([
        "# TYPE ok counter",
        "ok 1.0",
        "0bad_name 2.0",            # illegal leading digit
        "untyped_sample 3.0",       # no TYPE declared
        "# TYPE h histogram",
        'h_bucket{le="+Inf"} 1',    # histogram missing _sum/_count
        "ok not-a-number",
    ])
    problems = obs.lint_prometheus(bad)
    text = "\n".join(problems)
    assert "0bad_name" in text
    assert "untyped_sample" in text
    assert "missing series" in text
    assert "not-a-number" in text


# ---------------------------------------------------------------------------
# Spans: nesting/ordering under coalesced dispatch; engine metrics surface.
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering_under_query_many(data):
    x, _, y = data
    obs.configure(trace=True)
    obs.clear_trace()
    eng = ServeEngine(ServeConfig(backend="jnp", min_batch=16,
                                  max_batch=128))
    eng.register("t", x, h=H)
    eng.query_many([QueryRequest(key="t", points=q)
                    for q in (y[:5], y[:17], y[:3])])
    ev = obs.trace_events()
    req = [e for e in ev if e["name"] == "serve.request"]
    disp = [e for e in ev if e["name"] == "serve.dispatch"]
    buck = [e for e in ev if e["name"] == "serve.bucket"]
    assert len(req) == 1 and req[0]["attrs"]["requests"] == 3
    assert len(disp) == 1 and disp[0]["parent"] == req[0]["id"]
    assert len(buck) == 1 and buck[0]["parent"] == disp[0]["id"]
    assert buck[0]["attrs"]["rows"] == 25          # coalesced 5+17+3
    assert buck[0]["attrs"]["cache"] == "miss"
    # children close (and are buffered) before parents; timestamps nest
    order = [e["name"] for e in ev if e["name"].startswith("serve.")]
    assert order.index("serve.bucket") < order.index("serve.dispatch")
    assert order.index("serve.dispatch") < order.index("serve.request")
    assert req[0]["ts_us"] <= disp[0]["ts_us"] <= buck[0]["ts_us"]
    assert buck[0]["dur_us"] <= req[0]["dur_us"]
    # a second identical dispatch reuses the executable
    eng.query_many([QueryRequest(key="t", points=q)
                    for q in (y[:5], y[:17], y[:3])])
    last = obs.trace_events()[-3:]
    hit = [e for e in last if e["name"] == "serve.bucket"]
    assert hit and hit[0]["attrs"]["cache"] == "hit"
    # reconstruction helper: the tree groups children under parent ids
    tree = obs.span_tree(obs.trace_events())
    assert any(c["name"] == "serve.dispatch" for c in tree[req[0]["id"]])


def test_engine_metrics_surface(data):
    x, _, y = data
    eng = ServeEngine(ServeConfig(backend="jnp", min_batch=16,
                                  max_batch=128))
    eng.register("t", x, h=H)
    _q(eng, "t", y[:9])
    _q(eng, "t", y[:9])
    m = eng.metrics()
    assert m["latency"]["count"] == 2
    assert m["latency_hist"]["count"] == 2
    assert m["bucket_cache"]["hits"] == 1
    assert m["bucket_cache"]["misses"] == 1
    assert m["bucket_cache"]["resident"] == 1
    assert isinstance(m["registry"], dict)
    json.dumps(m, allow_nan=False)


def test_trace_disabled_is_null_span_and_records_nothing():
    obs.clear_trace()
    with obs.span("t.nothing", a=1) as sp:
        sp.set(b=2)
    assert obs.trace_events() == []
    assert obs.span("x") is obs.span("y")  # one shared no-op object


# ---------------------------------------------------------------------------
# Streaming: staleness histogram agrees with the engine's summary.
# ---------------------------------------------------------------------------


def _stream_cfg(**kw):
    base = dict(backend="pallas", method="sdkde", interpret=True,
                block_m=8, block_n=64, min_batch=16, max_batch=128,
                stream=True, staleness_budget=2)
    base.update(kw)
    return ServeConfig(**base)


def test_staleness_histogram_matches_summary(data):
    x, xa, y = data
    obs.registry.reset()
    eng = ServeEngine(_stream_cfg())
    eng.register("s", x[:128], h=H)
    _q(eng, "s", y[:8])
    for i in range(3):
        eng.registry.append("s", xa[i * 8:(i + 1) * 8])
        _q(eng, "s", y[:8])
    summ = eng.staleness_summary()
    hist = obs.histogram("serve.staleness_gen").snapshot()
    assert summ["count"] == hist["count"] >= 4
    assert summ["max"] == pytest.approx(hist["max"])
    # quantile estimate agrees to histogram resolution: exact when every
    # lag is 0; otherwise bounded by the winning bucket (lags 0 and 1
    # share the first bucket at lo=1, so the floor there is just >= 0)
    ratio = 10 ** (1 / 8)
    if summ["max"] == 0:
        assert hist["p50"] == 0.0
    else:
        assert 0.0 <= hist["p50"] <= max(summ["p50"], 1) * ratio


# ---------------------------------------------------------------------------
# Acceptance: the streaming soak's trace reconstructs every request chain.
# ---------------------------------------------------------------------------


def test_streaming_soak_trace_reconstruction(data):
    x, xa, y = data
    obs.configure(trace=True)
    obs.clear_trace()
    obs.registry.reset()
    # prune=0.0: an explicit epsilon engages the pruned pallas path at any
    # size, so per-request kernel launches appear in the trace
    eng = ServeEngine(_stream_cfg(prune=0.0))
    eng.register("soak", x[:128], h=H)
    rng = np.random.default_rng(0)
    n_requests = 6
    for i in range(n_requests):
        if i % 2 == 0:
            eng.registry.append("soak", xa[(i // 2) * 8:(i // 2) * 8 + 8])
        m = int(rng.integers(3, 60))
        _q(eng, "soak", y[:m])
    eng.registry.get("soak").stream.ensure(0)      # final flush

    ev = eng.trace_events()
    tree = obs.span_tree(ev)
    requests = [e for e in ev if e["name"] == "serve.request"]
    assert len(requests) == n_requests
    for req in requests:
        # request -> dispatch: staleness + pinned generation
        disp = [c for c in tree.get(req["id"], ())
                if c["name"] == "serve.dispatch"]
        assert len(disp) == 1, "each request has exactly one dispatch"
        a = disp[0]["attrs"]
        assert a["backend"] == "pallas"
        assert 0 <= a["staleness"] <= 2            # within budget
        assert "stream_gen" in a and "layout_epoch" in a
        # dispatch -> bucket: padded shape + cache hit/miss
        buck = [c for c in tree.get(disp[0]["id"], ())
                if c["name"] == "serve.bucket"]
        assert len(buck) == 1
        b = buck[0]["attrs"]
        assert b["bucket"] >= b["rows"] == req["attrs"]["rows"]
        assert b["cache"] in ("hit", "miss")
        assert b["pad_ratio"] == pytest.approx(b["bucket"] / b["rows"],
                                               rel=1e-3)
        # bucket -> pruned kernel launch: per-request prune occupancy
        kern = [c for c in tree.get(buck[0]["id"], ())
                if c["name"] == "kernels.pruned_eval"]
        assert kern, "pruned launch span missing under bucket span"
        assert 0.0 < kern[0]["attrs"]["occupancy"] <= 1.0
    # the append/flush side of the soak is in the same trace
    names = {e["name"] for e in ev}
    assert {"stream.append", "stream.flush"} <= names
    # and the metrics plane saw the same story
    snap = obs.metrics_snapshot()
    assert snap["serve.staleness_gen"]["count"] == n_requests
    assert any(k.startswith("kernels.prune.launches") for k in snap)
    assert snap["kernels.prune.visit_fraction"]["count"] >= n_requests
