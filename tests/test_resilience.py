"""Resilient serving (PR 8): replicated shard dispatch, chaos injection,
hedging, circuit breakers, fencing, and certified graceful degradation."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.fault_injection import ChaosConfig, ChaosEvent, FaultInjector
from repro.kernels import spatial
from repro.serve import (
    BadRequest,
    QueryRequest,
    Degraded,
    DeadlineExceeded,
    Overloaded,
    ResilienceConfig,
    ResilientEngine,
    ServeConfig,
    ServeError,
    UnknownKey,
)

D = 3
N = 384


@pytest.fixture(scope="module")
def data():
    mix = mixture_for_dim(D)
    key = jax.random.PRNGKey(0)
    return mix.sample(key, N), mix.sample(jax.random.fold_in(key, 1), 64)


def _req(key, y, **kw):
    return QueryRequest(key=key, points=y, **kw)


def mk_engine(chaos=None, **rkw):
    cfg = ServeConfig(backend="jnp", method="sdkde",
                      min_batch=8, max_batch=32)
    defaults = dict(shards=2, replicas=2, deadline_ms=30_000.0,
                    backoff_ms=1.0, hedge_after_ms=1000.0, seed=0)
    defaults.update(rkw)
    return ResilientEngine(cfg, ResilienceConfig(**defaults), chaos=chaos)


# -- exact recombination -------------------------------------------------------


def test_sharded_answer_matches_full_reference(data):
    x, pool = data
    with mk_engine() as eng:
        table = eng.register("k", x, prewarm=False)
        assert table.n_shards == 2 and table.n_replicas == 2
        assert sum(table.shard_n) == N
        y = pool[:24]
        ans = eng.query(_req("k", y))
        expect = np.asarray(ref.sdkde_eval(x, y, table.h, block=256))
        np.testing.assert_allclose(np.asarray(ans.densities), expect,
                                   rtol=1e-4)
        assert not ans.degraded and ans.live_shards == (0, 1)
        assert ans.missing_shards == ()
        assert 0.0 < ans.rel_err_bound <= 1e-5   # f32 tier rtol


# -- shard partitioning + certificates ----------------------------------------


def test_partition_clusters_covers_and_balances():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 8, 500)
    shard_of = spatial.partition_clusters(labels, 3)
    assert shard_of.shape == (8,)
    assert set(shard_of) == {0, 1, 2}           # no empty shard
    # greedy LPT: largest shard at most ~2x the smallest for iid sizes
    loads = np.bincount(shard_of[labels], minlength=3)
    assert loads.min() > 0 and loads.sum() == 500
    with pytest.raises(ValueError):
        spatial.partition_clusters(labels, 0)
    with pytest.raises(ValueError):
        spatial.partition_clusters(labels, 9)   # more shards than clusters


def test_point_mass_bound_dominates_true_mass(data):
    x, pool = data
    pts = np.asarray(x, np.float32)[:200]
    labels = np.asarray(spatial.build_index(pts, seed=0).labels)
    local = np.unique(labels, return_inverse=True)[1]
    layout = spatial.cluster_layout(jnp.asarray(pts), local, 64)
    meta = spatial.tile_metadata(layout.points, layout.real, block=64)
    h = 0.4
    inv2h2 = jnp.float32(1.0 / (2 * h * h))
    y = pool[:32]
    bound = np.asarray(spatial.point_mass_bound(y, meta, inv2h2), np.float64)
    d2 = np.sum(
        (np.asarray(y, np.float64)[:, None, :] - pts[None, :, :]) ** 2, -1)
    true_mass = np.exp(-d2 / (2 * h * h)).sum(axis=1)
    assert (bound + 1e-9 >= true_mass).all()


# -- chaos survival ------------------------------------------------------------


def test_replica_kill_is_survived_exactly(data):
    x, pool = data
    chaos = ChaosConfig(events=(
        ChaosEvent("shard_kill", shard=0, replica=0),), seed=0)
    with mk_engine(chaos=chaos) as eng:
        table = eng.register("k", x, prewarm=False)
        expect = None
        for i in range(5):
            y = pool[8 * i:8 * i + 16]
            ans = eng.query(_req("k", y))
            assert not ans.degraded
            expect = np.asarray(ref.sdkde_eval(x, y, table.h, block=256))
            np.testing.assert_allclose(np.asarray(ans.densities), expect,
                                       rtol=1e-4)
        assert eng.stats["dropped"] == 0
        assert eng.injector.snapshot()["shard_kill"] > 0


def test_nan_poison_never_reaches_caller(data):
    x, pool = data
    chaos = ChaosConfig(events=(
        ChaosEvent("nan_poison", shard=0, replica=0),), seed=0)
    with mk_engine(chaos=chaos) as eng:
        eng.register("k", x, prewarm=False)
        for i in range(4):
            ans = eng.query(_req("k", pool[8 * i:8 * i + 8]))
            assert np.isfinite(np.asarray(ans.densities)).all()
            assert not ans.degraded
        assert eng.stats["dropped"] == 0


def test_compile_fail_opens_breaker(data):
    x, pool = data
    chaos = ChaosConfig(events=(
        ChaosEvent("compile_fail", shard=0, replica=0),), seed=0)
    with mk_engine(chaos=chaos, breaker_threshold=2,
                   breaker_cooldown_s=3600.0) as eng:
        eng.register("k", x, prewarm=False)
        for i in range(8):
            ans = eng.query(_req("k", pool[:8]))
            assert not ans.degraded
        states = eng.breaker_states()
        assert any(k.startswith("k/s0r0") and v == "open"
                   for k, v in states.items()), states
        # the sibling replica keeps the shard serving: zero drops
        assert eng.stats["dropped"] == 0


def test_hedge_wins_over_slow_replica(data):
    x, pool = data
    chaos = ChaosConfig(events=(
        ChaosEvent("slow_shard", shard=0, replica=0),),
        slow_ms=300.0, seed=0)
    with mk_engine(chaos=chaos, hedge_after_ms=20.0) as eng:
        eng.register("k", x, prewarm=False)
        eng.query(_req("k", pool[:8]))            # compile both replicas
        for i in range(6):
            ans = eng.query(_req("k", pool[:8]))
            assert not ans.degraded
        assert eng.stats["hedges"] > 0
        assert eng.stats["hedge_wins"] > 0
        assert eng.stats["dropped"] == 0


def test_real_bug_propagates_not_retried(data):
    x, _ = data
    with mk_engine() as eng:
        table = eng.register("k", x, prewarm=False)

        def boom(*a, **kw):
            raise ZeroDivisionError("real bug, not chaos")

        for r in range(table.n_replicas):
            table.engines[0][r].query = boom
        with pytest.raises(ZeroDivisionError, match="real bug"):
            eng.query(_req("k", jnp.zeros((4, D))))


# -- graceful degradation ------------------------------------------------------


def test_total_shard_loss_yields_certified_answer(data):
    x, pool = data
    chaos = ChaosConfig(events=(ChaosEvent("shard_kill", shard=1),), seed=0)
    with mk_engine(chaos=chaos, max_retries=1,
                   degraded_accuracy=10.0) as eng:
        table = eng.register("k", x, prewarm=False)
        y = pool[:16]
        ans = eng.query(_req("k", y))
        assert ans.degraded and ans.missing_shards == (1,)
        assert ans.live_shards == (0,)
        oracle = np.asarray(ref.sdkde_eval(x, y, table.h, block=256),
                            np.float64)
        actual = np.abs(np.asarray(ans.densities, np.float64)
                        - oracle) / oracle
        bounds = np.asarray(ans.rel_err_bounds, np.float64)
        # the certificate must dominate the realized error, per query
        assert (actual <= bounds + 1e-5).all()
        assert ans.rel_err_bound == pytest.approx(bounds.max())
        # and the caller asked for exactness -> typed refusal instead
        with pytest.raises(ServeError):
            eng.query(_req("k", y, allow_degraded=False))


def test_uncertifiable_degradation_is_refused(data):
    x, pool = data
    chaos = ChaosConfig(events=(ChaosEvent("shard_kill", shard=1),), seed=0)
    with mk_engine(chaos=chaos, max_retries=0,
                   degraded_accuracy=1e-6) as eng:
        eng.register("k", x, prewarm=False)
        with pytest.raises(Degraded) as ei:
            eng.query(_req("k", pool[:8]))
        assert ei.value.bound > ei.value.target == 1e-6
        assert eng.stats["dropped"] == 1


# -- deadlines, shedding, typed errors ----------------------------------------


def test_deadline_exceeded_is_typed(data):
    x, pool = data
    with mk_engine() as eng:
        eng.register("k", x, prewarm=False)
        with pytest.raises(DeadlineExceeded):
            eng.query(_req("k", pool[:8], deadline_s=1e-9))
        assert isinstance(DeadlineExceeded("x"), TimeoutError)


def test_deadline_misses_trigger_tier_shedding(data):
    x, pool = data
    with mk_engine(shed_after_misses=2, shed_requests=3,
                   shed_accuracy=5e-2) as eng:
        eng.register("k", x, prewarm=False)
        eng.query(_req("k", pool[:8]))                       # healthy baseline
        for _ in range(2):
            with pytest.raises(DeadlineExceeded):
                eng.query(_req("k", pool[:8], deadline_s=1e-9))
        ans = eng.query(_req("k", pool[:8]))
        assert ans.shed and ans.precision == "bf16"    # ladder downgrade
        # explicit precision overrides the shed tier
        ans = eng.query(_req("k", pool[:8], precision="f32"))
        assert ans.precision == "f32"
        # the episode ends after shed_requests
        eng.query(_req("k", pool[:8]))
        ans = eng.query(_req("k", pool[:8]))
        assert not ans.shed


def test_unknown_key_and_bad_request(data):
    x, _ = data
    with mk_engine() as eng:
        with pytest.raises(UnknownKey):
            eng.query(_req("nope", jnp.zeros((2, D))))
        assert isinstance(UnknownKey("k"), KeyError)
        eng.register("k", x, prewarm=False)
        with pytest.raises(BadRequest):
            eng.query(_req("k", jnp.zeros((2, D + 1))))      # wrong dim
        with pytest.raises(BadRequest):
            eng.query(_req("k", jnp.zeros((0, D))))          # empty batch


def test_overloaded_when_no_live_replica(data):
    x, pool = data
    chaos = ChaosConfig(events=(ChaosEvent("shard_kill",),), seed=0)
    with mk_engine(chaos=chaos, max_retries=0, allow_degraded=False) as eng:
        eng.register("k", x, prewarm=False)
        with pytest.raises(Overloaded):
            eng.query(_req("k", pool[:8]))


def test_fenced_but_alive_shard_served_as_last_resort(data):
    """Fencing is inferred from missed heartbeats, so a wrongly-fenced
    (stalled-but-alive) shard must be tried before answering degraded:
    the last-resort pass returns the EXACT answer."""
    x, pool = data
    with mk_engine() as eng:
        table = eng.register("k", x, prewarm=False)
        want = np.asarray(eng.query(_req("k", pool[:8])).value)
        R = table.n_replicas
        eng.supervisor.fence(range(R))           # all of shard 0
        ans = eng.query(_req("k", pool[:8]))
        np.testing.assert_allclose(np.asarray(ans.densities), want,
                                   rtol=1e-6)
        assert not ans.degraded and ans.missing_shards == ()
        assert eng.stats["last_resort"] >= 1


# -- fault injector determinism -----------------------------------------------


def _drive(inj: FaultInjector, requests: int = 40):
    fired = []
    for _ in range(requests):
        inj.begin_request()
        for s in range(2):
            for r in range(2):
                with inj.scope(s, r):
                    try:
                        inj.fire("serve.dispatch", key="k")
                        fired.append(0)
                    except Exception:
                        fired.append(1)
    return fired, inj.snapshot()


def test_injector_is_deterministic_in_seed():
    cfg = ChaosConfig(seed=7, shard_kill=0.3)
    f1, s1 = _drive(FaultInjector(cfg))
    f2, s2 = _drive(FaultInjector(cfg))
    assert f1 == f2 and s1 == s2 and s1["shard_kill"] > 0
    f3, s3 = _drive(FaultInjector(ChaosConfig(seed=8, shard_kill=0.3)))
    assert f3 != f1                     # the seed actually matters


# -- soak acceptance (benchmarks/chaos_soak.py) --------------------------------


def test_chaos_soak_acceptance():
    """The CI soak contract at test size: zero dropped queries across a
    kill + recovery arc, bounded tail, certified degraded answers."""
    from benchmarks import chaos_soak

    out = chaos_soak.run_soak(n=512, d=3, requests=18, pace_s=0.002,
                              heartbeat_timeout_s=0.5, seed=0)
    assert out["dropped"] == 0
    assert out["p99_ratio"] < chaos_soak.P99_RATIO_MAX
    deg = chaos_soak.run_degraded(n=512, d=3, requests=3, query_rows=32,
                                  seed=0)
    assert deg["bound_violations"] == 0
    assert deg["rel_err_bound_max"] <= chaos_soak.DEGRADED_ACCURACY
    assert deg["rel_err_actual_max"] <= deg["rel_err_bound_max"] + 1e-5
