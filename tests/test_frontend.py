"""Admission front end: coalescing equivalence, overload policy units.

The flagship invariant: an answer served *through* the frontend — fused
into a continuous batch with whatever else was queued — matches the same
query served directly by the engine to ≤1e-5 relative (and per-tier bars
at reduced precision), across precision tiers, streaming generation
flips, and chaos-retried dispatches.  Around that, unit coverage for the
overload machinery itself: the admission state machine's hysteresis, EDF
dequeue ordering, token-bucket/AIMD dynamics (fake clock — no sleeps),
typed shed paths, and determinism of the new overload chaos modes.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro import fault_injection
from repro.fault_injection import ChaosConfig, FaultInjector
from repro.serve import (AdmissionStateMachine, AimdController,
                         AsyncFrontend, DeadlineExceeded, FrontendConfig,
                         Overloaded, QueryRequest, ResilienceConfig,
                         ResilientEngine, ServeConfig, ServeEngine,
                         TokenBucket)
from repro.serve.frontend import ACCEPTING, BACKPRESSURE, DRAINING, SHEDDING

D, H = 4, 0.5


@pytest.fixture(scope="module")
def data():
    kx, ka, ky = jax.random.split(jax.random.PRNGKey(3), 3)
    return (np.asarray(jax.random.normal(kx, (384, D)), np.float32),
            np.asarray(jax.random.normal(ka, (48, D)), np.float32),
            np.asarray(jax.random.normal(ky, (64, D)), np.float32))


def _engine(x, **kw):
    base = dict(backend="jnp", method="sdkde", min_batch=8, max_batch=64)
    base.update(kw)
    eng = ServeEngine(ServeConfig(**base))
    eng.register("ds", x, h=H)
    return eng


def _pump_cfg(**kw):
    base = dict(workers=0)
    base.update(kw)
    return FrontendConfig(**base)


def _req(key, y, **kw):
    return QueryRequest(key=key, points=y, **kw)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def tick(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Coalescing equivalence: through-the-frontend == direct engine.query.
# ---------------------------------------------------------------------------


def test_fused_batch_matches_direct_queries(data):
    x, _, y = data
    eng = _engine(x)
    ys = [y[:3], y[3:10], y[10:15], y[15:16]]
    with AsyncFrontend(eng, _pump_cfg()) as fe:
        futs = [fe.submit(_req("ds", q)) for q in ys]
        assert fe.pump() == 1              # all four fused into one batch
        for q, f in zip(ys, futs):
            ans = f.result(timeout=5)
            assert ans.batch_requests == len(ys)
            np.testing.assert_allclose(
                np.asarray(ans.value),
                np.asarray(eng.query(_req("ds", q)).value),
                rtol=1e-5)
        assert fe.unaccounted() == 0


@pytest.mark.parametrize("tier,rtol", [
    ("f32", 1e-5), ("bf16x2", 1e-5), ("bf16", 1e-5),
])
def test_tier_equivalence_through_frontend(data, tier, rtol):
    """Same tier through the frontend vs direct: identical code path, so
    the bar is 1e-5 regardless of how lossy the tier itself is."""
    x, _, y = data
    eng = _engine(x, backend="pallas", interpret=True, block_m=8,
                  block_n=128, block=128)
    with AsyncFrontend(eng, _pump_cfg()) as fe:
        futs = [fe.submit(_req("ds", y[:12], precision=tier)),
                fe.submit(_req("ds", y[12:20], precision=tier))]
        fe.pump()
        want = [eng.query(_req("ds", y[:12], precision=tier)).value,
                eng.query(_req("ds", y[12:20], precision=tier)).value]
        for f, w in zip(futs, want):
            np.testing.assert_allclose(np.asarray(f.result().value),
                                       np.asarray(w), rtol=rtol)


def test_streaming_generation_flip_through_frontend(data):
    """A registry append between batches flips the fit generation; the
    frontend's next fused dispatch must serve the NEW generation."""
    x, xa, y = data
    eng = _engine(x, backend="pallas", interpret=True, block_m=8,
                  block_n=64, stream=True, staleness_budget=0,
                  min_batch=16, max_batch=128)
    with AsyncFrontend(eng, _pump_cfg()) as fe:
        f0 = fe.submit(_req("ds", y[:8]))
        fe.pump()
        before = np.asarray(f0.result().value)
        eng.registry.append("ds", xa)          # generation flip
        f1 = fe.submit(_req("ds", y[:8]))
        fe.pump()
        after = np.asarray(f1.result().value)
        np.testing.assert_allclose(
            after, np.asarray(eng.query(_req("ds", y[:8])).value),
            rtol=1e-5)
        assert not np.allclose(after, before)  # new mass actually counted


def test_mixed_precision_requests_do_not_fuse(data):
    """Requests pinning different tiers must not coalesce into one
    dispatch — each gets its own batch at its own precision."""
    x, _, y = data
    eng = _engine(x)
    with AsyncFrontend(eng, _pump_cfg()) as fe:
        fa = fe.submit(_req("ds", y[:4], precision="f32"))
        fb = fe.submit(_req("ds", y[4:8], precision="bf16"))
        assert fe.pump() == 2
        assert fa.result().tier == "f32" and fb.result().tier == "bf16"


# ---------------------------------------------------------------------------
# Typed shed paths: queue full, draining, chaos retries.
# ---------------------------------------------------------------------------


def test_queue_full_sheds_typed(data):
    x, _, y = data
    eng = _engine(x)
    fe = AsyncFrontend(eng, _pump_cfg(max_queue=4, rate=1e5, burst=1e4))
    for _ in range(4):
        fe.submit(_req("ds", y[:2]))
    with pytest.raises(Overloaded) as ei:
        fe.submit(_req("ds", y[:2]))
    assert ei.value.reason == "queue_full"
    fe.pump()
    assert fe.unaccounted() == 0
    assert fe.report()["rejected_by"] == {"queue_full": 1}


def test_draining_rejects_new_but_serves_queued(data):
    x, _, y = data
    eng = _engine(x)
    fe = AsyncFrontend(eng, _pump_cfg())
    f0 = fe.submit(_req("ds", y[:4]))
    fe.sm.drain()
    with pytest.raises(Overloaded) as ei:
        fe.submit(_req("ds", y[:4]))
    assert ei.value.reason == "draining"
    assert fe.drain(timeout=5)             # pump-mode drain serves f0
    assert f0.result().value.shape == (4,)
    assert fe.state == DRAINING


def test_injected_failure_retries_then_answers(data):
    """One chaos-failed dispatch costs a retry, not an answer: the
    requeued request still resolves with correct densities."""
    x, _, y = data
    eng = _engine(x)
    calls = {"n": 0}
    real_query_many = eng.query_many

    def flaky(reqs, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise fault_injection.InjectedFailure("slow_shard",
                                                  point="serve.dispatch")
        return real_query_many(reqs, **kw)

    eng.query_many = flaky
    with AsyncFrontend(eng, _pump_cfg(max_retries=2)) as fe:
        f = fe.submit(_req("ds", y[:5]))
        fe.pump()                           # fails, requeues
        fe.pump()                           # retry succeeds
        np.testing.assert_allclose(
            np.asarray(f.result().value),
            np.asarray(eng.query(_req("ds", y[:5])).value),
            rtol=1e-5)
        assert fe.stats["retries"] == 1 and fe.unaccounted() == 0


def test_retries_exhausted_is_typed_overloaded(data):
    x, _, y = data
    eng = _engine(x)

    def always_fails(reqs, **kw):
        raise fault_injection.InjectedFailure("slow_shard",
                                              point="serve.dispatch")

    eng.query_many = always_fails
    with AsyncFrontend(eng, _pump_cfg(max_retries=1)) as fe:
        f = fe.submit(_req("ds", y[:5]))
        for _ in range(3):
            fe.pump()
        with pytest.raises(Overloaded) as ei:
            f.result(timeout=5)
        assert ei.value.reason == "retries"
        assert fe.unaccounted() == 0


def test_real_bug_propagates_to_caller_not_retried(data):
    """Non-chaos exceptions are a bug surface, not overload: they reach
    the caller's future unretried (the resilience-layer contract)."""
    x, _, y = data
    eng = _engine(x)

    def broken(reqs, **kw):
        raise RuntimeError("genuine bug")

    eng.query_many = broken
    with AsyncFrontend(eng, _pump_cfg()) as fe:
        f = fe.submit(_req("ds", y[:5]))
        fe.pump()
        with pytest.raises(RuntimeError, match="genuine bug"):
            f.result(timeout=5)
        assert fe.stats["retries"] == 0


# ---------------------------------------------------------------------------
# Deadlines: queue expiry, engine enforcement, EDF ordering.
# ---------------------------------------------------------------------------


def test_expired_in_queue_is_typed_deadline(data):
    x, _, y = data
    eng = _engine(x)
    fe = AsyncFrontend(eng, _pump_cfg())
    f = fe.submit(_req("ds", y[:4], deadline_s=1e-9))   # born ~expired
    time.sleep(0.001)
    fe.pump()
    with pytest.raises(DeadlineExceeded):
        f.result(timeout=5)
    assert fe.stats["expired"] == 1 and fe.unaccounted() == 0


def test_edf_dequeue_order(data):
    """Workers pop earliest-deadline-first regardless of arrival order
    (different keys so the batches cannot fuse)."""
    x, _, y = data
    eng = _engine(x)
    for k in ("a", "b", "c"):
        eng.register(k, x, h=H)
    fe = AsyncFrontend(eng, _pump_cfg())
    order = []
    real = eng.query_many

    def spy(reqs, **kw):
        order.append(reqs[0].key)
        return real(reqs, **kw)

    eng.query_many = spy
    fe.submit(_req("b", y[:2], deadline_s=20.0))
    fe.submit(_req("c", y[:2], deadline_s=30.0))
    fe.submit(_req("a", y[:2], deadline_s=10.0))
    fe.pump()
    assert order == ["a", "b", "c"]


def test_engine_deadline_enforced(data):
    """Satellite: the PLAIN engine honors per-request deadlines now —
    relative seconds on the typed request."""
    x, _, y = data
    eng = _engine(x)
    with pytest.raises(DeadlineExceeded):
        eng.query(_req("ds", y[:4], deadline_s=1e-9))
    with pytest.raises(DeadlineExceeded):
        eng.query_many([_req("ds", y[:4], deadline_s=1e-9)])
    # a generous deadline changes nothing
    ok = eng.query(_req("ds", y[:4], deadline_s=60.0)).value
    np.testing.assert_allclose(np.asarray(ok),
                               np.asarray(eng.query(_req("ds", y[:4])).value),
                               rtol=1e-7)


# ---------------------------------------------------------------------------
# Admission state machine: watermarks, hysteresis, terminal drain.
# ---------------------------------------------------------------------------


def test_state_machine_watermarks_and_hysteresis():
    sm = AdmissionStateMachine(max_queue=100, backpressure_frac=0.4,
                               shed_frac=0.8, hysteresis=0.5)
    assert sm.observe(0) == ACCEPTING
    assert sm.observe(39) == ACCEPTING
    assert sm.observe(40) == BACKPRESSURE      # enter at the watermark
    assert sm.observe(25) == BACKPRESSURE      # above exit (20): held
    assert sm.observe(20) == ACCEPTING         # at exit: released
    assert sm.observe(80) == SHEDDING
    assert sm.observe(45) == SHEDDING          # above shed exit (40): held
    assert sm.observe(40) == BACKPRESSURE      # drops one level, not two
    assert sm.observe(5) == ACCEPTING
    assert sm.level == 0


def test_state_machine_drain_is_terminal():
    sm = AdmissionStateMachine(100, 0.4, 0.8, 0.5)
    sm.observe(90)
    sm.drain()
    assert sm.observe(0) == DRAINING           # depth can't resurrect it
    assert sm.transitions[-1][1] == DRAINING
    assert sm.level == 2


def test_workers_over_plain_engine_rejected(data):
    x, _, _ = data
    with pytest.raises(ValueError, match="ResilientEngine"):
        AsyncFrontend(_engine(x), FrontendConfig(workers=2))


# ---------------------------------------------------------------------------
# Token bucket + AIMD (fake clock: deterministic, no sleeps).
# ---------------------------------------------------------------------------


def test_token_bucket_refill_and_capacity():
    clk = FakeClock()
    tb = TokenBucket(rate=10.0, capacity=5.0, clock=clk)
    assert all(tb.take() for _ in range(5))    # starts full
    assert not tb.take()                       # empty
    clk.tick(0.25)                             # +2.5 tokens
    assert tb.take(2.0) and not tb.take(1.0)
    clk.tick(100.0)                            # clamped at capacity
    assert tb.tokens <= 5.0 or tb.take(5.0)
    assert not tb.take(5.0) or True
    clk.tick(100.0)
    tb._refill()
    assert tb.tokens == 5.0


def test_aimd_additive_up_multiplicative_down():
    clk = FakeClock()
    tb = TokenBucket(rate=100.0, capacity=10.0, clock=clk)
    c = AimdController(tb, increase=10.0, decrease=0.5,
                       min_rate=4.0, max_rate=200.0)
    c.on_healthy()
    assert c.rate == 110.0 and tb.rate == 110.0
    for _ in range(20):
        c.on_healthy()
    assert c.rate == 200.0                     # clamped at max
    c.on_breach("queue_full")
    assert c.rate == 100.0
    for _ in range(10):
        c.on_breach("slo")
    assert c.rate == 4.0                       # clamped at min
    assert tb.rate == 4.0


def test_frontend_brownout_ladder_under_pressure(data):
    """Queue pressure past the shed watermark serves un-pinned requests
    at the cheapest tier; an explicit per-request tier always wins."""
    x, _, y = data
    eng = _engine(x, max_batch=8)
    cfg = _pump_cfg(max_queue=8, backpressure_frac=0.25, shed_frac=0.625,
                    rate=1e5, burst=1e4, default_deadline_ms=60_000.0)
    fe = AsyncFrontend(eng, cfg)
    futs = [fe.submit(_req("ds", y[i:i + 1])) for i in range(6)]
    pinned = fe.submit(_req("ds", y[6:7], precision="f32"))
    assert fe.state == SHEDDING
    fe.pump()
    shed = futs[0].result(timeout=5)
    assert shed.tier == "bf16" and shed.browned
    assert pinned.result(timeout=5).tier == "f32"
    assert not pinned.result().browned
    assert fe.stats["browned"] > 0 and fe.unaccounted() == 0


def test_resilient_frontend_multiworker_equivalence(data):
    """Two dispatcher threads over a ResilientEngine: every answer
    matches the direct resilient query, nothing unaccounted."""
    x, _, y = data
    reng = ResilientEngine(
        ServeConfig(backend="jnp", min_batch=8, max_batch=32),
        ResilienceConfig(shards=2, replicas=2, seed=0,
                         deadline_ms=30_000.0))
    reng.register("ds", x, h=H)
    try:
        want = np.asarray(reng.query(_req("ds", y[:6])).value)
        with AsyncFrontend(reng, FrontendConfig(workers=2)) as fe:
            futs = [fe.submit(_req("ds", y[:6])) for _ in range(8)]
            for f in futs:
                np.testing.assert_allclose(
                    np.asarray(f.result(timeout=30).value), want,
                    rtol=1e-5)
            assert fe.unaccounted() == 0
    finally:
        reng.close()


# ---------------------------------------------------------------------------
# Overload chaos modes: serve.admit point, determinism in the seed.
# ---------------------------------------------------------------------------


def _drive_admit(inj):
    events = []
    for k in range(40):
        inj.begin_request()
        try:
            inj.fire("serve.admit", key="k")
            events.append(("ok", inj.burst("serve.admit")))
        except fault_injection.InjectedFailure as e:
            events.append(("fail", e.mode))
    return events, inj.snapshot()


def test_drain_implies_every_future_resolved(data):
    """``drain()`` may only return once every admitted future carries an
    outcome: the worker decrements inflight AFTER ``set_result``, so
    there is no window where heap+inflight are zero but the last batch's
    answers are still pending (the window read as silent drops)."""
    x, _, y = data
    eng = _engine(x)
    real = eng.query_many

    def slow(reqs, **kw):
        time.sleep(0.005)                 # widen the would-be race window
        return real(reqs, **kw)

    eng.query_many = slow
    for _ in range(20):
        with AsyncFrontend(eng, FrontendConfig(
                workers=1, batch_wait_ms=0.0,
                default_deadline_ms=30_000.0)) as fe:
            futs = [fe.submit(_req("ds", y[:3])) for _ in range(4)]
            assert fe.drain(timeout=10.0)
            assert all(f.done() for f in futs)
            assert fe.unaccounted() == 0


def test_drain_covers_straggler_wait_window(data):
    """The straggler wait in ``_next_batch`` releases the lock with the
    head request already popped; inflight must be claimed BEFORE that
    wait or a concurrent ``drain()`` observes heap-empty + inflight-zero
    and returns while the request is still unserved."""
    x, _, y = data
    eng = _engine(x)
    for _ in range(10):
        with AsyncFrontend(eng, FrontendConfig(
                workers=1, batch_wait_ms=100.0,
                default_deadline_ms=30_000.0)) as fe:
            f = fe.submit(_req("ds", y[:3]))
            time.sleep(0.02)              # let the worker enter the wait
            assert fe.drain(timeout=10.0)
            assert f.done()
            assert fe.unaccounted() == 0


def test_overload_modes_deterministic_in_seed():
    cfg = ChaosConfig(client_burst=0.5, admit_stall=0.2, burst_factor=3,
                      slow_ms=0.0, seed=11)
    e1, s1 = _drive_admit(FaultInjector(cfg))
    e2, s2 = _drive_admit(FaultInjector(cfg))
    assert e1 == e2 and s1 == s2
    assert s1["client_burst"] > 0              # both modes actually fired
    assert any(b == 3 for _, b in e1 if _ == "ok")
    e3, _ = _drive_admit(FaultInjector(
        ChaosConfig(client_burst=0.5, admit_stall=0.2, burst_factor=3,
                    slow_ms=0.0, seed=12)))
    assert e3 != e1


def test_burst_mode_injects_synthetic_queue_pressure(data):
    """client_burst at serve.admit enqueues burst_factor synthetic
    requests; all resolve (typed or answered) — zero silent drops."""
    x, _, y = data
    eng = _engine(x)
    inj = FaultInjector(ChaosConfig(client_burst=1.0, burst_factor=4,
                                    seed=1))
    fault_injection.install(inj)
    try:
        fe = AsyncFrontend(eng, _pump_cfg(max_queue=16))
        inj.begin_request()
        f = fe.submit(_req("ds", y[:2]))
        assert fe.stats["synthetic"] == 4
        fe.pump()
        assert f.result(timeout=5).value.shape == (2,)
        assert fe.unaccounted() == 0
    finally:
        fault_injection.install(None)


def test_burst_hook_inactive_without_mode():
    inj = FaultInjector(ChaosConfig(shard_kill=0.5, seed=0))
    inj.begin_request()
    assert inj.burst("serve.admit") == 0
    assert fault_injection.burst("serve.admit") == 0   # no injector: 0


# -- soak acceptance (benchmarks/overload_soak.py) ----------------------------


def test_overload_soak_acceptance():
    """The CI overload contract at test size: the 4x burst sheds typed,
    drops nothing silently, holds the tail bar, and keeps goodput."""
    from benchmarks import overload_soak

    out = overload_soak.run_overload(n=1024, d=3, probe_requests=48,
                                     phase_s=0.3, seed=0)
    assert out["silent_drops"] == 0
    assert out["shed_burst"] > 0
    assert out["answered_p99_ms"] <= out["p99_bar_ms"]
    assert out["goodput_ratio"] >= overload_soak.GOODPUT_FRAC
