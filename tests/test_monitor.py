"""Activation-density OOD monitor: separates in- from out-of-distribution."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monitor import ActivationMonitor, pool_activations


def test_pooling_shape():
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 64))
    assert pool_activations(h).shape == (4, 64)


def test_monitor_flags_ood():
    key = jax.random.PRNGKey(0)
    d = 64
    ref = jax.random.normal(key, (2000, d))                # in-distribution
    mon = ActivationMonitor(proj_dim=8, quantile=0.02).fit(ref)

    in_dist = jax.random.normal(jax.random.fold_in(key, 1), (200, d))
    ood = jax.random.normal(jax.random.fold_in(key, 2), (200, d)) * 4 + 6

    flags_in = np.asarray(mon.flag(in_dist))
    flags_ood = np.asarray(mon.flag(ood))
    assert flags_in.mean() < 0.15, flags_in.mean()
    assert flags_ood.mean() > 0.9, flags_ood.mean()

    # scores are ordered: in-distribution scores higher on average
    s_in = np.asarray(mon.score(in_dist)).mean()
    s_ood = np.asarray(mon.score(ood)).mean()
    assert s_in > s_ood + 5.0


def test_monitor_end_to_end_with_lm():
    """Wire the monitor to real model activations (reduced config)."""
    import dataclasses

    from repro.configs import get_arch
    from repro.data.synthetic import lm_batch
    from repro.models.common import init_params
    from repro.models.transformer import forward_hidden

    arch = get_arch("gemma2_2b")
    cfg = arch.model.reduced(dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))

    def acts(batch):
        h, _ = forward_hidden(params, batch["tokens"], cfg)
        return pool_activations(h)

    ref = acts(lm_batch(cfg, 0, 0, 32, 16))
    mon = ActivationMonitor(proj_dim=4, quantile=0.05).fit(ref)
    scores = mon.score(acts(lm_batch(cfg, 0, 1, 8, 16)))
    assert np.isfinite(np.asarray(scores)).all()
