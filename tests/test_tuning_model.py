"""Kernel performance model (kernels/tuning.py) sanity + paper anchors."""

import pytest

from repro.kernels.tuning import (
    VMEM_BUDGET,
    best_blocks,
    pair_pass_cost,
    sdkde_device_cost,
    sweep_blocks,
)


def test_byte_model_anchors_paper_coefficient():
    """§4.1: Bytes₁₆(k) ≈ 1.13 k² at the paper's (64, 1024) blocks.

    Our ledger amortizes row-tile loads over the column sweep (the paper
    re-counts them per tile), so we anchor slightly below: ~1.07 k².
    """
    c = pair_pass_cost(32768, 32768, 16, block_m=64, block_n=1024,
                       out_width=17)
    coef = c.hbm_bytes / 32768**2
    assert 1.0 < coef < 1.2, coef


def test_flops_match_paper_model():
    from repro.analysis.flops import sdkde_flops

    n, m, d = 32768, 4096, 16
    s = pair_pass_cost(n, n, d, block_m=64, block_n=1024, out_width=d + 1)
    k = pair_pass_cost(m, n, d, block_m=64, block_n=1024, out_width=1)
    total = (s.mxu_flops + s.exp_count * 8 + s.vpu_flops
             + k.mxu_flops + k.exp_count * 8 + k.vpu_flops)
    # within 15% of the paper's aggregate (scalar-op bookkeeping differs)
    paper = sdkde_flops(n, d, n_test=m)
    assert abs(total - paper) / paper < 0.15, (total, paper)


def test_sweep_respects_vmem_budget():
    for c in sweep_blocks(65536, 65536, 16, out_width=17):
        assert c.vmem_bytes <= VMEM_BUDGET


def test_bigger_row_blocks_cut_hbm():
    small = pair_pass_cost(65536, 65536, 16, block_m=64, block_n=1024)
    big = pair_pass_cost(65536, 65536, 16, block_m=1024, block_n=1024)
    assert big.hbm_bytes < small.hbm_bytes / 4


def test_device_cost_uses_block_partition():
    """Per-device pairs must be n²/chips (the §Perf iteration-2 fix)."""
    s, k = sdkde_device_cost(1048576, 131072, 16, chips=256)
    assert s.exp_count == pytest.approx(1048576**2 / 256)
    assert k.exp_count == pytest.approx(131072 * 1048576 / 256)


def test_kernel_path_is_vpu_bound_at_1m():
    """The §Perf conclusion: on v5e the flash kernel is exp-bound."""
    s, k = sdkde_device_cost(1048576, 131072, 16, chips=256,
                             block_m=1024, block_n=2048)
    assert s.bound == "vpu"
    assert s.t_vpu > 3 * s.t_hbm


def test_selective_scan_kernel_byte_advantage():
    """falcon-mamba prefill: kernel traffic ≥8× below the XLA path."""
    from repro.kernels.tuning import selective_scan_bytes

    kern, xla = selective_scan_bytes(2, 32768, 8192, 16)
    assert xla / kern > 8, (kern, xla)


def test_best_blocks_returns_feasible_minimum():
    best = best_blocks(65536, 65536, 16, out_width=17)
    assert best.vmem_bytes <= VMEM_BUDGET
    assert best.step_time <= sweep_blocks(65536, 65536, 16,
                                          out_width=17)[-1].step_time
