"""Pallas-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Every kernel × a sweep of shapes (including non-tile-multiple row counts,
which exercise the sentinel padding) × dtypes, in interpret mode (CPU
executes the kernel body in Python — the brief's validation mode).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (64, 16, 8),        # n, m, d  — tiny
    (300, 50, 16),      # non-multiples: padding path
    (513, 129, 16),     # prime-ish
    (1024, 128, 4),     # d not 16
    (256, 256, 32),     # larger d
    (128, 64, 1),       # 1-D (the appendix setting)
]

BLOCKS = [(32, 64), (128, 128)]


def _data(n, m, d, dtype=jnp.float32, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, d), jnp.float32).astype(dtype)
    y = jax.random.normal(ky, (m, d), jnp.float32).astype(dtype) * 1.2
    return x, y


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("bm,bn", BLOCKS)
def test_flash_kde_matches_ref(n, m, d, bm, bn):
    x, y = _data(n, m, d)
    h = 0.7
    got = ops.flash_kde(x, y, h, block_m=bm, block_n=bn, interpret=True)
    # normalize the oracle the same way
    from repro.core.bandwidth import gaussian_norm_const

    want = ref.ref_kde_sums(x, y, h) / (n * gaussian_norm_const(d, 1.0) * h**d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-9)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_flash_laplace_matches_ref(n, m, d):
    x, y = _data(n, m, d, seed=1)
    h = 0.9
    from repro.core.bandwidth import gaussian_norm_const

    norm = n * gaussian_norm_const(d, 1.0) * h**d
    got = ops.flash_laplace_kde(x, y, h, block_m=32, block_n=64,
                                interpret=True)
    want = ref.ref_laplace_sums(x, y, h) / norm
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-7)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_nonfused_laplace_matches_fused(n, m, d):
    """Fusion is an implementation detail, not an estimator change (§5)."""
    x, y = _data(n, m, d, seed=2)
    h = 0.8
    fused = ops.flash_laplace_kde(x, y, h, block_m=32, block_n=64,
                                  interpret=True)
    nonfused = ops.laplace_kde_nonfused(x, y, h, block_m=32, block_n=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(nonfused),
                               rtol=2e-4, atol=1e-7)


@pytest.mark.parametrize("n,d", [(64, 8), (300, 16), (513, 16), (128, 1)])
@pytest.mark.parametrize("bm,bn", BLOCKS)
def test_flash_score_stats_matches_ref(n, d, bm, bn):
    x, _ = _data(n, 1, d, seed=3)
    h = 0.6
    s0, s1 = ops.flash_score_stats(x, h, block_m=bm, block_n=bn,
                                   interpret=True)
    r0, r1 = ref.ref_score_stats(x, h)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(r0), rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(r1),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("n,d", [(128, 16), (300, 8)])
def test_flash_sdkde_shift_matches_ref(n, d):
    x, _ = _data(n, 1, d, seed=4)
    h = 0.5
    got = ops.flash_sdkde_shift(x, h, block_m=32, block_n=64, interpret=True)
    want = ref.ref_sdkde_shift(x, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kde_dtypes(dtype):
    """bf16 inputs, f32 MXU accumulation — the mixed-precision path."""
    x, y = _data(256, 64, 16, dtype=dtype, seed=5)
    h = 0.8
    got = ops.flash_kde(x, y, h, block_m=32, block_n=64, interpret=True)
    x32, y32 = x.astype(jnp.float32), y.astype(jnp.float32)
    from repro.core.bandwidth import gaussian_norm_const

    want = ref.ref_kde_sums(x32, y32, h) / (
        256 * gaussian_norm_const(16, 1.0) * h**16
    )
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol)


def test_full_pipeline_matches_reference_path():
    """flash_sdkde (pallas) == core.kde.sdkde_eval (streaming jnp GEMM)."""
    from repro.core import kde

    x, y = _data(300, 77, 16, seed=6)
    h = 0.6
    got = ops.flash_sdkde(x, y, h, block_m=32, block_n=64, interpret=True)
    want = kde.sdkde_eval(x, y, h, block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4)


def test_vmem_budget_rejects_oversized_tiles():
    with pytest.raises(ValueError, match="VMEM"):
        ops.flash_kde(jnp.zeros((1024, 16)), jnp.zeros((64, 16)), 1.0,
                      block_m=2048, block_n=2048, interpret=True)
