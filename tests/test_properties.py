"""Hypothesis property tests on the estimator invariants (DESIGN.md §8).

  * normalization: ∫p̂ ≈ 1 (grid in 1-D, importance sampling in d-D)
  * translation / scale equivariance of the density
  * permutation invariance in the training set
  * the score-shift identity Σ_j (x_i−x_j)φ_ij = x_i·S0_i − S1_i
    (the GEMM re-ordering the whole paper rests on)
  * SD-KDE == KDE on oracle-score data with zero score
  * Laplace fused ≡ non-fused
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import kde
from repro.core.bandwidth import silverman_bandwidth

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _points(seed, n, d, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n, d))


@given(seed=st.integers(0, 2**16), n=st.integers(16, 128),
       d=st.sampled_from([1, 2, 8, 16]))
def test_score_shift_identity(seed, n, d):
    """Σ_j (x_i−x_j)φ_ij == x_i S0_i − S1_i — Section 4's identity."""
    x = _points(seed, n, d)
    h = 0.7
    s0, s1 = kde.score_stats(x, x, h, block=32)
    # naive left side
    diff = x[:, None, :] - x[None, :, :]
    phi = jnp.exp(-jnp.sum(diff**2, -1) / (2 * h * h))
    lhs = jnp.einsum("ijd,ij->id", diff, phi)
    rhs = x * s0[:, None] - s1
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-4)


@given(seed=st.integers(0, 2**16))
def test_normalization_1d(seed):
    x = _points(seed, 200, 1)
    h = float(silverman_bandwidth(x))
    grid = jnp.linspace(-8, 8, 4001)[:, None]
    p = kde.kde_eval(x, grid, h, block=64)
    integral = float(jnp.sum(p) * (16 / 4000))
    assert abs(integral - 1.0) < 2e-2

    p_lc = kde.laplace_kde_eval(x, grid, h, block=64)
    integral_lc = float(jnp.sum(p_lc) * (16 / 4000))
    # Laplace correction integrates to 1 too (∫ΔK = 0)
    assert abs(integral_lc - 1.0) < 2e-2

    p_sd = kde.sdkde_eval(x, grid, h, block=64)
    integral_sd = float(jnp.sum(p_sd) * (16 / 4000))
    assert abs(integral_sd - 1.0) < 2e-2


@given(seed=st.integers(0, 2**16),
       shift=st.floats(-5, 5, allow_nan=False),
       scale=st.floats(0.5, 3.0, allow_nan=False))
def test_translation_scale_equivariance(seed, shift, scale):
    """p̂_{aX+b}(a y + b) = p̂_X(y) / a^d for every estimator."""
    d = 2
    x = _points(seed, 100, d)
    y = _points(seed + 1, 20, d)
    h = 0.6
    for fn in (kde.kde_eval, kde.laplace_kde_eval, kde.sdkde_eval):
        p1 = fn(x, y, h, block=32)
        p2 = fn(scale * x + shift, scale * y + shift, scale * h, block=32)
        np.testing.assert_allclose(
            np.asarray(p2) * scale**d, np.asarray(p1), rtol=5e-3, atol=1e-7
        )


@given(seed=st.integers(0, 2**16))
def test_permutation_invariance(seed):
    x = _points(seed, 64, 4)
    y = _points(seed + 1, 16, 4)
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 2), 64)
    h = 0.8
    p1 = kde.sdkde_eval(x, y, h, block=16)
    p2 = kde.sdkde_eval(x[perm], y, h, block=16)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-4, atol=1e-8)


@given(seed=st.integers(0, 2**16))
def test_laplace_fused_equals_nonfused(seed):
    x = _points(seed, 90, 8)
    y = _points(seed + 1, 30, 8)
    h = 0.7
    p1 = kde.laplace_kde_eval(x, y, h, block=32)
    p2 = kde.laplace_kde_eval_nonfused(x, y, h, block=32)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-4, atol=1e-8)


@given(seed=st.integers(0, 2**16))
def test_oracle_zero_score_reduces_to_kde(seed):
    """With ŝ ≡ 0 the SD shift is the identity: SD-KDE == KDE."""
    x = _points(seed, 80, 4)
    y = _points(seed + 1, 20, 4)
    h = 0.6
    p_sd = kde.sdkde_eval_oracle(x, y, h, lambda z: jnp.zeros_like(z),
                                 block=32)
    p = kde.kde_eval(x, y, h, block=32)
    np.testing.assert_allclose(np.asarray(p_sd), np.asarray(p), rtol=1e-5)


@given(seed=st.integers(0, 2**16), block=st.sampled_from([16, 32, 64, 1024]))
def test_streaming_block_size_irrelevant(seed, block):
    """The streaming accumulation must be block-size invariant."""
    x = _points(seed, 130, 8)
    y = _points(seed + 1, 25, 8)
    h = 0.75
    p_ref = kde.kde_eval_naive(x, y, h)
    p = kde.kde_eval(x, y, h, block=block)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref), rtol=2e-4)


def test_padding_sentinel_is_exact_zero():
    """exp(-‖pad − x‖²/2h²) must underflow to exactly 0.0 in f32."""
    x = jnp.array([[kde.PAD_VALUE] * 4])
    y = jnp.zeros((1, 4))
    phi = jnp.exp(-jnp.sum((x - y) ** 2) / (2.0 * 100.0**2))
    assert float(phi) == 0.0
