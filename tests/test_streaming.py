"""repro.stream: incremental fit, generations, staleness, rebuild policy.

The flagship invariant (acceptance criterion): after ANY interleaving of
appends and evictions, served densities match a from-scratch refit over
the surviving live set to ≤1e-5 relative (f32, exact eps=0 pruning), and
the same interleaving at reduced precision tiers meets each tier's
documented accuracy bar.  Everything runs at small sizes with tiny
interpret-mode tiles, like the rest of the tier-1 suite.
"""

import threading

import jax
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import kde as ref
from repro.core.estimator import SDKDE, EstimatorConfig
from repro.kernels import ops, spatial
from repro.serve import QueryRequest, ServeConfig, ServeEngine
from repro.stream import StreamConfig, StreamingSDKDE, delta


def _q(eng, key, y, **kw):
    """One typed query, densities out."""
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

D, H = 4, 0.5


@pytest.fixture(scope="module")
def data():
    kx, ka, ky = jax.random.split(jax.random.PRNGKey(0), 3)
    return (np.asarray(jax.random.normal(kx, (512, D)), np.float32),
            np.asarray(jax.random.normal(ka, (64, D)), np.float32),
            np.asarray(jax.random.normal(ky, (128, D)), np.float32))


def _serve_cfg(**kw):
    base = dict(backend="pallas", method="sdkde", interpret=True,
                block_m=8, block_n=64, min_batch=16, max_batch=128,
                stream=True, staleness_budget=0)
    base.update(kw)
    return ServeConfig(**base)


def _refit_eval(x_live, y, method="sdkde"):
    fn = {"kde": ref.kde_eval, "sdkde": ref.sdkde_eval,
          "laplace": ref.laplace_kde_eval}[method]
    return np.asarray(fn(jnp.asarray(x_live), jnp.asarray(y), H, block=256))


# ---------------------------------------------------------------------------
# The delta score pass (stream.delta).
# ---------------------------------------------------------------------------


def test_cross_stats_matches_reference_score_pass(data):
    x, _, _ = data
    s0, s1 = delta.initial_stats(x, H, block=100)   # odd block: remainders
    r0, r1 = ref.score_stats(jnp.asarray(x), jnp.asarray(x), H, block=128)
    np.testing.assert_allclose(s0, np.asarray(r0), rtol=1e-5)
    np.testing.assert_allclose(s1, np.asarray(r1), rtol=1e-5, atol=1e-5)


def test_append_then_evict_roundtrips_stats(data):
    x, xa, _ = data
    s0, s1 = delta.initial_stats(x, H)
    ds0, ds1, _, _ = delta.append_delta(x, xa, H)
    es0, es1 = delta.evict_delta(x, xa, H)
    # f64 accumulation: the += / -= cancel to f64 rounding, not f32 drift
    np.testing.assert_allclose(s0 + ds0 - es0, s0, rtol=1e-12)
    np.testing.assert_allclose(s1 + ds1 - es1, s1, rtol=1e-12, atol=1e-12)


def test_append_delta_includes_within_batch_terms(data):
    """Grown-set stats == old stats + append deltas, including the new
    points' within-batch and self (φ=1) terms."""
    x, xa, _ = data
    want0, want1 = delta.initial_stats(np.concatenate([x, xa]), H)
    base0, base1 = delta.initial_stats(x, H)
    ds0, ds1, s0n, s1n = delta.append_delta(x, xa, H)
    np.testing.assert_allclose(np.concatenate([base0 + ds0, s0n]),
                               want0, rtol=1e-10)
    np.testing.assert_allclose(np.concatenate([base1 + ds1, s1n]),
                               want1, rtol=1e-10, atol=1e-12)


# ---------------------------------------------------------------------------
# Slack layouts + incremental placement (kernels.spatial).
# ---------------------------------------------------------------------------


def test_cluster_capacities_reserve_slack():
    labels = np.array([0] * 10 + [1] * 100 + [2] * 3)
    starts, caps = spatial.cluster_capacities(labels, 16, slack=0.5)
    sizes = np.array([10, 100, 3])
    assert (caps >= sizes + np.ceil(sizes * 0.5)).all()
    assert (caps % 16 == 0).all()
    assert (np.diff(starts) == caps[:-1]).all() and starts[0] == 0
    # slack=0 reproduces the legacy geometry (empty cluster -> 0 rows)
    _, caps0 = spatial.cluster_capacities(np.array([0, 2, 2]), 8, slack=0.0,
                                          n_clusters=4)
    assert caps0.tolist() == [8, 0, 8, 0]


def test_slack_layout_roundtrip_and_placement(data):
    x, xa, _ = data
    index = spatial.build_index(jnp.asarray(x), n_clusters=4, seed=0)
    labels = np.asarray(index.labels)
    layout = spatial.cluster_layout(jnp.asarray(x), labels, 16, slack=0.5)
    np.testing.assert_array_equal(
        np.asarray(layout.points)[np.asarray(layout.slots)], x)
    starts, caps = spatial.cluster_capacities(labels, 16, slack=0.5)
    real = np.asarray(layout.real).copy()
    lab_new = np.asarray(spatial.assign(jnp.asarray(xa), index))
    slots = spatial.place_points(real, lab_new, starts, caps)
    assert slots is not None
    assert not real[slots].any()                      # claimed free slots only
    for s, c in zip(slots, lab_new):                  # inside the right slab
        assert starts[c] <= s < starts[c] + caps[c]
    assert len(np.unique(slots)) == len(slots)
    # exhaust one cluster's slab -> overflow signal
    tight_real = np.ones_like(real)
    assert spatial.place_points(tight_real, lab_new[:1], starts, caps) is None


def test_tile_metadata_update_matches_full_rebuild(data):
    x, xa, _ = data
    index = spatial.build_index(jnp.asarray(x), n_clusters=4, seed=0)
    labels = np.asarray(index.labels)
    layout = spatial.cluster_layout(jnp.asarray(x), labels, 16, slack=0.5)
    xp = np.asarray(layout.points).copy()
    real = np.asarray(layout.real).copy()
    meta = spatial.tile_metadata(jnp.asarray(xp), jnp.asarray(real), block=16)
    # mutate two tiles' worth of rows, update just those tiles
    xp[:16] = xa[:16]
    real[:16] = True
    xp[32:40] = xa[16:24]
    real[32:40] = True
    upd = spatial.tile_metadata_update(meta, jnp.asarray(xp),
                                       jnp.asarray(real), [0, 2], block=16)
    full = spatial.tile_metadata(jnp.asarray(xp), jnp.asarray(real), block=16)
    for f in spatial.TileMeta._fields:
        np.testing.assert_array_equal(np.asarray(getattr(upd, f)),
                                      np.asarray(getattr(full, f)))
    # untouched tiles carried over bit-for-bit
    for f in spatial.TileMeta._fields:
        np.testing.assert_array_equal(np.asarray(getattr(upd, f))[1],
                                      np.asarray(getattr(meta, f))[1])


def test_update_train_columns_matches_fresh_prepare(data):
    x, xa, _ = data
    for tier in ("f32", "bf16x2"):
        cols = ops.prepare_train_columns(jnp.asarray(x), block_n=64,
                                         precision=tier, clustered=True)
        xp = np.full((cols.xt.shape[1], D), ops.PAD_VALUE, np.float32)
        # reconstruct the layout's points from the prepared planes is
        # lossy at reduced tiers; rebuild the layout directly instead
        labels = np.asarray(cols.index.labels)
        layout = spatial.cluster_layout(jnp.asarray(x), labels, 64)
        xp = np.asarray(layout.points).copy()
        real = np.asarray(layout.real).copy()
        # swap some rows of tile 0 and refresh it
        xp[:8] = xa[:8]
        real[:8] = True
        upd = ops.update_train_columns(cols, jnp.asarray(xp),
                                       jnp.asarray(real), [0, 0],
                                       precision=tier)   # repeats are ok
        fresh = ops.columns_from_layout(jnp.asarray(xp), jnp.asarray(real),
                                        cols.index, block_n=64,
                                        precision=tier)
        np.testing.assert_array_equal(np.asarray(upd.xt),
                                      np.asarray(fresh.xt))
        if tier == "bf16x2":
            np.testing.assert_array_equal(np.asarray(upd.xt_lo),
                                          np.asarray(fresh.xt_lo))
        np.testing.assert_array_equal(np.asarray(upd.nrm_x),
                                      np.asarray(fresh.nrm_x))
        for f in spatial.TileMeta._fields:
            np.testing.assert_array_equal(np.asarray(getattr(upd.meta, f)),
                                          np.asarray(getattr(fresh.meta, f)))


# ---------------------------------------------------------------------------
# StreamingSDKDE: the acceptance-criterion interleavings.
# ---------------------------------------------------------------------------


def test_interleaved_updates_match_refit_exact_pruning(data):
    """Appends/evictions in every order vs from-scratch refit, f32 eps=0."""
    x, xa, y = data
    cfg = _serve_cfg(prune=0.0)          # exact pruning on every dispatch
    eng = ServeEngine(cfg)
    eng.register("ds", x, h=H)
    ids0 = eng.registry.append("ds", xa[:32])
    eng.registry.evict_ids("ds", ids0[:8])
    eng.registry.append("ds", xa[32:])
    eng.registry.evict_ids("ds", np.arange(16))       # oldest originals
    eng.registry.append("ds", xa[:4])                 # duplicates are fine
    got = np.asarray(_q(eng, "ds", y))
    live = np.concatenate([x[16:], xa[8:32], xa[32:], xa[:4]])
    want = _refit_eval(live, y)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))
    st = eng.registry.get("ds").stream
    assert st.n_live == live.shape[0]
    snap = st.snapshot()
    assert snap.affected_tiles <= snap.total_tiles


@pytest.mark.parametrize("tier,rtol,atol_frac", [
    ("f32", 1e-5, 1e-6), ("bf16", 5e-2, 5e-3), ("bf16x2", 5e-4, 1e-5),
])
def test_streaming_matches_refit_across_precision_tiers(data, tier, rtol,
                                                        atol_frac):
    x, xa, y = data
    eng = ServeEngine(_serve_cfg(precision=tier))
    eng.register("ds", x, h=H)
    ids = eng.registry.append("ds", xa)
    eng.registry.evict_ids("ds", ids[::2])
    got = np.asarray(_q(eng, "ds", y))
    live = np.concatenate([x, xa[1::2]])
    want = _refit_eval(live, y)
    np.testing.assert_allclose(got, want, rtol=rtol,
                               atol=atol_frac * float(want.max()))


@pytest.mark.parametrize("method", ["kde", "laplace"])
def test_streaming_methods_without_stats(data, method):
    x, xa, y = data
    eng = ServeEngine(_serve_cfg(method=method))
    eng.register("ds", x, h=H)
    eng.registry.slide("ds", xa)          # sliding window: append + evict
    got = np.asarray(_q(eng, "ds", y))
    live = np.concatenate([x[len(xa):], xa])
    want = _refit_eval(live, y, method)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(np.abs(want).max()))


def test_staleness_budget_serves_stale_then_flushes(data):
    x, xa, y = data
    eng = ServeEngine(_serve_cfg(staleness_budget=2))
    eng.register("ds", x, h=H)
    q0 = np.asarray(_q(eng, "ds", y))
    eng.registry.append("ds", xa[:16])                 # gen 1
    q1 = np.asarray(_q(eng, "ds", y))                # within budget
    np.testing.assert_array_equal(q0, q1)              # stale gen served
    eng.registry.append("ds", xa[16:32])               # gen 2
    eng.registry.append("ds", xa[32:])                 # gen 3 > budget
    q2 = np.asarray(_q(eng, "ds", y))                # must flush
    want = _refit_eval(np.concatenate([x, xa]), y)
    np.testing.assert_allclose(q2, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))
    s = eng.staleness_summary()
    assert s["max"] >= 1 and s["count"] == 3


def test_value_generations_reuse_executables_rebuild_invalidates(data):
    """Appends that keep the layout shape must NOT rebuild executables;
    only a layout rebuild (epoch bump) builds new ones."""
    x, xa, y = data
    eng = ServeEngine(_serve_cfg())
    eng.register("ds", x, h=H)
    _q(eng, "ds", y[:16])
    misses0 = eng.cache.misses
    eng.registry.append("ds", xa[:8])     # slack absorbs it: same epoch
    _q(eng, "ds", y[:16])
    assert eng.cache.misses == misses0    # same compiled executable served
    st = eng.registry.get("ds").stream
    epoch0 = st.snapshot().layout_epoch
    # force a rebuild through the policy and confirm new executables
    eng.registry.append("ds", np.repeat(xa, 20, axis=0))   # > append budget
    _q(eng, "ds", y[:16])
    assert st.snapshot().layout_epoch > epoch0
    assert eng.cache.misses > misses0


def test_slack_overflow_triggers_rebuild_and_stays_correct(data):
    x, xa, y = data
    eng = ServeEngine(_serve_cfg(method="kde", stream_slack=0.05,
                                 staleness_budget=0))
    eng.register("ds", x[:128], h=H)
    big = np.concatenate([x[128:], xa])
    eng.registry.append("ds", big)                    # overflows the slack
    got = np.asarray(_q(eng, "ds", y))
    st = eng.registry.get("ds").stream
    assert st.rebuilds >= 1
    assert st.last_rebuild_reason == "slack-overflow"
    want = _refit_eval(np.concatenate([x[:128], big]), y, "kde")
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))


def test_clean_tiles_carry_over_bitwise(data):
    """A far-away append leaves every unaffected tile's metadata and
    operand columns bit-for-bit unchanged (the in-place update is real)."""
    x, _, _ = data
    far = x + np.float32(100.0)           # separate cluster, zero overlap
    both = np.concatenate([x, far])
    st = StreamingSDKDE(both, H, method="sdkde", backend="pallas",
                        block_n=64, config=StreamConfig(slack=0.5))
    snap0 = st.snapshot()
    cols0 = st.columns_for("f32", snap0)
    # append next to the far cluster: φ against the near cluster is 0.0
    st.append(far[:8] + np.float32(0.1))
    snap1 = st.ensure(0)
    assert snap1.layout_epoch == snap0.layout_epoch   # no rebuild
    assert 0 < snap1.affected_tiles < snap1.total_tiles
    cols1 = st.columns_for("f32", snap1)
    # identify tiles of the near cluster via the f64 stats: unaffected
    changed = np.zeros(snap1.total_tiles, bool)
    xt0 = np.asarray(cols0.xt)
    xt1 = np.asarray(cols1.xt)
    for t in range(snap0.total_tiles):
        sl = slice(t * 64, (t + 1) * 64)
        if not np.array_equal(xt0[:, sl], xt1[:, sl]):
            changed[t] = True
    assert changed.sum() == snap1.affected_tiles or changed.sum() <= \
        snap1.affected_tiles                       # pads may rewrite equal
    clean = ~changed
    for f in spatial.TileMeta._fields:
        a0 = np.asarray(getattr(cols0.meta, f))
        a1 = np.asarray(getattr(cols1.meta, f))
        np.testing.assert_array_equal(a0[clean], a1[clean])


def test_append_into_trailing_empty_cluster(data, monkeypatch):
    """k-means can leave a trailing centroid with zero train points; the
    layout must still reserve that cluster's slab so a later append
    assigned to it has somewhere to land (regression: IndexError)."""
    x, _, _ = data
    cents = np.zeros((3, D), np.float32)
    cents[0] -= 1.0
    cents[1] += 1.0
    cents[2] = 50.0                       # no train point lands here

    def fake_index(pts, **kw):
        idx = spatial.SpatialIndex(None, jnp.asarray(cents))
        return spatial.SpatialIndex(spatial.assign(pts, idx),
                                    jnp.asarray(cents))

    monkeypatch.setattr(spatial, "build_index", fake_index)
    st = StreamingSDKDE(x[:64], H, method="kde", backend="pallas",
                        block_n=16)
    assert st._caps.shape[0] == 3         # slab reserved for the empty one
    far = np.full((3, D), 50.0, np.float32)
    ids = st.append(far)                  # must place, not IndexError
    assert (st._slots[-3:] >= 0).all()
    snap = st.ensure(0)
    assert snap.n_live == 67
    cols = st.columns_for("f32", snap)
    assert int(np.asarray(cols.meta.counts).sum()) == 67
    st.evict(ids)
    assert st.ensure(0).n_live == 64


def test_jnp_stream_bounds_executable_shapes(data):
    """Net appends on the jnp backend reuse the padded pow2 row bucket —
    the published layout shape changes only when the bucket overflows."""
    x, xa, _ = data
    st = StreamingSDKDE(x[:200], H, method="kde", backend="jnp")
    shape0 = st.snapshot().xp.shape
    st.append(xa[:8])
    assert st.ensure(0).xp.shape == shape0      # same bucket, no retrace
    st.append(np.repeat(xa, 2, axis=0))         # past the pow2 bucket
    snap = st.ensure(0)
    assert snap.xp.shape[0] >= snap.n_live
    assert snap.xp.shape != shape0


def test_background_flush_serves_stale_then_catches_up(data):
    x, xa, y = data
    st = StreamingSDKDE(x, H, method="kde", backend="jnp",
                        config=StreamConfig(background=True,
                                            staleness_budget=0))
    gen0 = st.snapshot().gen
    st.append(xa)                          # kicks a worker build
    snap = st.ensure(0)                    # joins the worker
    assert snap.gen == st.gen and snap.gen > gen0
    got = np.asarray(ref.kde_eval(snap.points, jnp.asarray(y), H, block=256))
    want = _refit_eval(np.concatenate([x, xa]), y, "kde")
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))


def test_stream_rejects_bad_usage(data):
    x, xa, _ = data
    st = StreamingSDKDE(x[:64], H, method="kde", backend="jnp")
    with pytest.raises(KeyError):
        st.evict([999999])
    with pytest.raises(ValueError):
        st.evict(st.ids)                   # cannot evict everything
    with pytest.raises(ValueError):
        st.append(xa[:, :2])               # dimension mismatch
    with pytest.raises(ValueError):
        StreamingSDKDE(x[:64], H, backend="ring")
    with pytest.raises(ValueError):
        ServeConfig(backend="ring", stream=True)
    eng = ServeEngine(_serve_cfg(stream=False))
    eng.register("static", x[:64], h=H)
    with pytest.raises(ValueError):
        eng.registry.append("static", xa)


# ---------------------------------------------------------------------------
# core.estimator.SDKDE incremental API.
# ---------------------------------------------------------------------------


def test_sdkde_append_evict_matches_refit(data):
    x, xa, y = data
    est = SDKDE(H, EstimatorConfig(backend="jnp", block=128)).fit(
        jnp.asarray(x))
    est.append(xa).evict(np.arange(32))
    got = np.asarray(est.evaluate(jnp.asarray(y)))
    want = _refit_eval(np.concatenate([x[32:], xa]), y)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))
    with pytest.raises(ValueError):
        est.evict(np.arange(est.x_train.shape[0]))


def test_sdkde_refit_resets_streaming_stats(data):
    """fit() must drop lazily-seeded stats — an append after a refit on a
    different dataset reseeds instead of mixing old statistics in."""
    x, xa, y = data
    est = SDKDE(H, EstimatorConfig(backend="jnp", block=128)).fit(
        jnp.asarray(x))
    est.append(xa)                       # seeds f64 stats for x + xa
    est.fit(jnp.asarray(x[:256]))        # refit: different dataset
    est.append(xa[:16])
    got = np.asarray(est.evaluate(jnp.asarray(y)))
    want = _refit_eval(np.concatenate([x[:256], xa[:16]]), y)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))


# ---------------------------------------------------------------------------
# Registry/engine update races (the satellite's three scenarios).
# ---------------------------------------------------------------------------


def test_registry_evict_during_inflight_queries(data):
    """Thread A queries while thread B evicts the key and re-registers:
    every answer is either a valid density vector from some published
    generation or a clean KeyError — never corruption."""
    x, _, y = data
    eng = ServeEngine(_serve_cfg(method="kde", backend="jnp"))
    eng.register("ds", x, h=H)
    want_a = _refit_eval(x, y[:16], "kde")
    want_b = _refit_eval(2.0 + x, y[:16], "kde")
    errors, results = [], []

    def worker():
        for _ in range(20):
            try:
                results.append(np.asarray(_q(eng, "ds", y[:16])))
            except KeyError:
                pass
            except Exception as e:  # noqa: BLE001
                errors.append(e)

    t = threading.Thread(target=worker)
    t.start()
    for _ in range(5):
        eng.registry.evict("ds")
        eng.register("ds", 2.0 + x, h=H)
        eng.registry.evict("ds")
        eng.register("ds", x, h=H)
    t.join()
    assert not errors, errors
    assert results
    for r in results:
        assert np.isfinite(r).all()
        ok_a = np.allclose(r, want_a, rtol=1e-5,
                           atol=1e-6 * float(want_a.max()))
        ok_b = np.allclose(r, want_b, rtol=1e-5,
                           atol=1e-6 * float(want_b.max()))
        assert ok_a or ok_b


def test_point_evict_during_pinned_snapshot_is_consistent(data):
    """An in-flight dispatch pinned to snapshot g keeps serving g's
    tensors even while evictions publish g+1 (snapshots are immutable)."""
    x, xa, y = data
    eng = ServeEngine(_serve_cfg())
    eng.register("ds", x, h=H)
    st = eng.registry.get("ds").stream
    pinned = st.ensure(0)
    cols_before = st.columns_for("f32", pinned)
    ids = eng.registry.append("ds", xa)
    eng.registry.evict_ids("ds", ids)                # live set moved on
    st.ensure(0)                                     # publish the new gen
    cols_after = st.columns_for("f32", pinned)       # pinned view unchanged
    np.testing.assert_array_equal(np.asarray(cols_before.xt),
                                  np.asarray(cols_after.xt))
    assert pinned.n_live == x.shape[0]
    # and the live snapshot reflects the round-trip back to x
    want = _refit_eval(x, y)
    got = np.asarray(_q(eng, "ds", y))
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))


def test_stream_refit_bumps_generation_and_invalidates(data):
    """refit=True on a streaming key rebuilds the stream and can never
    serve executables of the replaced one."""
    x, xa, y = data
    eng = ServeEngine(_serve_cfg(method="kde"))
    eng.register("ds", x, h=H)
    stale = np.asarray(_q(eng, "ds", y[:16]))
    gen0 = eng.registry.get("ds").generation
    eng.register("ds", 2.0 + x, h=H, refit=True)
    assert eng.registry.get("ds").generation != gen0
    fresh = np.asarray(_q(eng, "ds", y[:16]))
    want = _refit_eval(2.0 + x, y[:16], "kde")
    np.testing.assert_allclose(fresh, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))
    assert not np.allclose(fresh, stale)


# ---------------------------------------------------------------------------
# Execution planning (repro.plan): planned streaming == explicit knobs,
# across a generation flip.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier", ["f32", "bf16", "bf16x2"])
def test_planned_stream_matches_explicit_across_generation_flip(data, tier):
    """A plan-resolved streaming estimator serves the same densities as a
    hand-pinned one, before AND after an append bumps the generation."""
    x, xa, y = data
    planned = ServeConfig(
        backend="pallas", method="sdkde", interpret=True, plan="auto",
        precision=tier,                   # explicit: wins over the plan
        stream=True, min_batch=16, max_batch=128,
    )
    ep = ServeEngine(planned)
    prep = ep.register("ds", x, h=H)
    assert prep.plan is not None
    # default accuracy target is f32-grade -> the plan pins freshness
    assert prep.config.staleness_budget == 0
    explicit = ServeConfig(
        backend="pallas", method="sdkde", interpret=True,
        precision=tier, prune=prep.config.prune,
        block_m=prep.block_m, block_n=prep.block_n,
        stream=True, staleness_budget=0,
        min_batch=16, max_batch=128,
    )
    ee = ServeEngine(explicit)
    ee.register("ds", x, h=H)

    before_p = np.asarray(_q(ep, "ds", y[:64]))
    before_e = np.asarray(_q(ee, "ds", y[:64]))
    np.testing.assert_allclose(before_p, before_e, rtol=1e-5,
                               atol=1e-8 * float(np.max(before_e)))

    ep.registry.append("ds", xa)          # generation flip on both
    ee.registry.append("ds", xa)
    after_p = np.asarray(_q(ep, "ds", y[:64]))
    after_e = np.asarray(_q(ee, "ds", y[:64]))
    np.testing.assert_allclose(after_p, after_e, rtol=1e-5,
                               atol=1e-8 * float(np.max(after_e)))
    assert not np.allclose(before_p, after_p)   # the flip actually served


def test_planned_stream_loose_accuracy_gets_staleness_budget(data):
    x, _, _ = data
    eng = ServeEngine(ServeConfig(
        backend="pallas", method="sdkde", interpret=True, plan="auto",
        accuracy_target=5e-2, stream=True, min_batch=16, max_batch=128,
    ))
    prep = eng.register("ds", x, h=H)
    assert prep.config.staleness_budget == 2
    assert prep.config.stream_background
