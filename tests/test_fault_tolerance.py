"""Fault-tolerance substrate: supervisor, restart loop, straggler dispatch,
elastic mesh planning, gradient compression."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    compress,
    decompress,
    init_residual,
)
from repro.distributed.elastic import plan_mesh, rebatch, reshard_specs
from repro.distributed.fault import RestartLoop, Supervisor
from repro.distributed.straggler import DuplicateDispatcher, pick_backup


# -- supervisor --------------------------------------------------------------


def test_supervisor_failure_detection():
    clock = [0.0]
    sup = Supervisor(4, timeout=10.0, clock=lambda: clock[0])
    for h in range(4):
        sup.beat(h, 1)
    clock[0] = 5.0
    for h in (0, 1, 2):
        sup.beat(h, 2)
    assert sup.dead_hosts() == []
    clock[0] = 12.0     # host 3 last beat at t=0 -> dead; 0-2 beat at t=5
    assert sup.dead_hosts() == [3]
    plan = sup.restart_plan(spare_hosts=0)
    assert plan["action"] == "shrink" and plan["new_size"] == 3
    plan = sup.restart_plan(spare_hosts=2)
    assert plan["action"] == "replace"


def test_supervisor_straggler_detection():
    clock = [0.0]
    sup = Supervisor(4, timeout=1e9, straggler_factor=2.0,
                     clock=lambda: clock[0])
    # hosts 0-2 step every 1s; host 3 every 10s
    for step in range(1, 6):
        for h in (0, 1, 2):
            clock[0] = step * 1.0
            sup.beat(h, step)
        clock[0] = step * 10.0
        sup.beat(3, step)
    assert sup.stragglers() == [3]
    assert sup.fleet_step() == 5


def test_restart_loop_resumes_from_checkpoint():
    executed = []
    saved = {"step": 0}

    loop = RestartLoop(
        step_fn=lambda i: executed.append(i),
        save_fn=lambda s: saved.update(step=s),
        restore_fn=lambda: saved["step"],
        ckpt_every=10,
    )
    starts = loop.run(50, fail_at=25)
    assert starts == 2
    # steps 20..24 re-executed after restart from checkpoint at 20
    assert executed == list(range(0, 25)) + list(range(20, 50))


# -- straggler dispatch -------------------------------------------------------


def test_duplicate_dispatch_backup_wins():
    d = DuplicateDispatcher(deadline=0.05)

    def work(host):
        if host == 0:
            time.sleep(0.5)    # straggling primary
        return host

    result, winner = d.run(work, primary=0, backup=1)
    assert winner == 1 and result == 1
    d.close()


def test_duplicate_dispatch_primary_fast_path():
    d = DuplicateDispatcher(deadline=1.0)
    result, winner = d.run(lambda h: h, primary=0, backup=1)
    assert winner == 0
    d.close()


def test_pick_backup_fastest():
    assert pick_backup({0: 5.0, 1: 1.0, 2: 2.0}, straggler=0) == 1


# -- elastic -------------------------------------------------------------------


def test_plan_mesh_shrink():
    p = plan_mesh(512, model_parallel=16, want_pods=2)
    assert p.shape == (2, 16, 16)
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    # lost 16 hosts of 32 on one pod: 240 devices
    p = plan_mesh(240, model_parallel=16)
    assert p.shape == (15, 16) and p.note == ""
    # awkward count: drops stragglers
    p = plan_mesh(250, model_parallel=16)
    assert p.n_devices <= 250


def test_rebatch_exact_when_divisible():
    per_dev, mb, new_gb = rebatch(256, old_dp=16, new_dp=8, microbatches=8)
    assert per_dev * 8 * mb == 256 and new_gb == 256


def test_rebatch_nearest_when_impossible():
    # 15 hosts never tile 256 exactly -> nearest achievable multiple
    per_dev, mb, new_gb = rebatch(256, old_dp=16, new_dp=15, microbatches=8)
    assert new_gb == per_dev * 15 * mb
    assert abs(new_gb - 256) <= 15 * mb // 2 + 1


def test_reshard_specs_drops_dead_axes():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.elastic import make_mesh

    plan = plan_mesh(1, model_parallel=1)
    mesh = make_mesh(plan)
    specs = reshard_specs(
        {"w": P(("pod", "data"), "model"), "b": P(None, "pod")},
        ("pod", "data", "model"), mesh,
    )
    assert specs["w"].spec == P(("data",), "model")
    assert specs["b"].spec == P(None, None)


# -- compression ----------------------------------------------------------------


def test_int8_compression_error_feedback():
    g = {"w": jnp.array([[0.5, -0.25], [1.0, 0.003]], jnp.float32)}
    res = init_residual(g)
    q, s, res1 = compress(g, res)
    assert q["w"].dtype == jnp.int8
    out = decompress(q, s)
    # error feedback: residual + dequantized == original exactly
    np.testing.assert_allclose(
        np.asarray(out["w"] + res1["w"]), np.asarray(g["w"]), rtol=1e-6
    )


def test_compression_converges_with_feedback():
    """Accumulated compressed updates track the true sum (unbiased-ish)."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((64,))
    got_sum = jnp.zeros((64,))
    res = {"g": jnp.zeros((64,))}
    for i in range(50):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        q, s, res = compress(g, res)
        out = decompress(q, s)
        true_sum = true_sum + g["g"]
        got_sum = got_sum + out["g"]
    err = float(jnp.linalg.norm(got_sum - true_sum) / jnp.linalg.norm(true_sum))
    assert err < 0.02, err
