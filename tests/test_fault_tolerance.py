"""Fault-tolerance substrate: supervisor, restart loop, straggler dispatch,
elastic mesh planning, gradient compression."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    compress,
    decompress,
    init_residual,
)
from repro.distributed.elastic import plan_mesh, rebatch, reshard_specs
from repro.distributed.fault import RestartLoop, Supervisor
from repro.distributed.straggler import DuplicateDispatcher, pick_backup


# -- supervisor --------------------------------------------------------------


def test_supervisor_failure_detection():
    clock = [0.0]
    sup = Supervisor(4, timeout=10.0, clock=lambda: clock[0])
    for h in range(4):
        sup.beat(h, 1)
    clock[0] = 5.0
    for h in (0, 1, 2):
        sup.beat(h, 2)
    assert sup.dead_hosts() == []
    clock[0] = 12.0     # host 3 last beat at t=0 -> dead; 0-2 beat at t=5
    assert sup.dead_hosts() == [3]
    plan = sup.restart_plan(spare_hosts=0)
    assert plan["action"] == "shrink" and plan["new_size"] == 3
    plan = sup.restart_plan(spare_hosts=2)
    assert plan["action"] == "replace"


def test_supervisor_straggler_detection():
    clock = [0.0]
    sup = Supervisor(4, timeout=1e9, straggler_factor=2.0,
                     clock=lambda: clock[0])
    # hosts 0-2 step every 1s; host 3 every 10s
    for step in range(1, 6):
        for h in (0, 1, 2):
            clock[0] = step * 1.0
            sup.beat(h, step)
        clock[0] = step * 10.0
        sup.beat(3, step)
    assert sup.stragglers() == [3]
    assert sup.fleet_step() == 5


def test_restart_loop_resumes_from_checkpoint():
    executed = []
    saved = {"step": 0}

    loop = RestartLoop(
        step_fn=lambda i: executed.append(i),
        save_fn=lambda s: saved.update(step=s),
        restore_fn=lambda: saved["step"],
        ckpt_every=10,
    )
    starts = loop.run(50, fail_at=25)
    assert starts == 2
    # steps 20..24 re-executed after restart from checkpoint at 20
    assert executed == list(range(0, 25)) + list(range(20, 50))


# -- straggler dispatch -------------------------------------------------------


def test_duplicate_dispatch_backup_wins():
    d = DuplicateDispatcher(deadline=0.05)

    def work(host):
        if host == 0:
            time.sleep(0.5)    # straggling primary
        return host

    result, winner = d.run(work, primary=0, backup=1)
    assert winner == 1 and result == 1
    d.close()


def test_duplicate_dispatch_primary_fast_path():
    d = DuplicateDispatcher(deadline=1.0)
    result, winner = d.run(lambda h: h, primary=0, backup=1)
    assert winner == 0
    d.close()


def test_pick_backup_fastest():
    assert pick_backup({0: 5.0, 1: 1.0, 2: 2.0}, straggler=0) == 1


# -- elastic -------------------------------------------------------------------


def test_plan_mesh_shrink():
    p = plan_mesh(512, model_parallel=16, want_pods=2)
    assert p.shape == (2, 16, 16)
    p = plan_mesh(256, model_parallel=16)
    assert p.shape == (16, 16)
    # lost 16 hosts of 32 on one pod: 240 devices
    p = plan_mesh(240, model_parallel=16)
    assert p.shape == (15, 16) and p.note == ""
    # awkward count: drops stragglers
    p = plan_mesh(250, model_parallel=16)
    assert p.n_devices <= 250


def test_rebatch_exact_when_divisible():
    per_dev, mb, new_gb = rebatch(256, old_dp=16, new_dp=8, microbatches=8)
    assert per_dev * 8 * mb == 256 and new_gb == 256


def test_rebatch_nearest_when_impossible():
    # 15 hosts never tile 256 exactly -> nearest achievable multiple
    per_dev, mb, new_gb = rebatch(256, old_dp=16, new_dp=15, microbatches=8)
    assert new_gb == per_dev * 15 * mb
    assert abs(new_gb - 256) <= 15 * mb // 2 + 1


def test_reshard_specs_drops_dead_axes():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.elastic import make_mesh

    plan = plan_mesh(1, model_parallel=1)
    mesh = make_mesh(plan)
    specs = reshard_specs(
        {"w": P(("pod", "data"), "model"), "b": P(None, "pod")},
        ("pod", "data", "model"), mesh,
    )
    assert specs["w"].spec == P(("data",), "model")
    assert specs["b"].spec == P(None, None)


# -- compression ----------------------------------------------------------------


def test_int8_compression_error_feedback():
    g = {"w": jnp.array([[0.5, -0.25], [1.0, 0.003]], jnp.float32)}
    res = init_residual(g)
    q, s, res1 = compress(g, res)
    assert q["w"].dtype == jnp.int8
    out = decompress(q, s)
    # error feedback: residual + dequantized == original exactly
    np.testing.assert_allclose(
        np.asarray(out["w"] + res1["w"]), np.asarray(g["w"]), rtol=1e-6
    )


def test_compression_converges_with_feedback():
    """Accumulated compressed updates track the true sum (unbiased-ish)."""
    key = jax.random.PRNGKey(0)
    true_sum = jnp.zeros((64,))
    got_sum = jnp.zeros((64,))
    res = {"g": jnp.zeros((64,))}
    for i in range(50):
        g = {"g": jax.random.normal(jax.random.fold_in(key, i), (64,))}
        q, s, res = compress(g, res)
        out = decompress(q, s)
        true_sum = true_sum + g["g"]
        got_sum = got_sum + out["g"]
    err = float(jnp.linalg.norm(got_sum - true_sum) / jnp.linalg.norm(true_sum))
    assert err < 0.02, err

# -- fencing epoch (PR 8) -----------------------------------------------------


def test_fence_rejects_zombie_beats():
    clock = [0.0]
    sup = Supervisor(4, timeout=10.0, clock=lambda: clock[0])
    for h in range(4):
        sup.beat(h, 1)
    clock[0] = 20.0
    for h in (0, 1, 2):
        sup.beat(h, 2)
    plan = sup.restart_plan(fence=True)
    assert plan["action"] == "shrink" and plan["dead"] == [3]
    assert sup.fenced() == [3]
    # the zombie process keeps beating: no epoch, then a stale epoch —
    # neither may flip the host back to alive
    assert sup.beat(3, 3) is False
    assert sup.beat(3, 3, epoch=0) is False
    assert sup.rejected_beats == 2
    assert sup.fenced() == [3]
    assert 3 not in [h for h in sup.hosts if sup.hosts[h].alive]


def test_fence_readmission_epoch():
    clock = [0.0]
    sup = Supervisor(2, timeout=5.0, clock=lambda: clock[0])
    sup.fence([1])
    ep = sup.hosts[1].epoch
    # a beat carrying the CURRENT epoch is the re-admission handshake
    assert sup.beat(1, 7, epoch=ep) is True
    assert sup.fenced() == [] and sup.hosts[1].alive
    # coordinator-side readmit: refreshes the beat clock too
    sup.fence([0])
    clock[0] = 3.0
    assert sup.readmit(0) == sup.hosts[0].epoch
    assert sup.fenced() == [] and sup.hosts[0].last_beat == 3.0


def test_restart_plan_fencing_is_idempotent():
    clock = [0.0]
    sup = Supervisor(3, timeout=1.0, clock=lambda: clock[0])
    clock[0] = 5.0
    sup.beat(0, 1)
    p1 = sup.restart_plan(fence=True)
    epochs = {h: sup.hosts[h].epoch for h in (1, 2)}
    # a second sweep sees the same dead set and must not bump epochs again
    p2 = sup.restart_plan(fence=True)
    assert p1["dead"] == p2["dead"] == [1, 2]
    assert {h: sup.hosts[h].epoch for h in (1, 2)} == epochs
    # default restart_plan never fences (pre-PR-8 behavior preserved)
    sup2 = Supervisor(2, timeout=1.0, clock=lambda: clock[0])
    clock[0] = 10.0
    assert sup2.restart_plan()["dead"] == [0, 1]
    assert sup2.fenced() == []
    assert sup2.beat(0, 1) is True


# -- restart loop error taxonomy (PR 8) --------------------------------------


def test_restart_loop_propagates_real_bugs():
    """Only InjectedFailure is retried; a genuine step_fn bug must surface."""
    executed = []

    def step(i):
        executed.append(i)
        if i == 3:
            raise ZeroDivisionError("real bug in step 3")

    loop = RestartLoop(step_fn=step, save_fn=lambda s: None,
                       restore_fn=lambda: 0, ckpt_every=10)
    with pytest.raises(ZeroDivisionError, match="real bug"):
        loop.run(10)
    assert executed == [0, 1, 2, 3]     # no silent retry loop


def test_restart_loop_still_retries_injected_failure():
    from repro.fault_injection import InjectedFailure  # noqa: F401

    loop = RestartLoop(step_fn=lambda i: None, save_fn=lambda s: None,
                       restore_fn=lambda: 0, ckpt_every=100)
    assert loop.run(5, fail_at=2) == 2


# -- elastic edge cases (PR 8) ------------------------------------------------


def test_rebatch_non_divisible_device_count():
    # 100 over 7 hosts never tiles exactly: nearest achievable multiple,
    # with the invariant new_gb == per_dev * dp * mb
    per_dev, mb, new_gb = rebatch(100, old_dp=4, new_dp=7, microbatches=3)
    assert per_dev >= 1 and new_gb == per_dev * 7 * mb
    assert abs(new_gb - 100) <= 7 * mb


def test_rebatch_shrink_to_single_host():
    per_dev, mb, new_gb = rebatch(256, old_dp=16, new_dp=1, microbatches=8)
    assert new_gb == 256 and per_dev * mb == 256


def test_plan_mesh_awkward_counts():
    # prime count: model axis folds down to 1, everything becomes data
    p = plan_mesh(7, model_parallel=16)
    assert p.shape == (7, 1) and p.n_devices == 7
    # single device
    p = plan_mesh(1, model_parallel=16)
    assert p.n_devices == 1
    # non-dividing want_pods falls back to a 2-axis mesh
    p = plan_mesh(256, model_parallel=16, want_pods=3)
    assert p.axes == ("data", "model")


def test_reshard_specs_vanished_tuple_axis():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.elastic import make_mesh

    plan = plan_mesh(1, model_parallel=1)
    mesh = make_mesh(plan)
    # a dim sharded ONLY over vanished axes becomes fully replicated
    specs = reshard_specs({"w": P(("pod",), None)}, ("pod", "data"), mesh)
    assert specs["w"].spec == P(None, None)
