"""benchmarks/run.py failure semantics + the regression gate logic."""

import json
import subprocess
import sys

from benchmarks import check_regression


def _doc(cells, **meta):
    return {"meta": meta, "cells": cells}


def test_gate_passes_within_tolerance():
    base = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 10.0}])
    cur = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 9.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert not failures and rows[0][3]


def test_gate_fails_on_regress_and_missing_cell():
    base = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        {"cell": "precision_model", "n": 32, "modeled_speedup": 1.3},
    ])
    cur = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 8.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(failures) == 2            # regressed + missing
    assert not rows[0][3] and rows[1][2] is None


def test_gate_fails_on_recorded_harness_failures():
    base = _doc([])
    cur = _doc([], failed_harnesses="fig1")
    _, failures = check_regression.check(cur, base, 0.15)
    assert failures and "fig1" in failures[0]


def test_gate_ignores_ungated_cells():
    base = _doc([{"cell": "serve", "n": 64, "qps": 100.0}])
    cur = _doc([{"cell": "serve", "n": 64, "qps": 1.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert not rows and not failures


def test_gate_ignores_non_dict_cells_and_metrics_key():
    """Telemetry rows (obs_overhead), an embedded metrics snapshot, and
    malformed/non-dict cells must never break the gate."""
    base = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        "stray-string-cell",
        None,
    ])
    cur = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        {"cell": "obs_overhead", "ratio": 1.01, "p50_on_ms": 2.0},
        ["not", "a", "cell"],
    ])
    cur["metrics"] = {"serve.requests": {"type": "counter", "value": 3.0}}
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(rows) == 1 and rows[0][3]
    assert not failures


def test_gate_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 10.0}])))
    cur.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 9.9}])))
    assert check_regression.main(
        ["--current", str(cur), "--baseline", str(base)]) == 0
    cur.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 1.0}])))
    assert check_regression.main(
        ["--current", str(cur), "--baseline", str(base)]) == 1


def test_run_harness_failure_recorded_and_nonzero(tmp_path):
    """A raising harness is recorded (emit + FAILURES) without aborting
    the suite, and the aggregator process exits nonzero."""
    from benchmarks import common, run

    records_before = len(common.RECORDS)
    failures_before = list(run.FAILURES)
    run._run("boom", "always raises", lambda: 1 / 0)
    try:
        assert run.FAILURES[-1] == "boom"
        new = common.RECORDS[records_before:]
        assert any(r["cell"] == "harness_error" for r in new)
        harness = [r for r in new if r["cell"] == "harness"][-1]
        assert harness["ok"] is False
    finally:
        del run.FAILURES[:]
        run.FAILURES.extend(failures_before)
        del common.RECORDS[records_before:]

    # end-to-end: a tiny aggregator in the same style exits 1 on failure
    script = (
        "import sys; sys.path.insert(0, '.');"
        "from benchmarks import run;"
        "run._run('boom', 'raises', lambda: 1/0);"
        "sys.exit(1 if run.FAILURES else 0)"
    )
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True)
    assert proc.returncode == 1
