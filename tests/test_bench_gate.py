"""benchmarks/run.py failure semantics + the regression gate logic."""

import json
import subprocess
import sys

from benchmarks import check_regression


def _doc(cells, **meta):
    return {"meta": meta, "cells": cells}


def test_gate_passes_within_tolerance():
    base = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 10.0}])
    cur = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 9.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert not failures and rows[0][3]


def test_gate_fails_on_regress_and_missing_cell():
    base = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        {"cell": "precision_model", "n": 32, "modeled_speedup": 1.3},
    ])
    cur = _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 8.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(failures) == 2            # regressed + missing
    assert not rows[0][3] and rows[1][2] is None


def test_gate_fails_on_recorded_harness_failures():
    base = _doc([])
    cur = _doc([], failed_harnesses="fig1")
    _, failures = check_regression.check(cur, base, 0.15)
    assert failures and "fig1" in failures[0]


def test_gate_ignores_ungated_cells():
    base = _doc([{"cell": "serve", "n": 64, "qps": 100.0}])
    cur = _doc([{"cell": "serve", "n": 64, "qps": 1.0}])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert not rows and not failures


def test_gate_ignores_non_dict_cells_and_metrics_key():
    """Telemetry rows (obs_overhead), an embedded metrics snapshot, and
    malformed/non-dict cells must never break the gate."""
    base = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        "stray-string-cell",
        None,
    ])
    cur = _doc([
        {"cell": "pruning", "n": 64, "modeled_speedup": 10.0},
        {"cell": "obs_overhead", "ratio": 1.01, "p50_on_ms": 2.0},
        ["not", "a", "cell"],
    ])
    cur["metrics"] = {"serve.requests": {"type": "counter", "value": 3.0}}
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(rows) == 1 and rows[0][3]
    assert not failures


def test_gate_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 10.0}])))
    cur.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 9.9}])))
    assert check_regression.main(
        ["--current", str(cur), "--baseline", str(base)]) == 0
    cur.write_text(json.dumps(
        _doc([{"cell": "pruning", "n": 64, "modeled_speedup": 1.0}])))
    assert check_regression.main(
        ["--current", str(cur), "--baseline", str(base)]) == 1


# ---------------------------------------------------------------------------
# planner cells: speedup floor + plan-vs-golden drift (benchmarks/
# planner_cells.py, check_regression.check_plan_drift)

_PLANNER_KEY = "n=64 d=4 q=16 accuracy=1e-05 backend=auto stream=False"


def _planner_cell(**over):
    cell = {
        "cell": "planner", "request_key": _PLANNER_KEY,
        "n": 64, "d": 4, "q": 16, "accuracy": 1e-05,
        "backend": "pallas", "precision": "f32", "prune": 1e-09,
        "block_m": 2048, "block_n": 128,
        "plan_id": "pallas/f32/prune=1e-09/2048x128",
        "modeled_speedup": 8.0, "beats_default": True,
    }
    cell.update(over)
    return cell


def _golden_doc():
    return {"plans": {_PLANNER_KEY: {"plan": {
        "backend": "pallas", "precision": "f32", "prune": 1e-09,
        "block_m": 2048, "block_n": 128,
    }}}}


def test_planner_cell_is_gated_on_speedup():
    """A planner cell carries modeled_speedup, so the 15% floor applies."""
    base = _doc([_planner_cell()])
    cur = _doc([_planner_cell(modeled_speedup=4.0)])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(rows) == 1 and not rows[0][3]
    assert failures and "modeled_speedup" in failures[0]


def test_planner_cell_key_ignores_decision_fields():
    """Identity is the request_key alone: a changed decision must surface
    as plan DRIFT, not as a missing gated cell."""
    base = _doc([_planner_cell()])
    cur = _doc([_planner_cell(precision="bf16", block_m=256,
                              plan_id="pallas/bf16/prune=1e-09/256x128")])
    rows, failures = check_regression.check(cur, base, 0.15)
    assert len(rows) == 1 and rows[0][3]      # same cell, speedup fine
    assert not failures


def test_plan_drift_fails_without_marker_and_notes_with():
    cur = _doc([_planner_cell(prune=1e-06,
                              plan_id="pallas/f32/prune=1e-06/2048x128")])
    failures, notes = check_regression.check_plan_drift(cur, _golden_doc())
    assert len(failures) == 1 and not notes
    assert "prune" in failures[0] and "--regen-golden" in failures[0]

    failures, notes = check_regression.check_plan_drift(
        cur, _golden_doc(), regen_marker=True)
    assert not failures and len(notes) == 1


def test_plan_matching_golden_passes():
    failures, notes = check_regression.check_plan_drift(
        _doc([_planner_cell()]), _golden_doc())
    assert not failures and not notes


def test_plan_without_golden_entry_fails_unless_marked():
    cur = _doc([_planner_cell(request_key="n=1 d=1 q=1 accuracy=1e-05 "
                                          "backend=auto stream=False")])
    failures, _ = check_regression.check_plan_drift(cur, _golden_doc())
    assert failures and "no golden entry" in failures[0]
    failures, notes = check_regression.check_plan_drift(
        cur, _golden_doc(), regen_marker=True)
    assert not failures and notes


def test_plan_id_drift_detected_even_when_fields_match():
    """plan_id is recomputed from the pinned fields, so a cell whose
    plan_id disagrees with its own decision fields is caught too."""
    cur = _doc([_planner_cell(plan_id="pallas/f32/prune=1e-09/512x512")])
    failures, _ = check_regression.check_plan_drift(cur, _golden_doc())
    assert failures and "plan_id" in failures[0]


def test_missing_baseline_planner_cell_fails_gate():
    base = _doc([_planner_cell()])
    cur = _doc([])                      # harness didn't emit the cell
    rows, failures = check_regression.check(cur, base, 0.15)
    assert rows[0][2] is None
    assert failures and "missing" in failures[0]


def test_failed_harness_fails_gate_despite_healthy_planner_cells():
    base = _doc([_planner_cell()])
    cur = _doc([_planner_cell()], failed_harnesses="planner")
    rows, failures = check_regression.check(cur, base, 0.15)
    assert rows[0][3]                   # the cell itself is fine
    assert failures and "planner" in failures[0]


def test_gate_cli_regen_golden_marker(tmp_path):
    """End-to-end: drift exits 1 without the marker, 0 with it."""
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    golden = tmp_path / "golden.json"
    base.write_text(json.dumps(_doc([_planner_cell()])))
    cur.write_text(json.dumps(_doc(
        [_planner_cell(prune=1e-06,
                       plan_id="pallas/f32/prune=1e-06/2048x128")])))
    golden.write_text(json.dumps(_golden_doc()))
    argv = ["--current", str(cur), "--baseline", str(base),
            "--golden", str(golden)]
    assert check_regression.main(argv) == 1
    assert check_regression.main(argv + ["--regen-golden"]) == 0
    # matching plan needs no marker
    cur.write_text(json.dumps(_doc([_planner_cell()])))
    assert check_regression.main(argv) == 0
    # --golden '' disables the drift check entirely
    cur.write_text(json.dumps(_doc(
        [_planner_cell(prune=1e-06,
                       plan_id="pallas/f32/prune=1e-06/2048x128")])))
    assert check_regression.main(
        ["--current", str(cur), "--baseline", str(base),
         "--golden", ""]) == 0


def test_committed_baseline_planner_cells_match_committed_golden():
    """The repo's own artifacts agree: every planner cell in
    BENCH_baseline.json matches tests/golden_plans.json exactly."""
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    with open(root / "benchmarks" / "BENCH_baseline.json") as f:
        baseline = json.load(f)
    with open(root / "tests" / "golden_plans.json") as f:
        golden = json.load(f)
    n_planner = sum(1 for c in baseline["cells"]
                    if isinstance(c, dict) and c.get("cell") == "planner")
    assert n_planner >= 4
    failures, notes = check_regression.check_plan_drift(baseline, golden)
    assert not failures and not notes
    # and every one of them beat the default path when committed
    assert all(c.get("beats_default") for c in baseline["cells"]
               if isinstance(c, dict) and c.get("cell") == "planner")


def test_run_harness_failure_recorded_and_nonzero(tmp_path):
    """A raising harness is recorded (emit + FAILURES) without aborting
    the suite, and the aggregator process exits nonzero."""
    from benchmarks import common, run

    records_before = len(common.RECORDS)
    failures_before = list(run.FAILURES)
    run._run("boom", "always raises", lambda: 1 / 0)
    try:
        assert run.FAILURES[-1] == "boom"
        new = common.RECORDS[records_before:]
        assert any(r["cell"] == "harness_error" for r in new)
        harness = [r for r in new if r["cell"] == "harness"][-1]
        assert harness["ok"] is False
    finally:
        del run.FAILURES[:]
        run.FAILURES.extend(failures_before)
        del common.RECORDS[records_before:]

    # end-to-end: a tiny aggregator in the same style exits 1 on failure
    script = (
        "import sys; sys.path.insert(0, '.');"
        "from benchmarks import run;"
        "run._run('boom', 'raises', lambda: 1/0);"
        "sys.exit(1 if run.FAILURES else 0)"
    )
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True)
    assert proc.returncode == 1
