"""Precision tiers (kernels/precision.py) + block autotuner (kernels/autotune.py).

Covers the PR-3 acceptance surface:
  * allclose sweeps per tier against the pure-jnp oracles — bf16x2 within
    1e-4 rtol, bf16 within 1e-2 rtol (tail densities under a small atol
    floor, as every allclose in this repo);
  * the prepared serving fast path with ``laplace=True`` and per-tier
    padded-query behavior (padding must contribute exactly 0 to real rows
    at every tier);
  * the model-guided autotuner: feasibility, memoization, measured top-k,
    "auto" resolution constraints, and the acceptance cell (autotuned bf16
    beats the fixed f32 128×512 on modeled step time at the paper's
    32k-sample 16-d problem);
  * dtype-aware VMEM budgeting (bf16 tiles cost half the f32 budget).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bandwidth import gaussian_norm_const
from repro.kernels import autotune, ops, ref
from repro.kernels import precision as prec
from repro.kernels.tuning import VMEM_BUDGET

# (rtol, atol-as-fraction-of-peak) per tier: the documented accuracy bars.
# The atol floor covers deep-tail densities (orders of magnitude below the
# peak), exactly like the seed's f32 allclose sweeps; the rtol is the
# headline bar — 1e-4 for the compensated bf16x2 split, 1e-2 for raw bf16.
TIER_TOL = {"f32": (2e-4, 1e-6), "bf16": (1e-2, 5e-3), "bf16x2": (1e-4, 1e-5)}
TIERS = ("f32", "bf16", "bf16x2")

# (n, m, d, h): bandwidths at the Silverman-ish scale for each dimension —
# bf16's documented 1e-2 bar presumes a statistically sane h (undersmoothing
# far below it amplifies the operand rounding through the exponential).
SHAPES = [
    (300, 50, 16, 1.0),     # non-multiples: padding path
    (512, 128, 8, 0.9),
    (256, 64, 32, 1.5),
    (128, 64, 1, 0.7),      # 1-D (the appendix setting)
]



def _q(eng, key, y, **kw):
    from repro.serve import QueryRequest
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

def _data(n, m, d, seed=0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (n, d), jnp.float32)
    y = jax.random.normal(ky, (m, d), jnp.float32) * 1.2
    return x, y


def _assert_tier(got, want, tier, rtol_scale=1.0):
    rtol, atol_frac = TIER_TOL[tier]
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=rtol * rtol_scale,
        atol=atol_frac * float(np.max(np.abs(np.asarray(want)))),
    )


# ---------------------------------------------------------------------------
# Allclose sweeps per tier vs the ref.py oracles.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,d,h", SHAPES)
@pytest.mark.parametrize("tier", TIERS)
def test_flash_kde_precision_tiers(n, m, d, h, tier):
    x, y = _data(n, m, d)
    got = ops.flash_kde(x, y, h, precision=tier, block_m=32, block_n=128,
                        interpret=True)
    want = ref.ref_kde_sums(x, y, h) / (n * gaussian_norm_const(d, 1.0)
                                        * h**d)
    _assert_tier(got, want, tier)


@pytest.mark.parametrize("n,d", [(300, 16), (512, 8)])
@pytest.mark.parametrize("tier", TIERS)
def test_flash_score_stats_precision_tiers(n, d, tier):
    x, _ = _data(n, 1, d, seed=1)
    h = 0.8
    s0, s1 = ops.flash_score_stats(x, h, precision=tier, block_m=32,
                                   block_n=128, interpret=True)
    r0, r1 = ref.ref_score_stats(x, h)
    _assert_tier(s0, r0, tier)
    _assert_tier(s1, r1, tier)


@pytest.mark.parametrize("n,m,d,h", [(300, 50, 16, 1.0), (256, 64, 8, 1.0)])
@pytest.mark.parametrize("tier", TIERS)
def test_flash_laplace_precision_tiers(n, m, d, h, tier):
    x, y = _data(n, m, d, seed=2)
    got = ops.flash_laplace_kde(x, y, h, precision=tier, block_m=32,
                                block_n=128, interpret=True)
    want = ref.ref_laplace_sums(x, y, h) / (n * gaussian_norm_const(d, 1.0)
                                            * h**d)
    # the Laplace factor crosses zero, so pure relative error is undefined
    # at the crossings — the tier bar applies against the peak magnitude
    _assert_tier(got, want, tier, rtol_scale=2.0)


@pytest.mark.parametrize("tier", TIERS)
def test_full_sdkde_pipeline_precision_tiers(tier):
    """flash_sdkde per tier vs the f32 jnp reference path (end to end)."""
    from repro.core import kde

    x, y = _data(300, 77, 16, seed=3)
    h = 0.6
    got = ops.flash_sdkde(x, y, h, precision=tier, block_m=32, block_n=128,
                          interpret=True)
    want = kde.sdkde_eval(x, y, h, block=128)
    _assert_tier(got, want, tier)


# ---------------------------------------------------------------------------
# Prepared fast path: laplace coverage + padding exactness per tier.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier", TIERS)
@pytest.mark.parametrize("laplace", [False, True])
def test_flash_kde_prepared_tiers_and_laplace(tier, laplace):
    x, y = _data(320, 48, 8, seed=4)
    h = 1.0
    cols = ops.prepare_train_columns(x, block_n=64, precision=tier)
    yp = ops._pad_to(y, 16)
    sums = ops.flash_kde_prepared(
        yp, cols.xt, cols.nrm_x, h, cols.xt_lo, precision=tier,
        block_m=16, block_n=64, interpret=True, laplace=laplace,
    )
    oracle = ref.ref_laplace_sums if laplace else ref.ref_kde_sums
    # Laplace sums cross zero → the bar applies against the peak (see
    # test_flash_laplace_precision_tiers)
    _assert_tier(sums[: y.shape[0]], oracle(x, y, h), tier,
                 rtol_scale=2.0 if laplace else 1.0)


@pytest.mark.parametrize("tier", TIERS)
def test_padding_contributes_exactly_zero_per_tier(tier):
    """Sentinel train columns add exactly 0.0 to real rows at every tier,
    and query padding never changes real rows: heavier padding (smaller
    bucket tiles vs a 4× padded layout) must give bit-identical sums."""
    x, y = _data(96, 24, 8, seed=5)
    h = 0.7
    light = ops.prepare_train_columns(x, block_n=32, precision=tier)
    heavy = ops.prepare_train_columns(x, block_n=256, precision=tier)
    assert heavy.xt.shape[1] == 256 > light.xt.shape[1]

    kw = dict(precision=tier, block_m=8, interpret=True)
    yp_light = ops._pad_to(y, 8)
    yp_heavy = ops._pad_to(y, 64)
    s_light = ops.flash_kde_prepared(
        yp_light, light.xt, light.nrm_x, h, light.xt_lo, block_n=32, **kw
    )
    s_heavy = ops.flash_kde_prepared(
        yp_heavy, heavy.xt, heavy.nrm_x, h, heavy.xt_lo, block_n=64, **kw
    )
    np.testing.assert_array_equal(np.asarray(s_light[: y.shape[0]]),
                                  np.asarray(s_heavy[: y.shape[0]]))


def test_prepared_rejects_mismatched_lo_planes():
    x, y = _data(64, 16, 4, seed=6)
    cols32 = ops.prepare_train_columns(x, block_n=32, precision="f32")
    colsx2 = ops.prepare_train_columns(x, block_n=32, precision="bf16x2")
    yp = ops._pad_to(y, 16)
    with pytest.raises(ValueError, match="bf16x2"):
        ops.flash_kde_prepared(yp, colsx2.xt, colsx2.nrm_x, 0.5,
                               precision="bf16x2", block_m=16, block_n=32,
                               interpret=True)
    with pytest.raises(ValueError, match="bf16x2"):
        ops.flash_kde_prepared(yp, cols32.xt, cols32.nrm_x, 0.5,
                               colsx2.xt_lo, precision="f32", block_m=16,
                               block_n=32, interpret=True)


# ---------------------------------------------------------------------------
# Autotuner: feasibility, memoization, measurement, acceptance.
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _fresh_tuner_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_autotune_returns_feasible_blocks():
    for tier in TIERS:
        bm, bn = autotune.autotune_blocks(4096, 32768, 16, precision=tier,
                                          measure=False)
        c = autotune.modeled_cost(4096, 32768, 16, block_m=bm, block_n=bn,
                                  precision=tier)
        assert c is not None and c.vmem_bytes <= VMEM_BUDGET


def test_autotune_memoizes_by_padded_shape_bucket():
    assert autotune.cache_info() == {}
    a = autotune.autotune_blocks(1000, 30000, 16, measure=False)
    assert len(autotune.cache_info()) == 1
    # same power-of-two shape bucket (1024, 32768) → cache hit, no growth
    b = autotune.autotune_blocks(997, 32768, 16, measure=False)
    assert a == b and len(autotune.cache_info()) == 1
    autotune.autotune_blocks(997, 32768, 16, precision="bf16",
                             measure=False)
    assert len(autotune.cache_info()) == 2


def test_autotune_measured_topk_overrides_model():
    """With a time_fn, the hardware vote wins over the model ranking."""
    ranked = autotune.shortlist(4096, 32768, 16, precision="bf16")
    assert len(ranked) >= 2
    # pretend the model's 2nd choice is actually fastest on "hardware"
    target = ranked[1].blocks
    picked = autotune.autotune_blocks(
        4096, 32768, 16, precision="bf16",
        time_fn=lambda bm, bn: 0.0 if (bm, bn) == target else 1.0,
        topk=3,
    )
    assert picked == target


def test_resolve_blocks_passthrough_and_constraints():
    assert autotune.resolve_blocks(32, 128, 100, 1000, 8) == (32, 128)
    bm, bn = autotune.resolve_blocks("auto", "auto", 64, 384, 8,
                                     row_multiple=64, col_multiple=384,
                                     measure=False)
    assert 64 % bm == 0 and 384 % bn == 0
    # fixed one side, auto the other
    bm2, bn2 = autotune.resolve_blocks(16, "auto", 64, 512, 8,
                                       measure=False)
    assert bm2 == 16 and 512 % bn2 == 0 or bn2 in autotune.DEFAULT_BLOCK_NS


def test_acceptance_bf16_auto_beats_f32_fixed_on_model():
    """ISSUE 3 acceptance: autotuned bf16 on the 32k-sample 16-d cell beats
    the fixed f32 128×512 configuration on modeled step time."""
    n, d = 32768, 16
    m = n // 8
    fixed = autotune.modeled_cost(m, n, d, block_m=128, block_n=512,
                                  precision="f32")
    bm, bn = autotune.autotune_blocks(m, n, d, precision="bf16",
                                      measure=False)
    tuned = autotune.modeled_cost(m, n, d, block_m=bm, block_n=bn,
                                  precision="bf16")
    assert tuned.step_time < fixed.step_time, (tuned, fixed)


def test_auto_is_the_wrapper_default_and_matches_explicit():
    """block_m/block_n default to "auto" end to end (wrapper acceptance)."""
    x, y = _data(200, 40, 8, seed=7)
    got = ops.flash_kde(x, y, 0.7, interpret=True)           # all defaults
    want = ops.flash_kde(x, y, 0.7, block_m=32, block_n=128,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Dtype-aware VMEM budgeting.
# ---------------------------------------------------------------------------


def test_vmem_budget_is_dtype_aware():
    f32_b = ops.vmem_tile_bytes(128, 1024, 256, itemsize=4)
    bf16_b = ops.vmem_tile_bytes(128, 1024, 256, itemsize=2)
    assert bf16_b < f32_b
    # operand-dominated tile: bf16 halves the operand share exactly
    operand_elems = 128 * 256 + 256 * 1024 + 1024 * 257
    assert f32_b - bf16_b == 2 * operand_elems
    assert prec.operand_bytes("bf16") == 2
    assert prec.operand_bytes("bf16x2") == 4     # two bf16 planes


def test_check_vmem_admits_bf16_tile_that_f32_rejects():
    # operand-dominated config sitting between the bf16 and f32 budgets
    bm, bn, d = 64, 2048, 1024
    with pytest.raises(ValueError, match="VMEM"):
        ops._check_vmem(bm, bn, d, itemsize=4)
    ops._check_vmem(bm, bn, d, itemsize=2)       # fits at bf16


# ---------------------------------------------------------------------------
# Serve integration: per-tier dispatch + tuned tiles.
# ---------------------------------------------------------------------------


def test_serve_precision_override_and_per_tier_cache():
    from repro.core import kde as refkde
    from repro.serve import ServeConfig, ServeEngine

    x, y = _data(256, 60, 8, seed=8)
    h = 0.6
    cfg = ServeConfig(backend="pallas", method="kde", interpret=True,
                      block_m="auto", block_n="auto", precision="bf16x2",
                      min_batch=16, max_batch=128, block=128)
    eng = ServeEngine(cfg)
    prep = eng.register("ds", x, h=h)
    assert isinstance(prep.block_m, int) and isinstance(prep.block_n, int)
    want = np.asarray(refkde.kde_eval(x, y, h, block=128))

    _assert_tier(_q(eng, "ds", y), want, "bf16x2")
    _assert_tier(_q(eng, "ds", y, precision="f32"), want, "f32")
    _assert_tier(_q(eng, "ds", y, precision="bf16"), want, "bf16")
    # one prepared-column set per tier, cached on the estimator
    assert sorted(prep._columns) == ["bf16", "bf16x2", "f32"]
    # bucket ladder respects the tuned row tile
    assert all(b % prep.block_m == 0
               for b in cfg.bucket_sizes(1, prep.block_m))
