"""Planner conformance: golden decisions, validity properties, wiring.

Three layers:

1. Golden-decision snapshots — one pinned plan per committed benchmark
   cell (``tests/golden_plans.json``).  Any drift fails; the fixture is
   rewritten only deliberately via ``python -m repro.plan --regen-golden``.
2. Property suite — randomized (n, d, q, accuracy, backend, stream)
   requests always produce *valid* plans: VMEM-fitting blocks, tile
   multiples, tier/prune compatibility, monotone modeled cost in n.
   Uses hypothesis when available, a fixed-seed sweep otherwise (same
   degradation pattern as tests/test_pruning.py).
3. Wiring — override precedence in ``resolve_config``, the ops ``plan=``
   kwarg, engine prewarm, plan-decision metrics, eps=0 plans dense.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.kernels import autotune, ops
from repro.plan import (
    DEFAULT_ACCURACY,
    EPS_SAFETY,
    PALLAS_MIN_COLS,
    TIER_RTOL,
    BenchModel,
    ExecutionPlan,
    PlanRequest,
    golden_entries,
    load_docs,
    load_golden,
    plan,
    plan_for,
    request_key,
    requests_from_docs,
    resolve_config,
)
from repro.serve import QueryRequest, ServeConfig, ServeEngine


def _q(eng, key, y, **kw):
    """One typed query, densities out."""
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

_REPO = Path(__file__).resolve().parents[1]
_GOLDEN = load_golden(_REPO / "tests" / "golden_plans.json")
_BENCH = BenchModel.load()


def _subenv():
    env = dict(os.environ)
    src = str(_REPO / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# Golden-decision conformance.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def recomputed():
    """Planner decisions recomputed fresh from the committed artifacts."""
    return golden_entries()


def test_golden_fixture_meta():
    assert _GOLDEN["meta"]["entries"] == len(_GOLDEN["plans"])
    assert _GOLDEN["meta"]["entries"] >= 15
    assert "--regen-golden" in _GOLDEN["meta"]["regen"]


def test_golden_covers_every_committed_cell():
    """Every shape-bearing benchmark cell derives a pinned request, and
    the fixture has no stale extras — derivation drift fails here."""
    want = {request_key(r) for r in requests_from_docs(load_docs())}
    assert want == set(_GOLDEN["plans"])


@pytest.mark.parametrize("key", sorted(_GOLDEN["plans"]))
def test_golden_decision(key, recomputed):
    """The planner's decision for this cell matches the pinned plan."""
    assert key in recomputed, f"no longer derived: {key}"
    pinned, fresh = _GOLDEN["plans"][key], recomputed[key]
    assert fresh["request"] == pinned["request"]
    assert fresh["plan"] == pinned["plan"], (
        f"plan drift for {key} — if intentional, rerun "
        "`python -m repro.plan --regen-golden`"
    )


def test_golden_plans_all_valid(recomputed):
    for key, entry in recomputed.items():
        req = PlanRequest(**entry["request"])
        p = plan(req, bench=_BENCH)
        assert p.validate() == [], key


def test_plans_match_or_beat_default_path():
    """Acceptance bar: on every committed cell the planner's modeled cost
    is within the 15% regression gate of — in practice, well under — the
    current default serve path (f32 @ 128x512, prune auto)."""
    for key, entry in _GOLDEN["plans"].items():
        if entry["plan"]["backend"] != "pallas":
            continue
        r = entry["request"]
        default = autotune.modeled_cost(
            r["q"], r["n"], r["d"], block_m=128,
            block_n=min(512, r["n"]) if r["n"] >= 128 else 128,
            precision="f32", vmem_itemsize=4,
        )
        if default is None:
            continue
        got = entry["plan"]["modeled_cost_us"] * 1e-6
        assert got <= default.step_time * 1.15, (
            f"{key}: planned {got * 1e6:.1f}us worse than default "
            f"{default.step_time * 1e6:.1f}us beyond the 15% gate"
        )


# ---------------------------------------------------------------------------
# Regen CLI (the deliberate-rewrite path).
# ---------------------------------------------------------------------------


def test_regen_cli_reproduces_committed_fixture(tmp_path):
    """--regen-golden writes a byte-stable fixture identical to the
    committed one (i.e. the committed fixture is up to date)."""
    out = tmp_path / "golden.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.plan", "--regen-golden",
         "--golden", str(out)],
        capture_output=True, text=True, env=_subenv(), timeout=300,
    )
    assert r.returncode == 0, r.stderr
    assert json.loads(out.read_text()) == _GOLDEN
    committed = (_REPO / "tests" / "golden_plans.json").read_text()
    assert out.read_text() == committed


def test_cli_adhoc_plan_json():
    r = subprocess.run(
        [sys.executable, "-m", "repro.plan", "--n", "262144", "--d", "16",
         "--q", "32768"],
        capture_output=True, text=True, env=_subenv(), timeout=300,
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads(r.stdout)
    assert doc["plan"]["backend"] == "pallas"
    assert doc["plan"]["block_m"] % 8 == 0
    assert "/" in doc["plan_id"]


def test_cli_requires_shape_or_regen():
    r = subprocess.run(
        [sys.executable, "-m", "repro.plan"],
        capture_output=True, text=True, env=_subenv(), timeout=300,
    )
    assert r.returncode != 0


# ---------------------------------------------------------------------------
# Decision rules (unit).
# ---------------------------------------------------------------------------


def test_tier_admissibility():
    assert plan_for(4096, 8, accuracy=1e-5, bench=_BENCH).precision == "f32"
    # tighter than f32's own bar still resolves (f32 is the reference)
    assert plan_for(4096, 8, accuracy=1e-9, bench=_BENCH).precision == "f32"
    loose = plan_for(4096, 8, accuracy=5e-2, bench=_BENCH)
    assert TIER_RTOL[loose.precision] <= 5e-2
    mid = plan_for(4096, 8, accuracy=5e-4, bench=_BENCH)
    assert mid.precision in ("f32", "bf16x2")


def test_backend_auto_routing():
    assert plan_for(PALLAS_MIN_COLS - 1, 8, bench=_BENCH).backend == "jnp"
    assert plan_for(PALLAS_MIN_COLS, 8, bench=_BENCH).backend == "pallas"


def test_backend_explicit_honored():
    p = plan_for(262144, 16, backend="jnp", bench=_BENCH)
    assert p.backend == "jnp"
    assert p.prune == "off" and p.block_m is None
    assert plan_for(64, 4, backend="pallas", bench=_BENCH).backend == "pallas"
    r = plan_for(8192, 8, backend="ring", bench=_BENCH)
    assert r.backend == "ring" and r.prune == "off"
    # "auto" never routes to the ring — multi-host is an explicit choice
    for n in (64, 8192, 1 << 20):
        assert plan_for(n, 8, bench=_BENCH).backend != "ring"


def test_prune_off_below_threshold():
    p = plan_for(ops.PRUNE_AUTO_MIN_COLS - 1, 16, bench=_BENCH)
    assert p.prune == "off"


def test_prune_promoted_by_measured_cells():
    # the committed 262144x16 pruning sweep measured eps up to 1e-6 at
    # zero observed error; accuracy 1e-5 licenses eps<=1e-7 -> 1e-9 wins
    p = plan_for(262144, 16, q=32768, accuracy=1e-5, bench=_BENCH)
    assert p.prune == pytest.approx(1e-9)
    assert p.occupancy < 1.0            # measured occupancy priced in
    # a looser target promotes the larger measured epsilon
    p4 = plan_for(262144, 16, q=32768, accuracy=1e-4, bench=_BENCH)
    assert p4.prune == pytest.approx(1e-6)
    assert p4.modeled_cost_s <= p.modeled_cost_s


def test_prune_unmeasured_regime_stays_exact():
    # no committed pruning cells for this regime: epsilon>0 is never
    # licensed, only exact (certified-underflow) pruning
    p = plan_for(65536, 3, accuracy=5e-2, bench=_BENCH)
    assert p.prune == pytest.approx(0.0)


def test_prune_epsilon_accuracy_rule():
    for acc in (1e-5, 1e-4, 1e-3, 5e-2):
        p = plan_for(262144, 16, accuracy=acc, bench=_BENCH)
        if isinstance(p.prune, float) and p.prune > 0:
            assert p.prune * EPS_SAFETY <= acc


def test_staleness_policy():
    assert plan_for(4096, 8, bench=_BENCH).staleness_budget == 0
    s0 = plan_for(4096, 8, stream=True, accuracy=1e-5, bench=_BENCH)
    assert s0.staleness_budget == 0 and not s0.stream_background
    s1 = plan_for(4096, 8, stream=True, accuracy=5e-4, bench=_BENCH)
    assert s1.staleness_budget == 1 and s1.stream_background
    s2 = plan_for(4096, 8, stream=True, accuracy=5e-2, bench=_BENCH)
    assert s2.staleness_budget == 2 and s2.stream_background


def test_monotone_cost_in_n():
    """Doubling the train count never makes the planned pass cheaper."""
    empty = BenchModel()
    for d, q in ((2, 256), (16, 1024), (64, 256)):
        prev = 0.0
        for n in (64, 256, 1024, 2048, 4096, 16384, 65536, 262144):
            c = plan_for(n, d, q=q, bench=empty).modeled_cost_s
            assert c >= prev, (d, q, n)
            prev = c


# ---------------------------------------------------------------------------
# Plan validity (schema-level).
# ---------------------------------------------------------------------------


def _mk(req, **kw):
    base = dict(request=req, backend="pallas", precision="f32",
                prune="off", block_m=8, block_n=128,
                modeled_cost_s=1e-6, bound="vpu")
    base.update(kw)
    return ExecutionPlan(**base)


def test_validate_block_multiples():
    req = PlanRequest(n=4096, d=8)
    assert any("multiple of 8" in p
               for p in _mk(req, block_m=12).validate())
    assert any("multiple of 128" in p
               for p in _mk(req, block_n=200).validate())
    assert _mk(req).validate() == []


def test_validate_vmem_budget():
    req = PlanRequest(n=65536, d=512)
    bad = _mk(req, block_m=2048, block_n=4096)
    assert any("VMEM" in p or "vmem" in p for p in bad.validate())


def test_validate_epsilon_budget():
    req = PlanRequest(n=65536, d=8, accuracy=1e-5)
    bad = _mk(req, prune=1e-6)           # 1e-6 * 100 > 1e-5
    assert any("epsilon" in p for p in bad.validate())
    assert _mk(req, prune=1e-8).validate() == []
    assert any("< 0" in p for p in _mk(req, prune=-1.0).validate())


def test_validate_tier_vs_accuracy():
    req = PlanRequest(n=4096, d=8, accuracy=1e-5)
    bad = _mk(req, precision="bf16")
    assert any("exceeds accuracy" in p for p in bad.validate())


def test_validate_backend_constraints():
    req = PlanRequest(n=4096, d=8)
    jnp_pruned = ExecutionPlan(request=req, backend="jnp",
                               precision="f32", prune=0.0)
    assert any("pallas" in p for p in jnp_pruned.validate())
    stale = ExecutionPlan(request=req, backend="jnp", precision="f32",
                          prune="off", staleness_budget=1)
    assert any("staleness" in p for p in stale.validate())
    with pytest.raises(ValueError, match="invalid execution plan"):
        jnp_pruned.check()


def test_plan_request_validation():
    with pytest.raises(ValueError):
        PlanRequest(n=0, d=8)
    with pytest.raises(ValueError):
        PlanRequest(n=8, d=8, accuracy=0.0)
    with pytest.raises(ValueError):
        PlanRequest(n=8, d=8, backend="tpu")


def test_plan_id_stable_format():
    p = plan_for(262144, 16, q=32768, bench=_BENCH)
    assert p.plan_id == "pallas/f32/prune=1e-09/2048x128"
    assert plan_for(64, 4, bench=_BENCH).plan_id == "jnp/f32/prune=off/-"


# ---------------------------------------------------------------------------
# Property suite: randomized requests are always valid.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # degrades to a fixed-seed sweep
    _HAVE_HYPOTHESIS = False


_ACCURACIES = [1e-6, 1e-5, 1e-4, 5e-4, 1e-2, 5e-2, 1.0]


def _valid_plan_case(n, d, q, accuracy, backend, stream):
    req = PlanRequest(n=n, d=d, q=q, accuracy=accuracy,
                      backend=backend, stream=stream)
    p = plan(req, bench=_BENCH)
    assert p.validate() == []
    if p.backend == "pallas":
        assert p.block_m % 8 == 0
        assert p.block_n % 128 == 0
        ops._check_vmem(p.block_m, p.block_n, d, itemsize=4, out_width=1)
    else:
        assert p.prune == "off"
        assert p.block_m is None and p.block_n is None
    if isinstance(p.prune, float) and p.prune > 0:
        assert p.prune * EPS_SAFETY <= accuracy
    if not stream:
        assert p.staleness_budget == 0
    assert TIER_RTOL[p.precision] <= max(accuracy, TIER_RTOL["f32"])
    assert p.modeled_cost_s > 0


if _HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        n=st.integers(1, 1 << 20),
        d=st.integers(1, 128),
        q=st.integers(1, 16384),
        accuracy=st.sampled_from(_ACCURACIES),
        backend=st.sampled_from(["auto", "jnp", "pallas"]),
        stream=st.booleans(),
    )
    def test_random_plans_always_valid(n, d, q, accuracy, backend, stream):
        _valid_plan_case(n, d, q, accuracy, backend, stream)

else:

    @pytest.mark.parametrize("seed", range(10))
    def test_random_plans_always_valid(seed):
        rng = np.random.default_rng(seed)
        for _ in range(8):
            _valid_plan_case(
                n=int(rng.integers(1, 1 << 20)),
                d=int(rng.integers(1, 129)),
                q=int(rng.integers(1, 16385)),
                accuracy=float(rng.choice(_ACCURACIES)),
                backend=str(rng.choice(["auto", "jnp", "pallas"])),
                stream=bool(rng.integers(0, 2)),
            )


# ---------------------------------------------------------------------------
# eps=0 plans are dense (the pruning oracle, via the plan= kwarg).
# ---------------------------------------------------------------------------


def _eps0_dense_case(seed, h):
    rng = np.random.default_rng(seed)
    x = np.asarray(rng.normal(size=(512, 3)), np.float32)
    y = np.asarray(rng.normal(size=(96, 3)), np.float32)
    req = PlanRequest(n=512, d=3, q=96, backend="pallas")
    p = _mk(req, prune=0.0, block_m=8, block_n=128).check()
    pruned = ops.flash_kde(x, y, h, interpret=True, plan=p)
    dense = ops.flash_kde(x, y, h, interpret=True, prune="off",
                          block_m=8, block_n=128)
    # the eps=0 oracle bar from tests/test_pruning.py: identical up to
    # summation order
    np.testing.assert_allclose(np.asarray(pruned), np.asarray(dense),
                               rtol=1e-6, atol=1e-20)


if _HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000), h=st.floats(0.1, 1.0))
    def test_eps0_plan_is_dense(seed, h):
        _eps0_dense_case(seed, h)

else:

    @pytest.mark.parametrize("seed,h", [(0, 0.3), (1, 0.8), (2, 0.15)])
    def test_eps0_plan_is_dense(seed, h):
        _eps0_dense_case(seed, h)


# ---------------------------------------------------------------------------
# Wiring: resolve_config precedence, ops plan kwarg, prewarm, metrics.
# ---------------------------------------------------------------------------


def test_resolve_config_fills_defaults():
    cfg = ServeConfig(plan="auto", min_batch=16, max_batch=128)
    resolved, p = resolve_config(cfg, n=262144, d=16, bench=_BENCH)
    assert p.validate() == []
    assert resolved.backend == p.backend == "pallas"
    assert resolved.precision == p.precision
    assert resolved.prune == p.prune
    assert resolved.block_m == p.block_m
    assert resolved.block_n == p.block_n
    assert p.request.q == 128            # q = the config's max_batch


def test_resolve_config_explicit_wins():
    cfg = ServeConfig(plan="auto", backend="ring", block_m=64,
                      min_batch=16, max_batch=128)
    resolved, p = resolve_config(cfg, n=262144, d=16, bench=_BENCH)
    # explicitly-set (non-default) knobs survive plan resolution untouched
    assert resolved.backend == "ring" == p.backend
    assert resolved.block_m == 64
    assert resolved.prune == "off"       # non-pallas plans never prune


def test_resolve_config_default_value_reads_as_unset():
    # setting a knob TO its dataclass default is indistinguishable from
    # not setting it — the planner owns it (pass plan="off" to pin all)
    cfg = ServeConfig(plan="auto", backend="jnp",
                      min_batch=16, max_batch=128)
    resolved, p = resolve_config(cfg, n=262144, d=16, bench=_BENCH)
    assert resolved.backend == "pallas" == p.backend


def test_resolve_config_accuracy_target():
    cfg = ServeConfig(plan="auto", accuracy_target=1e-4,
                      min_batch=16, max_batch=128)
    _, p = resolve_config(cfg, n=262144, d=16, bench=_BENCH)
    assert p.request.accuracy == 1e-4
    assert p.prune == pytest.approx(1e-6)


def test_serve_config_plan_validation():
    with pytest.raises(ValueError, match="plan"):
        ServeConfig(plan="maybe")
    with pytest.raises(ValueError, match="accuracy_target"):
        ServeConfig(plan="auto", accuracy_target=-1.0)


def test_ops_plan_kwarg_matches_explicit_knobs():
    rng = np.random.default_rng(3)
    x = np.asarray(rng.normal(size=(2048, 4)), np.float32)
    y = np.asarray(rng.normal(size=(64, 4)), np.float32)
    p = plan_for(2048, 4, q=64, backend="pallas", bench=_BENCH)
    a = ops.flash_kde(x, y, 0.5, interpret=True, plan=p)
    b = ops.flash_kde(x, y, 0.5, interpret=True, precision=p.precision,
                      block_m=p.block_m, block_n=p.block_n, prune=p.prune)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-7)


def test_ops_plan_auto_resolves_per_call():
    rng = np.random.default_rng(4)
    x = np.asarray(rng.normal(size=(2048, 4)), np.float32)
    y = np.asarray(rng.normal(size=(32, 4)), np.float32)
    a = ops.flash_kde(x, y, 0.5, interpret=True)
    b = ops.flash_kde(x, y, 0.5, interpret=True, plan="auto")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_engine_prewarm_builds_chosen_executable():
    rng = np.random.default_rng(5)
    x = np.asarray(rng.normal(size=(512, 4)), np.float32)
    cfg = ServeConfig(plan="auto", min_batch=16, max_batch=64)
    eng = ServeEngine(cfg)
    prep = eng.register("warm", x)
    assert prep.plan is not None
    assert len(eng.cache) == 1           # largest bucket built at register
    misses = eng.cache.misses
    _q(eng, "warm", x[:64])
    assert eng.cache.misses == misses    # served by the prewarmed program


def test_plan_decision_metrics_emitted():
    before = {k: v for k, v in obs.metrics_snapshot().items()
              if k.startswith("plan.decisions")}
    p = plan_for(262144, 16, q=32768, bench=_BENCH)
    key = (f"plan.decisions{{backend={p.backend},prune=eps,"
           f"tier={p.precision}}}")
    after = obs.metrics_snapshot()
    assert after[key]["value"] >= before.get(key, {}).get("value", 0) + 1


def test_dispatch_span_carries_plan_id():
    rng = np.random.default_rng(6)
    x = np.asarray(rng.normal(size=(512, 4)), np.float32)
    obs.configure(trace=True)
    try:
        eng = ServeEngine(ServeConfig(plan="auto", min_batch=16,
                                      max_batch=64))
        prep = eng.register("traced", x)
        _q(eng, "traced", x[:8])
        spans = [e for e in eng.trace_events()
                 if e.get("name") == "serve.dispatch"
                 and e.get("attrs", {}).get("key") == "traced"]
        assert spans
        assert spans[-1]["attrs"]["plan"] == prep.plan.plan_id
    finally:
        obs.configure(trace=False)
