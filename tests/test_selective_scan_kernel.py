"""Allclose sweep: chunked Pallas selective scan vs the sequential oracle,
and vs the model's associative-scan mamba path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ref_selective_scan
from repro.kernels.selective_scan import selective_scan_pallas

SHAPES = [
    # B, S, D, N, block_d, chunk
    (1, 64, 32, 8, 16, 16),
    (2, 128, 64, 16, 32, 32),
    (2, 96, 48, 4, 16, 32),     # chunk > S/chunks alignment edge
    (1, 256, 128, 16, 128, 64),
]


def _inputs(bsz, s, d, n, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    xi = jax.random.normal(ks[0], (bsz, s, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, d), dtype))
    b = jax.random.normal(ks[2], (bsz, s, n), dtype)
    c = jax.random.normal(ks[3], (bsz, s, n), dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (d, n), jnp.float32) * 0.5)
    h0 = jax.random.normal(ks[5], (bsz, d, n), jnp.float32) * 0.1
    return xi, dt, b, c, a, h0


@pytest.mark.parametrize("bsz,s,d,n,bd,ck", SHAPES)
def test_matches_sequential_oracle(bsz, s, d, n, bd, ck):
    xi, dt, b, c, a, h0 = _inputs(bsz, s, d, n)
    y, h = selective_scan_pallas(xi, dt, b, c, a, h0,
                                 block_d=bd, chunk=ck, interpret=True)
    y_ref, h_ref = ref_selective_scan(xi, dt, b, c, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-5)


def test_zero_initial_state_matches_mamba_block_scan():
    """Cross-check against the model's associative-scan formulation."""
    bsz, s, d, n = 2, 64, 32, 8
    xi, dt, b, c, a, h0 = _inputs(bsz, s, d, n, seed=1)
    h0 = jnp.zeros_like(h0)
    y, _ = selective_scan_pallas(xi, dt, b, c, a, h0,
                                 block_d=16, chunk=16, interpret=True)

    # models/ssm.py inline recurrence (same math, log-depth over full S)
    decay = jnp.exp(dt[..., None] * a[None, None])
    drive = (dt * xi)[..., None] * b[..., None, :]

    def combine(l, r):
        dl, vl = l
        dr, vr = r
        return dl * dr, vr + dr * vl

    _, hs = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    y_ref = jnp.einsum("bsdn,bsn->bsd", hs, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_bf16_inputs_f32_accumulation():
    bsz, s, d, n = 1, 64, 32, 8
    xi, dt, b, c, a, h0 = _inputs(bsz, s, d, n, seed=2, dtype=jnp.bfloat16)
    y, h = selective_scan_pallas(xi, dt, b, c, a, h0,
                                 block_d=16, chunk=16, interpret=True)
    y_ref, h_ref = ref_selective_scan(xi, dt, b, c, a, h0)
    assert y.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-2, atol=5e-2)
