"""RFF fast tier + accuracy cascade (kernels/flash_rff.py, serve/cascade.py).

The contract under test, end to end:

  * the certified band dominates the realized error against an exact
    reference on every query, across seeds and shapes (the certificate
    the cascade routes on);
  * a loose ``accuracy_target`` resolves at the RFF tier, a tight one
    escalates to the exact kernel, and escalated rows are bit-identical
    to the exact path;
  * precision pins beat the cascade in both directions (``"rff"`` forces
    the fast tier, an exact pin skips it);
  * fused ``query_many`` members gate per member;
  * streaming generation flips keep RFF answers certified against the
    *updated* live set (incremental feature-sum sync, no refit).
"""

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.kernels import flash_rff
from repro.serve import QueryRequest, ServeConfig, ServeEngine

D2 = 2


def _sample(n, d, seed=0, queries=64):
    mix = mixture_for_dim(d)
    key = jax.random.PRNGKey(seed)
    x = np.asarray(mix.sample(key, n), np.float32)
    y = np.asarray(mix.sample(jax.random.fold_in(key, 7), queries),
                   np.float32)
    return x, y


def _engine(x, h=0.4, **kw):
    base = dict(backend="jnp", method="kde", rff="on", rff_features=512,
                rff_pilot=32, min_batch=16, max_batch=128)
    base.update(kw)
    eng = ServeEngine(ServeConfig(**base))
    eng.register("ds", x, h=h)
    return eng


# ---------------------------------------------------------------------------
# The certificate: band dominates realized error.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,d", [(2048, 2), (2048, 4), (4096, 3)])
def test_band_dominates_realized_error(n, d, seed):
    x, y = _sample(n, d, seed=seed, queries=128)
    h = 0.5
    st = flash_rff.fit(x, h, n_features=2048, n_pilot=64, seed=seed)
    p, band = flash_rff.eval_density(st.serving(), y)
    p, band = np.asarray(p, np.float64), np.asarray(band, np.float64)
    assert (p >= 0.0).all() and (band > 0.0).all()
    want = np.asarray(ref.kde_eval(x, y, h, block=1024), np.float64)
    realized = flash_rff.realized_error(p, want, st.p_scale)
    assert float((realized - band).max()) <= 0.0, (
        f"certified band violated by {float((realized - band).max()):.2e}")


def test_modeled_cost_monotone_in_features():
    lo = flash_rff.modeled_query_cost_us(1024, 4, n_features=2048)
    hi = flash_rff.modeled_query_cost_us(1024, 4, n_features=8192)
    assert 0.0 < lo < hi
    # the pilot pass adds cost once it stops being noise
    assert flash_rff.modeled_query_cost_us(
        1024, 4, n_features=2048, n_pilot=1024) > lo


# ---------------------------------------------------------------------------
# Cascade routing through the engine.
# ---------------------------------------------------------------------------


def test_cascade_answers_on_loose_target():
    x, y = _sample(1024, D2)
    eng = _engine(x)
    ans = eng.query(QueryRequest(key="ds", points=y, accuracy_target=10.0))
    assert ans.rff_hits == y.shape[0] and ans.escalated == 0
    assert ans.path == ("rff",) and ans.tier == "rff"
    # the answered rows carry the band, and the band fits the target
    assert ans.rel_err_bounds is not None
    assert float(ans.rel_err_bounds.max()) <= 10.0
    want = np.asarray(ref.kde_eval(x, y, 0.4, block=1024), np.float64)
    state = eng.registry.get("ds").rff.state
    realized = flash_rff.realized_error(
        np.asarray(ans.value, np.float64), want, state.p_scale)
    assert float((realized - np.asarray(ans.rel_err_bounds)).max()) <= 0.0


def test_cascade_escalates_on_tight_target():
    x, y = _sample(1024, D2)
    eng = _engine(x)
    ans = eng.query(QueryRequest(key="ds", points=y,
                                 accuracy_target=1e-9))
    assert ans.rff_hits == 0 and ans.escalated == y.shape[0]
    assert ans.path[-1] == "f32"
    # escalated rows ARE the exact path
    want = eng.query(QueryRequest(key="ds", points=y, precision="f32"))
    np.testing.assert_array_equal(np.asarray(ans.value),
                                  np.asarray(want.value))


def test_rff_pin_forces_fast_tier():
    x, y = _sample(1024, D2)
    eng = _engine(x)
    # the pin IS the routing decision: even an impossible target doesn't
    # escalate a pinned request
    ans = eng.query(QueryRequest(key="ds", points=y, precision="rff",
                                 accuracy_target=1e-9))
    assert ans.tier == "rff" and ans.escalated == 0
    assert ans.rff_hits == y.shape[0]


def test_exact_pin_skips_cascade():
    x, y = _sample(1024, D2)
    eng = _engine(x)
    ans = eng.query(QueryRequest(key="ds", points=y, precision="f32",
                                 accuracy_target=10.0))
    assert ans.tier == "f32" and ans.rff_hits == 0
    assert ans.path == ("f32",)


def test_rff_pin_raises_when_tier_disabled():
    x, y = _sample(512, D2)
    eng = _engine(x, rff="off")
    from repro.serve.engine import BadRequest
    with pytest.raises(BadRequest, match="rff"):
        eng.query(QueryRequest(key="ds", points=y, precision="rff"))


def test_query_many_gates_per_member():
    x, y = _sample(1024, D2, queries=96)
    eng = _engine(x)
    reqs = [
        QueryRequest(key="ds", points=y[:32], accuracy_target=10.0),
        QueryRequest(key="ds", points=y[32:64], accuracy_target=1e-9),
        QueryRequest(key="ds", points=y[64:]),     # no target: exact
    ]
    loose, tight, plain = eng.query_many(reqs)
    assert loose.rff_hits == 32 and loose.escalated == 0
    assert tight.rff_hits == 0 and tight.escalated == 32
    assert plain.rff_hits == 0
    want = np.asarray(
        eng.query(QueryRequest(key="ds", points=y[32:64],
                               precision="f32")).value)
    np.testing.assert_array_equal(np.asarray(tight.value), want)


def test_cascade_counters_and_band_histogram():
    x, y = _sample(1024, D2)
    eng = _engine(x)

    def val(name):
        m = obs.metrics_snapshot().get(name)
        return m["value"] if m else 0

    hits0, esc0 = val("serve.cascade_hits"), val("serve.cascade_escalations")
    eng.query(QueryRequest(key="ds", points=y, accuracy_target=10.0))
    eng.query(QueryRequest(key="ds", points=y, accuracy_target=1e-9))
    assert val("serve.cascade_hits") == hits0 + y.shape[0]
    assert val("serve.cascade_escalations") == esc0 + y.shape[0]


# ---------------------------------------------------------------------------
# Streaming: generation flips keep the fast tier certified.
# ---------------------------------------------------------------------------


def test_streaming_generation_flip_keeps_rff_certified():
    x, y = _sample(2048, D2)
    h = 0.4
    eng = _engine(x, h=h, stream=True, rff_features=1024, rff_pilot=32)

    def syncs():
        m = obs.metrics_snapshot().get("rff.incremental_syncs")
        return m["value"] if m else 0

    ans0 = eng.query(QueryRequest(key="ds", points=y,
                                  accuracy_target=10.0))
    assert ans0.rff_hits == y.shape[0]

    before = syncs()
    mix = mixture_for_dim(D2)
    fresh = np.asarray(mix.sample(jax.random.PRNGKey(99), 64), np.float32)
    eng.registry.slide("ds", fresh)       # append batch + evict oldest
    ans1 = eng.query(QueryRequest(key="ds", points=y,
                                  accuracy_target=10.0))
    assert ans1.rff_hits == y.shape[0]
    assert syncs() == before + 1          # delta sync, not a refit

    # certified against the UPDATED live set, not the fit-time one
    st = eng.registry.get("ds").stream
    st.ensure(0)
    want = np.asarray(ref.kde_eval(st.x, y, h, block=1024), np.float64)
    state = eng.registry.get("ds").rff.state
    realized = flash_rff.realized_error(
        np.asarray(ans1.value, np.float64), want, state.p_scale)
    assert float((realized - np.asarray(ans1.rel_err_bounds)).max()) <= 0.0
    # and the flip actually moved the answer (the live set changed)
    assert not np.allclose(np.asarray(ans0.value), np.asarray(ans1.value))
