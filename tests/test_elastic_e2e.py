"""Elastic restart end-to-end: train on mesh A, checkpoint, restore on a
SMALLER mesh B with resharding, continue training — the loss trajectory
must continue smoothly (the restored step matches the uninterrupted run's
state bit-for-bit up to resharding layout)."""

import os
import subprocess
import sys

import pytest

_CHILD = r"""
import dataclasses, tempfile
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.configs import ShapeCfg, get_arch
from repro.launch.steps import (abstract_opt_state, abstract_params,
                                make_train_step)
from repro.launch.train import shaped_batch
from repro.models.common import init_params
from repro.optim.adamw import adamw_init

arch = get_arch('gemma2_2b')
arch = dataclasses.replace(arch, model=arch.model.reduced(dtype=jnp.float32))
cfg = arch.model
shape = ShapeCfg('t', 'train', 32, 8, microbatches=2)

def mesh_of(n_data):
    devs = np.asarray(jax.devices()[: n_data * 2]).reshape(n_data, 2)
    return Mesh(devs, ('data', 'model'))

def run(mesh, params, opt, start, steps):
    fn, _, donate = make_train_step(arch, mesh, shape, peak_lr=1e-3, warmup=2)
    jit = jax.jit(fn, donate_argnums=donate)
    losses = []
    for s in range(start, start + steps):
        params, opt, m = jit(params, opt, shaped_batch(cfg, 0, s, shape))
        losses.append(float(m['loss']))
    return params, opt, losses

with tempfile.TemporaryDirectory() as ckdir:
    # phase 1: 4x2 mesh, 6 steps, checkpoint
    mesh_a = mesh_of(4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    params, opt, l1 = run(mesh_a, params, opt, 0, 6)
    mgr = CheckpointManager(ckdir)
    mgr.save(6, {'params': params, 'opt': opt}, blocking=True)

    # uninterrupted continuation on mesh A (the reference)
    p_ref, o_ref, l_ref = run(mesh_a, params, opt, 6, 4)

    # phase 2: "two hosts died" -> restore on a 2x2 mesh with resharding
    mesh_b = mesh_of(2)
    sh = {
        'params': jax.tree.map(lambda a: a.sharding,
                               abstract_params(cfg, mesh_b)),
        'opt': jax.tree.map(lambda a: a.sharding,
                            abstract_opt_state(arch, mesh_b)),
    }
    state = mgr.restore(sh)
    p2, o2, l2 = run(mesh_b, state['params'], state['opt'], 6, 4)

    np.testing.assert_allclose(l2, l_ref, rtol=2e-4, atol=1e-4)
    print('losses match across elastic restart:', [f'{a:.4f}' for a in l2])
print('ALL_OK')
"""


@pytest.mark.slow
def test_elastic_restart_preserves_trajectory():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], env=env, capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=1200,
    )
    assert "ALL_OK" in out.stdout, out.stdout[-1500:] + out.stderr[-2500:]
