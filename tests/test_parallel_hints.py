"""models/parallel.py: mesh registry + sharding-hint semantics."""

import jax
import jax.numpy as jnp
import pytest

try:  # jax >= 0.5; older releases have no explicit-sharding axis types
    from jax.sharding import AxisType  # noqa: F401
except ImportError:
    AxisType = None

from repro.models import parallel


@pytest.fixture
def mesh():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    import numpy as np
    from jax.sharding import Mesh

    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_hint_noop_without_mesh():
    parallel.set_mesh(None)
    x = jnp.ones((4, 8))
    y = parallel.hint(x, "dp", "model")
    assert y is x


def test_hint_skips_indivisible_dims(mesh):
    with parallel.model_mesh(mesh):
        # mesh sizes are 1 so everything divides; check entry resolution
        x = jnp.ones((4, 8, 2))
        y = parallel.hint(x, "dp", "model", None)
        assert y.shape == x.shape


def test_dp_axes_reads_registry(mesh):
    parallel.set_mesh(None)
    assert parallel.dp_axes() == ()
    with parallel.model_mesh(mesh):
        # axis sizes are 1 -> excluded (nothing to shard over)
        assert parallel.dp_axes() == ()
    assert parallel.get_mesh() is None


def test_model_mesh_restores_on_exception(mesh):
    parallel.set_mesh(None)
    try:
        with parallel.model_mesh(mesh):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert parallel.get_mesh() is None


def test_seq_shard_condition_auto_rule():
    """The divisibility rule from §Perf: hint only when heads don't divide."""
    import dataclasses

    from repro.configs import get_arch

    hinted = {"gemma2_2b": True,       # 8 heads
              "granite_moe_3b_a800m": True,   # 24 heads
              "minitron_8b": False,    # 32 heads
              "chatglm3_6b": False}    # 32 heads
    for arch_id, expect in hinted.items():
        cfg = get_arch(arch_id).model
        use = cfg.seq_shard_attn
        if use is None:
            use = cfg.n_heads % 16 != 0
        assert use == expect, arch_id
    # kimi overrides the rule (measured)
    assert get_arch("kimi_k2_1t_a32b").model.seq_shard_attn is True
