"""repro.serve: registry amortization, ragged-batch padding, bucket cache.

Small sizes + tiny Pallas tiles (interpret mode) keep this fast on CPU; the
full 4k/8-d acceptance check lives in benchmarks/serve_throughput.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kde as ref
from repro.serve import (
    EstimatorRegistry,
    QueryRequest,
    ServeConfig,
    ServeEngine,
    ShapeBucketCache,
    coalesce,
    pad_queries,
    split,
)


def _q(eng, key, y, **kw):
    """One typed query, densities out."""
    return eng.query(QueryRequest(key=key, points=y, **kw)).value

N, D, H = 384, 8, 0.6


@pytest.fixture(scope="module")
def data():
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    return (jax.random.normal(kx, (N, D)),
            jax.random.normal(ky, (300, D)))


def _cfg(backend="jnp", method="sdkde", **kw):
    base = dict(backend=backend, method=method, interpret=True,
                block_m=8, block_n=128, block=128,
                min_batch=16, max_batch=128)
    base.update(kw)
    return ServeConfig(**base)


# ---------------------------------------------------------------------------
# Registry: the quadratic debias pass runs once per dataset.
# ---------------------------------------------------------------------------


def test_registry_debias_runs_once_per_key(data):
    x, _ = data
    reg = EstimatorRegistry(_cfg())
    p1 = reg.fit("a", x, h=H)
    p2 = reg.fit("a", x, h=H)          # cache hit: no second score pass
    assert p1 is p2
    assert reg.n_fits == 1
    reg.fit("b", x[:128], h=H)         # different dataset: fits again
    assert reg.n_fits == 2
    p3 = reg.fit("a", x, h=H, refit=True)
    assert reg.n_fits == 3 and p3 is not p1


def test_registry_prepared_state_matches_reference_shift(data):
    x, _ = data
    prep = EstimatorRegistry(_cfg(backend="jnp")).fit("a", x, h=H)
    np.testing.assert_allclose(
        np.asarray(prep.points),
        np.asarray(ref.sdkde_shift(x, H, block=128)),
        rtol=1e-6,
    )
    # pallas prep carries the transposed layout + column norms
    prep_p = EstimatorRegistry(_cfg(backend="pallas")).fit("a", x, h=H)
    assert prep_p.xt is not None and prep_p.xt.shape[0] == D
    assert prep_p.xt.shape[1] % 128 == 0          # padded to block_n
    assert prep_p.nrm_x.shape == (1, prep_p.xt.shape[1])


# ---------------------------------------------------------------------------
# Ragged batches: padding never changes densities (vs jnp reference).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "ring"])
@pytest.mark.parametrize("method", ["kde", "sdkde", "laplace"])
def test_ragged_batches_match_reference(data, backend, method):
    x, y = data
    eng = ServeEngine(_cfg(backend=backend, method=method))
    eng.register("ds", x, h=H)
    ref_fn = {"kde": ref.kde_eval, "sdkde": ref.sdkde_eval,
              "laplace": ref.laplace_kde_eval}[method]
    want = np.asarray(ref_fn(x, y, H, block=128))
    for m in (1, 7, 16, 33, 128):      # spans buckets incl. exact fits
        got = np.asarray(_q(eng, "ds", y[:m]))
        assert got.shape == (m,)
        np.testing.assert_allclose(got, want[:m], rtol=1e-5,
                                   atol=1e-6 * want.max())


def test_oversize_batch_chunks_at_largest_bucket(data):
    x, y = data
    eng = ServeEngine(_cfg())          # max bucket 128 < 300 queries
    eng.register("ds", x, h=H)
    got = np.asarray(_q(eng, "ds", y))
    want = np.asarray(ref.sdkde_eval(x, y, H, block=128))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6 * want.max())


def test_query_many_coalesces_to_one_dispatch(data):
    x, y = data
    eng = ServeEngine(_cfg(backend="pallas", method="kde"))
    eng.register("ds", x, h=H)
    outs = [a.value for a in eng.query_many(
        [QueryRequest(key="ds", points=q)
         for q in (y[:3], y[3:50], y[50:61])])]
    assert [o.shape[0] for o in outs] == [3, 47, 11]
    want = np.asarray(ref.kde_eval(x, y[:61], H, block=128))
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs)), want,
                               rtol=1e-5, atol=1e-6 * want.max())
    assert eng.latency.summary().count == 3     # 3 requests, 1 dispatch


def test_pad_queries_roundtrip(data):
    _, y = data
    yp = pad_queries(y[:5], 16)
    assert yp.shape == (16, D)
    fused, sizes = coalesce([y[:2], y[2:9]])
    parts = split(fused, sizes)
    assert [p.shape[0] for p in parts] == [2, 7]
    with pytest.raises(ValueError):
        pad_queries(y[:20], 16)


# ---------------------------------------------------------------------------
# Shape buckets: bounded compiled shapes, LRU behavior.
# ---------------------------------------------------------------------------


def test_bucket_ladder_respects_tile_multiples():
    cfg = _cfg(backend="pallas", block_m=8, min_batch=10, max_batch=100)
    sizes = cfg.bucket_sizes()
    assert all(b % 8 == 0 for b in sizes)
    assert sizes == tuple(sorted(set(sizes)))
    assert cfg.bucket_for(1) == sizes[0]
    assert cfg.bucket_for(sizes[-1]) == sizes[-1]


def test_shape_bucket_cache_hits_and_eviction(data):
    x, y = data
    eng = ServeEngine(_cfg(cache_buckets=2))
    eng.register("ds", x, h=H)
    _q(eng, "ds", y[:5])             # bucket 16: miss (compile)
    _q(eng, "ds", y[:9])             # bucket 16: hit
    _q(eng, "ds", y[:20])            # bucket 32: miss
    assert (eng.cache.hits, eng.cache.misses) == (1, 2)
    _q(eng, "ds", y[:40])            # bucket 64: miss -> evicts LRU (16)
    assert eng.cache.evictions == 1 and len(eng.cache) == 2
    _q(eng, "ds", y[:9])             # bucket 16 again: rebuilt (miss)
    assert eng.cache.misses == 4


def test_refit_invalidates_bucket_executables(data):
    x, y = data
    eng = ServeEngine(_cfg())
    eng.register("ds", x, h=H)
    stale = np.asarray(_q(eng, "ds", y[:8]))
    eng.register("ds", 2.0 + x, h=H, refit=True)   # dataset moved
    fresh = np.asarray(_q(eng, "ds", y[:8]))
    want = np.asarray(ref.sdkde_eval(2.0 + x, y[:8], H, block=128))
    np.testing.assert_allclose(fresh, want, rtol=1e-5,
                               atol=1e-6 * want.max())
    assert not np.allclose(fresh, stale)


def test_evict_and_reregister_never_serves_stale_executables(data):
    """Cache keys include the fit generation, so replacing a dataset by ANY
    path (here: evict + re-register, bypassing refit=True) gets fresh
    executables instead of closures over the old prepared estimator."""
    x, y = data
    eng = ServeEngine(_cfg())
    eng.register("ds", x, h=H)
    stale = np.asarray(_q(eng, "ds", y[:8]))
    eng.registry.evict("ds")
    eng.register("ds", 2.0 + x, h=H)       # no refit flag, no invalidate
    fresh = np.asarray(_q(eng, "ds", y[:8]))
    want = np.asarray(ref.sdkde_eval(2.0 + x, y[:8], H, block=128))
    np.testing.assert_allclose(fresh, want, rtol=1e-5,
                               atol=1e-6 * want.max())
    assert not np.allclose(fresh, stale)


def test_lru_cache_unit():
    c = ShapeBucketCache(capacity=2)
    built = []
    for k in ("a", "b", "a", "c", "b"):
        c.get_or_build(k, lambda k=k: built.append(k) or (lambda: k))
    assert built == ["a", "b", "c", "b"]   # 'b' evicted by 'c', rebuilt
    assert c.hits == 1 and c.misses == 4 and c.evictions == 2


# ---------------------------------------------------------------------------
# Execution planning (repro.plan): planner-chosen config == explicit knobs.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier", ["f32", "bf16", "bf16x2"])
def test_planned_config_matches_explicit_knobs(data, tier):
    """A plan-resolved estimator serves the same densities as one whose
    knobs are pinned by hand to the plan's choices (<= 1e-5 rel)."""
    x, y = data
    planned = ServeConfig(
        backend="pallas", method="sdkde", interpret=True, plan="auto",
        precision=tier,                   # explicit: wins over the plan
        min_batch=16, max_batch=128,
    )
    ep = ServeEngine(planned)
    prep = ep.register("ds", x, h=H)
    assert prep.plan is not None
    assert prep.config.precision == tier  # override precedence held
    got_p = np.asarray(_q(ep, "ds", y[:100]))

    explicit = ServeConfig(
        backend="pallas", method="sdkde", interpret=True,
        precision=tier, prune=prep.config.prune,
        block_m=prep.block_m, block_n=prep.block_n,
        min_batch=16, max_batch=128,
    )
    ee = ServeEngine(explicit)
    ee.register("ds", x, h=H)
    got_e = np.asarray(_q(ee, "ds", y[:100]))
    np.testing.assert_allclose(got_p, got_e, rtol=1e-5,
                               atol=1e-8 * float(np.max(got_e)))


def test_planned_estimator_still_matches_reference(data):
    x, y = data
    eng = ServeEngine(ServeConfig(
        backend="pallas", method="sdkde", interpret=True, plan="auto",
        min_batch=16, max_batch=128,
    ))
    eng.register("ds", x, h=H)
    got = np.asarray(_q(eng, "ds", y[:64]))
    want = np.asarray(ref.sdkde_eval(x, y[:64], H, block=128))
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-6 * float(want.max()))
