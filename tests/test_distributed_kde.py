"""Ring / ring2d distributed SD-KDE == single-device reference.

Runs on 8 forced host devices (subprocess-free: this file is executed by
pytest in the main process, so we spawn a child python with XLA_FLAGS —
the main test process must keep seeing ONE device for the smoke tests).
"""

import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import kde as ref
from repro.distributed import ring
from repro.distributed.ring2d import ring2d_sdkde, ring2d_kde_sums

def make_mesh(shape, axes):
    try:  # jax >= 0.5: explicit axis types
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        import numpy as np
        from jax.sharding import Mesh
        n = int(np.prod(shape))
        return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)

x = jax.random.normal(jax.random.PRNGKey(0), (256, 8))
y = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
h = 0.6
p_ref = np.asarray(ref.sdkde_eval(x, y, h, block=64))

mesh2 = make_mesh((4, 2), ('data', 'model'))
p = np.asarray(ring.ring_sdkde(x, y, h, mesh=mesh2))
np.testing.assert_allclose(p, p_ref, rtol=2e-4)

mesh3 = make_mesh((2, 2, 2), ('pod', 'data', 'model'))
p = np.asarray(ring.ring_sdkde(x, y, h, mesh=mesh3, pod_axis='pod'))
np.testing.assert_allclose(p, p_ref, rtol=2e-4)

p = np.asarray(ring2d_sdkde(x, y, h, mesh=mesh2, chunk=32))
np.testing.assert_allclose(p, p_ref, rtol=2e-4)

p = np.asarray(ring2d_sdkde(x, y, h, mesh=mesh3, chunk=32))
np.testing.assert_allclose(p, p_ref, rtol=2e-4)

# laplace variant on the ring
p_lc_ref = np.asarray(ref.laplace_kde_eval(x, y, h, block=64))
s = np.asarray(ring2d_kde_sums(y, x, h, mesh=mesh2, chunk=32, laplace=True))
from repro.core.bandwidth import gaussian_norm_const
p_lc = s / (256 * gaussian_norm_const(8, 1.0) * h**8)
np.testing.assert_allclose(p_lc, p_lc_ref, rtol=2e-4)

# ring KDE with explicit n_true (padding correctness)
xs = ring.shard_points(x[:200], mesh2, ('data',))
p_pad = np.asarray(ring.ring_kde(xs, y, h, n_true=200, mesh=mesh2))
p_pad_ref = np.asarray(ref.kde_eval(x[:200], y, h, block=64))
np.testing.assert_allclose(p_pad, p_pad_ref, rtol=2e-4)
print('ALL_OK')
"""


@pytest.mark.slow
def test_ring_variants_match_reference():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _CHILD], env=env, capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert "ALL_OK" in out.stdout, out.stdout + out.stderr
