"""The typed query API (serve/api.py): round-trip equivalence with the
legacy signatures, precedence, deprecation shims, and the shim lint.

Round-trip: every layer (plain engine, resilient engine, async frontend)
must answer a ``QueryRequest`` with exactly the densities its legacy
signature returned (≤1e-5 relative).  The legacy calls in this file are
the deliberately-kept shim exercises — each is marked ``legacy-api-ok``
for the lint at the bottom, which fails on any *unmarked* legacy caller
left in tests/benchmarks/examples.
"""

import re
from pathlib import Path

import jax
import numpy as np
import pytest

from repro import obs
from repro.serve import (AsyncFrontend, FrontendConfig, QueryRequest,
                         ResilienceConfig, ResilientEngine, ServeConfig,
                         ServeEngine)
from repro.serve.engine import BadRequest

D, H = 4, 0.5


@pytest.fixture(scope="module")
def data():
    kx, ky = jax.random.split(jax.random.PRNGKey(11))
    return (np.asarray(jax.random.normal(kx, (384, D)), np.float32),
            np.asarray(jax.random.normal(ky, (40, D)), np.float32))


def _engine(x, **kw):
    base = dict(backend="jnp", method="sdkde", min_batch=8, max_batch=64)
    base.update(kw)
    eng = ServeEngine(ServeConfig(**base))
    eng.register("ds", x, h=H)
    return eng


# ---------------------------------------------------------------------------
# QueryRequest validation.
# ---------------------------------------------------------------------------


def test_request_validates_fields():
    y = np.zeros((1, D), np.float32)
    with pytest.raises(ValueError, match="non-empty"):
        QueryRequest(key="", points=y)
    with pytest.raises(ValueError, match="precision pin"):
        QueryRequest(key="k", points=y, precision="f64")
    with pytest.raises(ValueError, match="accuracy_target"):
        QueryRequest(key="k", points=y, accuracy_target=0.0)
    with pytest.raises(ValueError, match="deadline_s"):
        QueryRequest(key="k", points=y, deadline_s=-1.0)
    # the RFF fast tier is a first-class pin
    assert QueryRequest(key="k", points=y, precision="rff").precision == "rff"


def test_mixing_typed_and_legacy_args_rejected(data):
    x, y = data
    eng = _engine(x)
    with pytest.raises(BadRequest, match="not both"):
        eng.query(QueryRequest(key="ds", points=y), y)


# ---------------------------------------------------------------------------
# Round-trip equivalence: typed API == legacy shims, every layer.
# ---------------------------------------------------------------------------


def test_engine_roundtrip_matches_legacy(data):
    x, y = data
    eng = _engine(x)
    ans = eng.query(QueryRequest(key="ds", points=y))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = eng.query("ds", y)                      # legacy-api-ok
    np.testing.assert_allclose(np.asarray(ans.value), np.asarray(legacy),
                               rtol=1e-5)
    assert ans.key == "ds" and ans.tier == "f32"
    assert ans.path == ("f32",)
    assert ans.rel_err_bound > 0.0                 # exact tier's rtol
    assert ans.rff_hits == 0 and ans.escalated == 0


def test_engine_query_many_roundtrip_matches_legacy(data):
    x, y = data
    eng = _engine(x)
    parts = [y[:7], y[7:19], y[19:]]
    answers = eng.query_many(
        [QueryRequest(key="ds", points=p) for p in parts])
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = eng.query_many("ds", parts)             # legacy-api-ok
    assert len(answers) == len(legacy) == len(parts)
    for a, l in zip(answers, legacy):
        np.testing.assert_allclose(np.asarray(a.value), np.asarray(l),
                                   rtol=1e-5)
        assert a.rel_err_bounds.shape == (np.asarray(l).shape[0],)


def test_resilient_roundtrip_matches_legacy(data):
    x, y = data
    eng = ResilientEngine(ServeConfig(backend="jnp", method="sdkde",
                                      min_batch=8, max_batch=64),
                          ResilienceConfig(shards=2, replicas=2))
    eng.register("ds", x, h=H)
    ans = eng.query(QueryRequest(key="ds", points=y))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = eng.query("ds", y)                      # legacy-api-ok
    np.testing.assert_allclose(np.asarray(ans.value),
                               np.asarray(legacy.value), rtol=1e-5)
    assert not ans.degraded and ans.rel_err_bound > 0.0


def test_frontend_roundtrip_matches_legacy(data):
    x, y = data
    eng = _engine(x)
    with AsyncFrontend(eng, FrontendConfig(workers=0)) as fe:
        fut = fe.submit(QueryRequest(key="ds", points=y, deadline_s=60.0))
        fe.pump()
        ans = fut.result(timeout=10)
        with pytest.warns(DeprecationWarning, match="deprecated"):
            fut2 = fe.submit("ds", y, deadline_s=60.0)   # legacy-api-ok
        fe.pump()
        legacy = fut2.result(timeout=10)
    np.testing.assert_allclose(np.asarray(ans.value),
                               np.asarray(legacy.value), rtol=1e-5)
    assert ans.batch_requests >= 1 and ans.latency_s >= 0.0


def test_answer_compat_views(data):
    x, y = data
    eng = _engine(x)
    ans = eng.query(QueryRequest(key="ds", points=y))
    # migrating callers read .densities/.precision off any layer's answer
    assert ans.densities is ans.value
    assert ans.precision == ans.tier


# ---------------------------------------------------------------------------
# Precedence: request pin > explicit config > planner.
# ---------------------------------------------------------------------------


def test_request_pin_beats_explicit_config(data):
    x, y = data
    eng = _engine(x, backend="pallas", interpret=True, block_m=8,
                  block_n=128, precision="bf16")
    ans = eng.query(QueryRequest(key="ds", points=y, precision="f32"))
    assert ans.tier == "f32"
    want = eng.query(QueryRequest(key="ds", points=y))
    assert want.tier == "bf16"                 # explicit config, unpinned


def test_pin_override_of_plan_is_counted(data):
    x, y = data
    eng = _engine(x, backend="pallas", interpret=True, block_m=8,
                  block_n=128, plan="auto", accuracy_target=1e-5)
    prep = eng.registry.get("ds")
    assert prep.plan is not None and prep.plan.precision == "f32"

    def overrides():
        m = obs.metrics_snapshot().get("serve.pin_overrides_plan")
        return m["value"] if m else 0

    before = overrides()
    ans = eng.query(QueryRequest(key="ds", points=y, precision="bf16"))
    assert ans.tier == "bf16"
    after = overrides()
    assert after == before + 1
    # a pin that AGREES with the plan is not an override
    eng.query(QueryRequest(key="ds", points=y, precision="f32"))
    assert overrides() == after


# ---------------------------------------------------------------------------
# Deprecation-shim lint: no unmarked legacy callers left in-repo.
# ---------------------------------------------------------------------------

_LEGACY_CALL = re.compile(r"\.(query|query_many|submit)\(\s*[\"'fr]*[\"']")
_MARKER = "legacy-api-ok"
_SCAN_DIRS = ("tests", "benchmarks", "examples")


def test_no_unmarked_legacy_callers():
    """Every in-repo caller uses the typed API; deliberate shim exercises
    carry the ``legacy-api-ok`` marker.  This is the CI lint the shims'
    one-release deprecation window is enforced by."""
    root = Path(__file__).resolve().parents[1]
    offenders = []
    for dirname in _SCAN_DIRS:
        for path in sorted((root / dirname).rglob("*.py")):
            for i, line in enumerate(
                    path.read_text().splitlines(), start=1):
                if _LEGACY_CALL.search(line) and _MARKER not in line:
                    offenders.append(f"{path.relative_to(root)}:{i}: "
                                     f"{line.strip()}")
    assert not offenders, (
        "legacy serve-API call signatures found (migrate to "
        "QueryRequest/Answer or mark deliberate shim tests with "
        "'# legacy-api-ok'):\n" + "\n".join(offenders))
