"""Analysis layer: paper flop model, HLO parsers, roofline arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.flops import (
    sdkde_bytes,
    sdkde_flops,
    sdkde_flops_1d,
    sdkde_flops_coefficient,
    sdkde_intensity,
)
from repro.analysis.hlo import collective_bytes
from repro.analysis.hlo_exec import analyze_hlo, breakdown, parse_module
from repro.analysis.roofline import HW, RooflineTerms


# -- paper §4.1 flop model (validated against the paper's own numbers) -----


def test_flop_coefficient_matches_paper():
    assert abs(sdkde_flops_coefficient(16) - 81.5) < 1e-9


def test_flops_at_32k_order_1e11():
    f = sdkde_flops(32768)
    assert 5e10 < f < 2e11          # "on the order of 10^11 FLOPs" (§4.1)


def test_bytes_coefficient_matches_paper():
    c = sdkde_bytes(32768) / 32768**2
    assert abs(c - 1.13) < 0.02     # "≈ 1.13 k² bytes"


def test_intensity_matches_paper():
    i = sdkde_intensity(32768)
    assert 70 < i < 75              # "≈ 72 flops/byte"
    # compute-bound on the A6000 (tensor-core balance ~200, fp32 roof ~50):
    assert i > 50


def test_1d_model_appendix():
    f = sdkde_flops_1d(32768)
    assert abs(f - 17.75 * 32768**2) < 1e-6 * f


# -- HLO executable analyzer -------------------------------------------------


def test_analyzer_scan_flops_exact():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ).compile().as_text()
    s = analyze_hlo(txt)
    np.testing.assert_allclose(s.flops, 7 * 2 * 64**3, rtol=0.02)


def test_analyzer_vs_xla_on_loop_free_program():
    """Without loops the analyzer must agree with XLA's own count."""
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(32, 128), (128, 256), (256, 64)]]
    compiled = jax.jit(f).lower(*args).compile()
    s = analyze_hlo(compiled.as_text())
    xla = compiled.cost_analysis()
    if isinstance(xla, list):
        xla = xla[0]
    np.testing.assert_allclose(s.flops, float(xla["flops"]), rtol=0.1)


def test_analyzer_nested_scan_multiplies():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ).compile().as_text()
    s = analyze_hlo(txt)
    np.testing.assert_allclose(s.flops, 15 * 2 * 32**3, rtol=0.05)
    assert s.unknown_trip_loops == 0


def test_analyzer_exponential_transcendentals():
    def f(x):
        return jnp.exp(x).sum()

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((1024,), jnp.float32)
    ).compile().as_text()
    s = analyze_hlo(txt)
    assert s.transcendentals >= 1024


def test_breakdown_rows_ordered():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ).compile().as_text()
    rows = breakdown(txt, top=5)
    assert rows and rows[0]["trips"] == 4


def test_collective_parser_text_fixture():
    txt = """
  %all-reduce.1 = f32[1024]{0} all-reduce(%x), to_apply=%sum
  %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={1}
  %cp = f32[32,32]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    agg = collective_bytes(txt)
    assert agg["all-reduce_bytes"] == 4096
    assert agg["all-gather_bytes"] == 64 * 512 * 2
    assert agg["collective-permute_bytes"] == 32 * 32 * 4
    assert agg["wire_bytes"] == 2 * 4096 + 64 * 512 * 2 + 32 * 32 * 4


# -- roofline arithmetic ------------------------------------------------------


def test_roofline_terms_and_bound():
    t = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=197e12 * 0.010,          # 10 ms of compute
        hlo_bytes=819e9 * 0.005,           # 5 ms of HBM
        collective_bytes=50e9 * 0.020,     # 20 ms of ICI
        model_flops=197e12 * 0.010 * 256 * 0.5,
    )
    assert abs(t.t_compute - 0.010) < 1e-12
    assert abs(t.t_memory - 0.005) < 1e-12
    assert abs(t.t_collective - 0.020) < 1e-12
    assert t.bound == "collective"
    assert abs(t.step_time - 0.020) < 1e-12
    assert abs(t.useful_flops_ratio - 0.5) < 1e-9
    assert abs(t.mfu - 0.010 * 0.5 / 0.020) < 1e-9
