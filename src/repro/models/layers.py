"""Shared building blocks: norms, MLPs, embeddings, logits."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, one_plus: bool = False,
            eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if one_plus else w.astype(jnp.float32)
    return (x * scale).astype(dt)


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind in ("swiglu",):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp(x: jnp.ndarray, lp: dict, cfg: ModelConfig,
        prefix: str = "") -> jnp.ndarray:
    """Gated (SwiGLU/GeGLU) or plain (GELU/ReLU²) feed-forward."""
    up = x @ lp[prefix + "w_up"].astype(x.dtype)
    if cfg.gated:
        gate = _act(x @ lp[prefix + "w_gate"].astype(x.dtype), cfg.act)
        h = gate * up
    else:
        h = _act(up, cfg.act)
    return h @ lp[prefix + "w_down"].astype(x.dtype)


def embed_tokens(params: dict, tokens: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    emb = params["embed"]
    x = jnp.take(emb, tokens, axis=0).astype(cfg.dtype)
    # common convention (gemma/whisper): scale by sqrt(d)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    return x


def logits_head(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    out = x @ w
    return softcap(out.astype(jnp.float32), cfg.final_softcap)
