"""Trace-time mesh registry + sharding hints for the model code.

The model definitions are mesh-agnostic; step builders register the mesh
here and the layers drop GSPMD ``with_sharding_constraint`` hints where the
partitioner's default choice is catastrophic (measured in EXPERIMENTS.md
§Perf):

  * attention Q and the attention output are SEQUENCE-sharded over
    ``model`` during training — head-sharding is impossible for most
    assigned configs (24/25/8/20/56 heads vs a 16-way axis) and GSPMD's
    fallback was to shard the CONTRACTION dim, all-reducing (S×S) score
    tensors per layer (768 MB × 3 ops × layers × microbatches on granite);
  * the MoE layer runs fully-manual (models/moe.py) under the same mesh.

With no mesh registered every hint is a no-op (single-device smoke tests).
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: list = [None]


def set_mesh(mesh: Optional[Mesh]) -> None:
    _MESH[0] = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH[0]


@contextlib.contextmanager
def model_mesh(mesh: Optional[Mesh]):
    prev = _MESH[0]
    _MESH[0] = mesh
    try:
        yield
    finally:
        _MESH[0] = prev


def dp_axes(mesh=None) -> Tuple[str, ...]:
    mesh = mesh or _MESH[0]
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and mesh.shape[a] > 1)


def hint(x, *entries):
    """with_sharding_constraint when a mesh is registered and divisibility
    holds; otherwise identity.  ``entries`` are PartitionSpec entries; use
    the string "dp" for the batch axes."""
    mesh = _MESH[0]
    if mesh is None:
        return x
    dp = dp_axes(mesh)
    resolved = []
    for dim, e in zip(x.shape, entries):
        if e == "dp":
            e = dp if dp else None
        if e is not None:
            size = 1
            for a in (e if isinstance(e, tuple) else (e,)):
                size *= mesh.shape[a]
            if dim % size != 0:
                e = None          # indivisible: leave to the partitioner
        resolved.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )
