"""Decoder-only LM: scan-over-layers forward, decode-with-cache, loss.

One generic layer body covers the dense / MoE / SSM / hybrid / VLM families
(static Python dispatch on ``cfg.family`` — resolved at trace time).  Layers
are scanned over stacked parameters so compile time is independent of depth;
``jax.checkpoint`` wraps the body when ``cfg.remat == 'full'``.

Sliding-window / global alternation (gemma2) is handled by passing a
*numeric* per-layer window (huge window ≡ global) through the scan, avoiding
per-layer retracing.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, layer_tree
from repro.models.layers import embed_tokens, logits_head, mlp, rmsnorm
from repro.models.moe import moe_ffn
from repro.models.parallel import hint
from repro.models.ssm import mamba_block, mamba_decode_step

GLOBAL_WINDOW = jnp.int32(2**30)


def layer_windows(cfg: ModelConfig, n_layers: Optional[int] = None):
    """Per-layer attention window (traced through the scan). Huge == global."""
    n = n_layers or cfg.n_layers
    if cfg.local_global_alt and cfg.sliding_window:
        # even layers local (window), odd layers global — gemma2 convention
        idx = jnp.arange(n)
        return jnp.where(idx % 2 == 0, cfg.sliding_window, GLOBAL_WINDOW)
    if cfg.sliding_window:
        return jnp.full((n,), cfg.sliding_window, jnp.int32)
    return jnp.full((n,), GLOBAL_WINDOW, jnp.int32)


def _norm(x, lp, key, cfg):
    return rmsnorm(x, lp[key], one_plus=cfg.rms_one_plus)


def _seq_shard_qkv(q, k, v, cfg: ModelConfig):
    """Sequence-sharded attention for training (§Perf attention fix).

    Head counts that don't divide the 16-way ``model`` axis (8/20/24/25/56
    in the assigned pool) leave GSPMD sharding the score einsum's
    CONTRACTION dim — all-reducing (S×S)-sized score tensors per layer
    (measured: 3×768 MB × layers × microbatches on granite).  Sharding Q
    (and the attention output) on the SEQUENCE axis keeps every
    (S_loc × S) score tile device-local; K/V replicate over ``model`` and
    the only cross-device step left is the cheap (S, q_dim) reshard around
    wo.

    Archs whose heads DO divide the axis (32/64 heads) keep GSPMD's native
    head-sharding — measured better there (minitron train t_coll 10.5 s
    hinted-seq vs 16.6 s; the hints are strictly conditional).  No-op
    without a registered mesh.
    """
    from repro.models.parallel import get_mesh

    mesh = get_mesh()
    if mesh is None:
        return q, k, v
    use = cfg.seq_shard_attn
    if use is None:
        use = cfg.n_heads % mesh.shape["model"] != 0
    if not use:
        return q, k, v
    q = hint(q, "dp", "model", None, None)
    k = hint(k, "dp", None, None, None)
    v = hint(v, "dp", None, None, None)
    return q, k, v


def _attn_sublayer(x, lp, cfg, positions, window):
    h = _norm(x, lp, "attn_norm", cfg)
    q, k, v = attn.qkv_project(h, lp, cfg, positions)
    q2, k2, v2 = _seq_shard_qkv(q, k, v, cfg)
    o = attn.attention(q2, k2, v2, causal=True, window=window,
                       cap=cfg.attn_softcap)
    if q2 is not q:
        o = hint(o, "dp", "model", None, None)
    o = o.reshape(*x.shape[:-1], cfg.q_dim) @ lp["wo"].astype(x.dtype)
    if cfg.post_norms:
        o = _norm(o, lp, "post_attn_norm", cfg)
    return o


def _ffn_sublayer(x, lp, cfg):
    # Un-shard the sequence axis before the FFN: with seq-sharded
    # activations GSPMD all-gathers the (d, d_ff) WEIGHTS to preserve the
    # activation sharding (measured: 6×525 GiB per step on llava — §Perf);
    # gathering the (B, S, d) activations instead costs 20× less and
    # restores the standard column→row-parallel MLP pattern.  No-op when
    # no mesh is registered or the dim is indivisible.
    x = hint(x, "dp", None, None)
    h = _norm(x, lp, "mlp_norm", cfg)
    if cfg.family == "moe":
        b, s, d = h.shape
        out, aux = moe_ffn(h.reshape(b * s, d), lp, cfg)
        out = out.reshape(b, s, d)
    else:
        out, aux = mlp(h, lp, cfg), 0.0
    if cfg.post_norms:
        out = _norm(out, lp, "post_mlp_norm", cfg)
    return out, aux


def decoder_layer(
    x: jnp.ndarray,
    lp: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    positions: jnp.ndarray,
    window,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One layer; returns (x', aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        x = x + mamba_block(_norm(x, lp, "ssm_norm", cfg), lp, cfg)
        return x, aux
    if cfg.family == "hybrid":
        h = _norm(x, lp, "attn_norm", cfg)
        q, k, v = attn.qkv_project(h, lp, cfg, positions)
        q2, k2, v2 = _seq_shard_qkv(q, k, v, cfg)
        a = attn.attention(q2, k2, v2, causal=True, window=window,
                           cap=cfg.attn_softcap)
        if q2 is not q:
            a = hint(a, "dp", "model", None, None)
        a = a.reshape(*x.shape[:-1], cfg.q_dim) @ lp["wo"].astype(x.dtype)
        s = mamba_block(h, lp, cfg)
        s = rmsnorm(s, lp["ssm_norm"], one_plus=cfg.rms_one_plus)
        fused = (
            lp["fuse_attn_scale"].astype(x.dtype) * a
            + lp["fuse_ssm_scale"].astype(x.dtype) * s
        )
        x = x + fused
        out, aux2 = _ffn_sublayer(x, lp, cfg)
        return x + out, aux + aux2
    # dense / moe / vlm / audio decoder self-attention
    x = x + _attn_sublayer(x, lp, cfg, positions, window)
    out, aux2 = _ffn_sublayer(x, lp, cfg)
    return x + out, aux + aux2


def _scan_layers(x, params, cfg, positions, body):
    lt = layer_tree(params)
    windows = layer_windows(cfg)

    def wrapped(carry, inputs):
        x, aux = carry
        lp, window = inputs
        x, aux2 = body(x, lp, cfg, positions, window)
        return (x, aux + aux2), None

    if cfg.remat == "full":
        wrapped = jax.checkpoint(wrapped)
    (x, aux), _ = jax.lax.scan(wrapped, (x, jnp.float32(0.0)), (lt, windows))
    return x, aux


def forward_hidden(
    params: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    *,
    patches: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids -> final hidden states (after final norm); returns (h, aux)."""
    x = embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm":
        assert patches is not None, "vlm forward requires patch embeddings"
        p = patches.astype(cfg.dtype) @ params["patch_proj"].astype(cfg.dtype)
        x = jnp.concatenate([p, x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, aux = _scan_layers(x, params, cfg, positions, decoder_layer)
    x = rmsnorm(x, params["final_norm"], one_plus=cfg.rms_one_plus)
    return x, aux


def lm_loss(
    params: Dict[str, jnp.ndarray],
    hidden: jnp.ndarray,      # (B, S, d)
    targets: jnp.ndarray,     # (B, S) next-token ids
    cfg: ModelConfig,
) -> jnp.ndarray:
    """Mean next-token cross-entropy, sequence-chunked over the vocab GEMM.

    Chunking bounds the (B, chunk, V) logits temporary — without it the
    full (B, S, V) logits dominate activation memory at 256k vocab.
    """
    b, s, d = hidden.shape
    chunk = cfg.loss_chunk or s
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(tot, inputs):
        h, t = inputs
        logits = logits_head(params, h, cfg)              # (B, chunk, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, tc))
    return tot / (b * s)


def loss_fn(
    params: Dict[str, jnp.ndarray],
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
) -> jnp.ndarray:
    """Next-token LM loss over a batch {'tokens', optional 'patches'/'frames'}."""
    tokens = batch["tokens"]
    if cfg.family == "audio":
        from repro.models.encdec import encdec_hidden

        hidden, aux = encdec_hidden(params, batch["frames"], tokens, cfg)
        text_hidden = hidden
    else:
        hidden, aux = forward_hidden(
            params, tokens, cfg, patches=batch.get("patches")
        )
        # VLM: loss only on the text positions (after the patch prefix).
        text_hidden = hidden[:, -tokens.shape[1]:]
    targets = jnp.roll(tokens, -1, axis=1)
    loss = lm_loss(params, text_hidden[:, :-1], targets[:, :-1], cfg)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Prefill (serving) path: forward over the prompt, building the decode cache.
# ---------------------------------------------------------------------------


def prefill_layer(
    x: jnp.ndarray,
    lp: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    positions: jnp.ndarray,
    window,
    *,
    enc: Optional[jnp.ndarray] = None,
):
    """One layer of prompt processing; returns (x', per-layer cache entries).

    Mirrors ``decoder_layer`` but captures the K/V (and SSM states) that the
    decode path will extend — the ys of the layer scan stack into the
    (L, ...) cache layout of ``cache_spec``.
    """
    ce: Dict[str, jnp.ndarray] = {}

    if cfg.family == "ssm":
        out, conv, ssm = mamba_block(
            _norm(x, lp, "ssm_norm", cfg), lp, cfg, return_state=True
        )
        ce["conv"], ce["ssm"] = conv.astype(cfg.dtype), ssm
        return x + out, ce

    if cfg.family == "hybrid":
        h = _norm(x, lp, "attn_norm", cfg)
        q, k, v = attn.qkv_project(h, lp, cfg, positions)
        ce["k"], ce["v"] = k, v
        a = attn.attention(q, k, v, causal=True, window=window,
                           cap=cfg.attn_softcap)
        a = a.reshape(*x.shape[:-1], cfg.q_dim) @ lp["wo"].astype(x.dtype)
        s, conv, ssm = mamba_block(h, lp, cfg, return_state=True)
        ce["conv"], ce["ssm"] = conv.astype(cfg.dtype), ssm
        s = rmsnorm(s, lp["ssm_norm"], one_plus=cfg.rms_one_plus)
        x = x + (
            lp["fuse_attn_scale"].astype(x.dtype) * a
            + lp["fuse_ssm_scale"].astype(x.dtype) * s
        )
        out, _ = _ffn_sublayer(x, lp, cfg)
        return x + out, ce

    # dense / moe / vlm / audio decoder
    h = _norm(x, lp, "attn_norm", cfg)
    q, k, v = attn.qkv_project(h, lp, cfg, positions)
    ce["k"], ce["v"] = k, v
    o = attn.attention(q, k, v, causal=True, window=window,
                       cap=cfg.attn_softcap)
    o = o.reshape(*x.shape[:-1], cfg.q_dim) @ lp["wo"].astype(x.dtype)
    if cfg.post_norms:
        o = _norm(o, lp, "post_attn_norm", cfg)
    x = x + o
    if cfg.family == "audio":
        assert enc is not None
        b, t = enc.shape[0], enc.shape[1]
        xk = (enc @ lp["xwk"].astype(enc.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.hd
        )
        xv = (enc @ lp["xwv"].astype(enc.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.hd
        )
        ce["xk"], ce["xv"] = xk, xv
        hx = _norm(x, lp, "xattn_norm", cfg)
        qx = (hx @ lp["xwq"].astype(hx.dtype)).reshape(
            *hx.shape[:-1], cfg.n_heads, cfg.hd
        )
        ox = attn.attention(qx, xk, xv, causal=False)
        x = x + ox.reshape(*x.shape[:-1], cfg.q_dim) @ lp["xwo"].astype(x.dtype)
    out, _ = _ffn_sublayer(x, lp, cfg)
    return x + out, ce


def prefill(
    params: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,          # (B, S) prompt tokens
    cfg: ModelConfig,
    *,
    patches: Optional[jnp.ndarray] = None,
    frames: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """Prompt pass: returns (last-position logits (B, V), decode cache).

    Cache max_len equals the processed sequence length (patch prefix
    included for VLM); the serving driver re-allocates with headroom when
    generation continues past it.
    """
    x = embed_tokens(params, tokens, cfg)
    enc = None
    if cfg.family == "vlm":
        assert patches is not None
        p = patches.astype(cfg.dtype) @ params["patch_proj"].astype(cfg.dtype)
        x = jnp.concatenate([p, x], axis=1)
    if cfg.family == "audio":
        from repro.models.encdec import encode

        assert frames is not None
        enc = encode(params, frames, cfg)
    positions = jnp.arange(x.shape[1])
    lt = layer_tree(params)
    windows = layer_windows(cfg)

    def body(carry, inputs):
        lp, window = inputs
        x, ce = prefill_layer(carry, lp, cfg, positions, window, enc=enc)
        return x, ce

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, (lt, windows))
    x = rmsnorm(x, params["final_norm"], one_plus=cfg.rms_one_plus)
    logits = logits_head(params, x[:, -1:], cfg)
    cache = dict(caches)
    cache["pos"] = jnp.int32(tokens.shape[1])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode (serving) path.
# ---------------------------------------------------------------------------


def cache_spec(
    cfg: ModelConfig, batch: int, max_len: int
) -> Dict[str, Tuple[Tuple[int, ...], jnp.dtype]]:
    """Shapes/dtypes of the decode cache (shardings chosen by the launcher)."""
    l, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    spec: Dict[str, Tuple[Tuple[int, ...], jnp.dtype]] = {}
    if not cfg.attn_free:
        kv_dt = jnp.int8 if cfg.kv_quant else cfg.dtype
        spec["k"] = ((l, batch, max_len, hkv, hd), kv_dt)
        spec["v"] = ((l, batch, max_len, hkv, hd), kv_dt)
        if cfg.kv_quant:
            spec["k_scale"] = ((l, batch, max_len, hkv), jnp.float32)
            spec["v_scale"] = ((l, batch, max_len, hkv), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        spec["conv"] = ((l, batch, cfg.ssm_conv - 1, cfg.d_inner), cfg.dtype)
        spec["ssm"] = ((l, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    if cfg.family == "audio":
        spec["xk"] = ((l, batch, cfg.enc_frames, hkv, hd), cfg.dtype)
        spec["xv"] = ((l, batch, cfg.enc_frames, hkv, hd), cfg.dtype)
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    cache = {
        name: jnp.zeros(shape, dt)
        for name, (shape, dt) in cache_spec(cfg, batch, max_len).items()
    }
    cache["pos"] = jnp.int32(0)
    return cache


def decode_layer(
    x: jnp.ndarray,            # (B, 1, d)
    lp: Dict[str, jnp.ndarray],
    cache_l: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    pos,
    window,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode through one layer; returns (x', updated cache)."""
    new_cache = dict(cache_l)
    positions = pos[None] if jnp.ndim(pos) == 0 else pos

    def quant(x):
        # symmetric per-(position, head) int8; scale (B, 1, Hkv)
        s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
        s = jnp.maximum(s, 1e-12)
        q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                      -127, 127).astype(jnp.int8)
        return q8, s

    def attend(h, prefix="w", cache_k="k", cache_v="v", cross=False):
        if cross:
            b = h.shape[0]
            q = (h @ lp["xwq"].astype(h.dtype)).reshape(
                b, 1, cfg.n_heads, cfg.hd
            )
            k_c, v_c = cache_l["xk"], cache_l["xv"]
            o = attn.decode_attention(
                q, k_c, v_c, jnp.int32(cfg.enc_frames - 1), cap=None
            )
            return o.reshape(b, 1, cfg.q_dim) @ lp["xwo"].astype(h.dtype)
        q, k, v = attn.qkv_project(h, lp, cfg, positions, prefix=prefix)
        if cfg.kv_quant:
            # int8 cache: HBM reads halve vs bf16; dequant multiplies fuse
            # into the attention reads (EXPERIMENTS.md §Perf decode note).
            k8, ks = quant(k)
            v8, vs = quant(v)
            k_c = jax.lax.dynamic_update_slice(
                cache_l[cache_k], k8, (0, pos, 0, 0))
            v_c = jax.lax.dynamic_update_slice(
                cache_l[cache_v], v8, (0, pos, 0, 0))
            ks_c = jax.lax.dynamic_update_slice(
                cache_l[cache_k + "_scale"], ks, (0, pos, 0))
            vs_c = jax.lax.dynamic_update_slice(
                cache_l[cache_v + "_scale"], vs, (0, pos, 0))
            new_cache[cache_k], new_cache[cache_v] = k_c, v_c
            new_cache[cache_k + "_scale"] = ks_c
            new_cache[cache_v + "_scale"] = vs_c
            k_full = k_c.astype(h.dtype) * ks_c[..., None].astype(h.dtype)
            v_full = v_c.astype(h.dtype) * vs_c[..., None].astype(h.dtype)
        else:
            k_c = jax.lax.dynamic_update_slice(
                cache_l[cache_k], k.astype(cache_l[cache_k].dtype),
                (0, pos, 0, 0))
            v_c = jax.lax.dynamic_update_slice(
                cache_l[cache_v], v.astype(cache_l[cache_v].dtype),
                (0, pos, 0, 0))
            new_cache[cache_k], new_cache[cache_v] = k_c, v_c
            k_full, v_full = k_c, v_c
        o = attn.decode_attention(q, k_full, v_full, pos, window=window,
                                  cap=cfg.attn_softcap)
        return o.reshape(h.shape[0], 1, cfg.q_dim) @ lp["wo"].astype(h.dtype)

    def ssm_step(h):
        out, conv, ssm = mamba_decode_step(
            h, cache_l["conv"], cache_l["ssm"], lp, cfg
        )
        new_cache["conv"], new_cache["ssm"] = conv, ssm
        return out

    if cfg.family == "ssm":
        x = x + ssm_step(_norm(x, lp, "ssm_norm", cfg))
        return x, new_cache
    if cfg.family == "hybrid":
        h = _norm(x, lp, "attn_norm", cfg)
        a = attend(h)
        s = ssm_step(h)
        s = rmsnorm(s, lp["ssm_norm"], one_plus=cfg.rms_one_plus)
        x = x + (
            lp["fuse_attn_scale"].astype(x.dtype) * a
            + lp["fuse_ssm_scale"].astype(x.dtype) * s
        )
        out, _ = _ffn_sublayer(x, lp, cfg)
        return x + out, new_cache

    h = _norm(x, lp, "attn_norm", cfg)
    a = attend(h)
    if cfg.post_norms:
        a = _norm(a, lp, "post_attn_norm", cfg)
    x = x + a
    if cfg.family == "audio":
        xh = _norm(x, lp, "xattn_norm", cfg)
        x = x + attend(xh, cross=True)
    out, _ = _ffn_sublayer(x, lp, cfg)
    return x + out, new_cache


def decode_step(
    params: Dict[str, jnp.ndarray],
    cache: Dict,
    tokens: jnp.ndarray,       # (B, 1) the newest token ids
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict]:
    """One serving step: logits for the next token + updated cache."""
    pos = cache["pos"]
    x = embed_tokens(params, tokens, cfg)
    lt = layer_tree(params)
    windows = layer_windows(cfg)
    layer_caches = {k: v for k, v in cache.items() if k != "pos"}

    def body(x, inputs):
        lp_w, cache_l = inputs
        lp, window = lp_w
        x, new_cache = decode_layer(x, lp, cache_l, cfg, pos, window)
        return x, new_cache

    x, new_layer_caches = jax.lax.scan(body, x, ((lt, windows), layer_caches))
    x = rmsnorm(x, params["final_norm"], one_plus=cfg.rms_one_plus)
    logits = logits_head(params, x, cfg)
    new_cache = dict(new_layer_caches)
    new_cache["pos"] = pos + 1
    return logits[:, 0], new_cache
