"""Top-k MoE layer with capacity-based dispatch (GShard/Switch style).

Routing: softmax router → top-k experts per token → position-in-expert via
cumulative sum → tokens beyond an expert's capacity are dropped (their
residual passes through).  Dispatch/combine are scatter/gather ops; the
expert FFNs run as a single batched GEMM over the (E, C, d) buffer, which
shards cleanly:

  * EP  — expert axis over ``model`` (used when n_experts % 16 == 0, e.g.
          kimi-k2's 384 experts → 24/device).
  * TPE — per-expert d_ff over ``model`` (granite's 40 experts don't divide
          the axis; its d_ff=512 does).

**Shard-local dispatch** (the §Perf fix; see EXPERIMENTS.md): under pure
GSPMD the scatter-based dispatch builds a GLOBAL (E, C, d) capacity buffer
(C ∝ the full microbatch) that the partitioner replicates and all-reduces
per layer — the dominant collective cost of both assigned MoE cells
(7.5 GiB payloads × layers × microbatches for granite).  When a mesh is
registered via ``set_moe_mesh``, the dispatch/combine run inside a
partial-manual ``shard_map`` over the batch axes: every data shard routes
its OWN tokens into a LOCAL buffer (C_loc ∝ T/dp), while the expert weights
stay auto-sharded over ``model`` — the only cross-device traffic left is
the model-axis reduction GSPMD inserts for the expert GEMMs.  kimi-scale
2-D expert sharding (d_ff over ``data``) additionally all-gathers the
CURRENT layer's expert weights over ``data`` inside the manual region
(FSDP-style transient gather, freed after the layer).

Aux losses: load-balancing (Switch) + router z-loss, pmean'd over shards.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import axis_size as _axis_size
from repro.distributed.compat import optimization_barrier as _opt_barrier
from repro.distributed.compat import shard_map as _shard_map
from repro.models.common import ModelConfig
from repro.models.layers import _act

# Trace-time mesh registry (shared with the attention hints): step builders
# register the mesh so the MoE layer can open a fully-manual region.  None
# (the default) keeps the pure-GSPMD dense path — used by single-device
# smoke tests and kept as the §Perf BASELINE.
from repro.models.parallel import dp_axes as _dp_axes  # noqa: E402
from repro.models.parallel import get_mesh as _get_mesh  # noqa: E402
from repro.models.parallel import model_mesh as moe_mesh  # noqa: F401,E402
from repro.models.parallel import set_mesh as set_moe_mesh  # noqa: F401,E402


def _top_k_routing(logits: jnp.ndarray, k: int):
    """Return (weights, expert_idx): renormalized top-k softmax routing."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e


def moe_ffn(
    x: jnp.ndarray,          # (T, d) flattened tokens
    lp: dict,
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the MoE FFN; returns (output (T, d), aux_loss scalar).

    Dispatches to the shard-local path when a mesh with >1 batch shard is
    registered (see module docstring), else the dense GSPMD path.
    """
    mesh = _get_mesh()
    dp = _dp_axes(mesh)
    if mesh is not None and x.shape[0] <= 2048:
        # Decode-scale batches: weights-STATIONARY path.  Moving 2 TB of
        # experts for 128 tokens is absurd (GSPMD's auto choice gathered
        # one full layer = 34 GB/device on kimi decode); instead replicate
        # the tiny token batch, compute each shard's (E_loc × f_loc)
        # partial, and psum the (T, d) output — ~0.5 MB per layer.
        return _moe_ffn_stationary(x, lp, cfg, mesh)
    if dp and x.shape[0] % _dp_size(mesh, dp) == 0:
        return _moe_ffn_sharded(x, lp, cfg, mesh, dp)
    return _moe_ffn_body(x, lp, cfg)


def _dp_size(mesh, dp) -> int:
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return n


_MOE_WEIGHTS = ("router", "experts_up", "experts_gate", "experts_down",
                "shared_up", "shared_gate", "shared_down")


def _moe_weight_specs(cfg: ModelConfig, names):
    """The stored param PartitionSpecs (models.common._moe_shapes) — the
    manual region consumes weights exactly as they live in HBM."""
    from repro.models.common import _moe_shapes

    shapes = _moe_shapes(cfg)
    return {n: shapes[n][2] for n in names}


def _moe_ffn_sharded(x, lp, cfg: ModelConfig, mesh, dp):
    """Fully-manual dispatch: manual over (pod, data) AND model.

    Every shard routes its OWN T/dp tokens (local capacity, local scatter).
    Expert parallelism without all-to-all: with E sharded over ``model``,
    each model shard buffers only its E/16 experts (out-of-range routes are
    masked); with d_ff sharded over ``model`` (granite) each shard computes
    an f-slice partial.  Either way the final combine is ONE f32 psum of
    the (T_loc, d) layer output over ``model`` — the minimal collective the
    math admits.  kimi's 2-D expert sharding first all-gathers the current
    layer's d_ff slices over ``data`` (transient FSDP gather).
    """
    weights = {k: v for k, v in lp.items() if k in _MOE_WEIGHTS}
    wspecs = _moe_weight_specs(cfg, weights)
    manual = set(dp) | {"model"}
    ep = cfg.n_experts % mesh.shape["model"] == 0

    def local(x_loc, w_loc):
        if cfg.expert_2d_sharding and "data" in dp:
            w_loc = dict(w_loc)
            for name, axis in (("experts_up", 2), ("experts_gate", 2),
                               ("experts_down", 1)):
                if name in w_loc:
                    # optimization_barrier: stops XLA from hoisting the
                    # einsum's bf16→f32 convert ABOVE this gather, which
                    # would double the wire bytes (measured §Perf kimi#2).
                    w_loc[name] = _opt_barrier(
                        lax.all_gather(
                            w_loc[name], "data", axis=axis, tiled=True
                        )
                    )
        out, aux = _moe_ffn_manual(x_loc, w_loc, cfg, ep=ep)
        return out, lax.pmean(aux, tuple(manual))

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(dp, None), wspecs),
        out_specs=(P(dp, None), P()),
        axis_names=manual,
        check_vma=False,
    )(x, weights)


def _moe_ffn_stationary(x, lp, cfg: ModelConfig, mesh):
    """Weights-stationary MoE for small (decode) batches.

    Manual over every mesh axis; tokens replicated (in_specs P(None));
    expert weights stay exactly where they live (native param specs —
    including kimi's 2-D (model, data) layout, NO gather); each device
    computes its experts'/f-slice partial for all T tokens; the final psum
    over ALL axes merges expert locality and f partials at once.
    """
    weights = {k: v for k, v in lp.items() if k in _MOE_WEIGHTS}
    wspecs = _moe_weight_specs(cfg, weights)
    axes = tuple(mesh.axis_names)
    ep = cfg.n_experts % mesh.shape["model"] == 0
    # Reduce ONLY over axes the weights are sharded on: partials exist
    # over 'model' (experts or f) and — for 2-D expert layouts — 'data'
    # (f slices); over any other axis the compute is replicated and a
    # psum would overcount it.
    reduce_axes = ("model",) + (
        ("data",) if cfg.expert_2d_sharding and "data" in axes else ()
    )

    def local(x_loc, w_loc):
        out, aux = _moe_ffn_manual(x_loc, w_loc, cfg, ep=ep,
                                   psum_axes=reduce_axes)
        return out, lax.pmean(aux, axes)

    return _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, None), wspecs),
        out_specs=(P(None, None), P()),
        axis_names=set(axes),
        check_vma=False,
    )(x, weights)


def _moe_ffn_manual(x, lp, cfg: ModelConfig, *, ep: bool, psum_axes=None):
    """Per-device MoE body inside the fully-manual region.

    ``ep=True``: lp['experts_*'] hold this model shard's E_loc experts.
    ``ep=False``: all experts present, d_ff arrives f-sliced (TP-in-expert).
    Returns the (T_loc, d) output AFTER the model-axis psum.
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    mp = _axis_size("model")
    e_loc = lp["experts_up"].shape[0]

    logits = x.astype(jnp.float32) @ lp["router"].astype(jnp.float32)
    weights, expert_idx = _top_k_routing(logits, k)           # (T, k)

    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(onehot.mean(0) * probs.mean(0)) + 1e-3 * jnp.mean(
        jnp.log(jnp.sum(jnp.exp(logits), axis=-1)) ** 2
    )

    # Capacity bookkeeping over the FULL expert range (identical across
    # model shards, and to the dense path at equal per-shard token count).
    cap = int(cfg.capacity_factor * t * k / e) + 1
    flat_e = expert_idx.reshape(-1)
    onehot_te = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot_te, axis=0) - 1
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    if ep and e_loc < e:
        shard = lax.axis_index("model")
        local_e = flat_e - shard * e_loc
        keep = keep & (local_e >= 0) & (local_e < e_loc)
    else:
        local_e = flat_e
    dest = jnp.where(keep, local_e * cap + slot, e_loc * cap)

    xk = jnp.repeat(x, k, axis=0)
    buf = jnp.zeros((e_loc * cap + 1, d), x.dtype).at[dest].set(
        jnp.where(keep[:, None], xk, 0)
    )
    buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

    # Expert GEMMs consume weights in their STORAGE dtype (bf16) with f32
    # MXU accumulation — upcasting the operands would double both HBM and
    # (for 2-D-sharded experts) all-gather traffic.
    up = jnp.einsum("ecd,edf->ecf", buf, lp["experts_up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.gated:
        gate = jnp.einsum("ecd,edf->ecf", buf, lp["experts_gate"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
        h = _act(gate, cfg.act) * up
    else:
        h = _act(up, cfg.act)
    out_buf = jnp.einsum("ecf,efd->ecd", h, lp["experts_down"],
                         preferred_element_type=jnp.float32).astype(x.dtype)

    out_flat = out_buf.reshape(e_loc * cap, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(dest, e_loc * cap - 1)], 0.0
    )
    out = (
        gathered.reshape(t, k, d) * weights[..., None].astype(x.dtype)
    ).sum(axis=1)

    if cfg.n_shared_experts:
        s_up = x @ lp["shared_up"].astype(x.dtype)
        if cfg.gated:
            s_h = _act(x @ lp["shared_gate"].astype(x.dtype), cfg.act) * s_up
        else:
            s_h = _act(s_up, cfg.act)
        shared = s_h @ lp["shared_down"].astype(x.dtype)
        # The shared expert is sharded over 'model' ONLY; when the combine
        # psum also spans 'data' (stationary path, 2-D experts), its
        # data-replicated partial would be overcounted — pre-scale by the
        # extra reduction factor (a power of two: exact in fp).
        axes = psum_axes if psum_axes is not None else ("model",)
        extra = 1
        for a in axes:
            if a != "model":
                extra *= _axis_size(a)
        out = out + (shared / extra if extra > 1 else shared)

    # ONE combine psum: merges EP expert-locality masking and/or f-slice
    # partial sums (and the f-sliced shared expert) in a single collective.
    # The stationary (decode) path reduces over every weight-sharded axis.
    axes = psum_axes if psum_axes is not None else ("model",)
    if any(_axis_size(a) > 1 for a in axes):
        out = lax.psum(out.astype(jnp.float32), axes).astype(x.dtype)
    return out, aux


def _moe_ffn_body(
    x: jnp.ndarray,          # (T, d) flattened tokens (global or per-shard)
    lp: dict,
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(cfg.capacity_factor * t * k / e) + 1

    logits = x.astype(jnp.float32) @ lp["router"].astype(jnp.float32)
    weights, expert_idx = _top_k_routing(logits, k)           # (T,k)

    # Load-balance loss (Switch): E * Σ_e f_e · p_e ; z-loss on router logits.
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    f = onehot.mean(0)
    p = probs.mean(0)
    aux = e * jnp.sum(f * p) + 1e-3 * jnp.mean(
        jnp.log(jnp.sum(jnp.exp(logits), axis=-1)) ** 2
    )

    # Position of each (token, choice) within its expert's capacity buffer.
    flat_e = expert_idx.reshape(-1)                           # (T*k,)
    onehot_te = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # (T*k, E)
    pos_in_e = jnp.cumsum(onehot_te, axis=0) - 1              # (T*k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, e * cap)      # drop bucket

    # Dispatch: scatter token vectors into the (E*C+1, d) buffer.
    xk = jnp.repeat(x, k, axis=0)                             # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xk)
    buf = buf[: e * cap].reshape(e, cap, d)

    # Expert FFNs as batched GEMMs over the expert axis.
    up = jnp.einsum("ecd,edf->ecf", buf, lp["experts_up"].astype(x.dtype))
    if cfg.gated:
        gate = jnp.einsum(
            "ecd,edf->ecf", buf, lp["experts_gate"].astype(x.dtype)
        )
        h = _act(gate, cfg.act) * up
    else:
        h = _act(up, cfg.act)
    out_buf = jnp.einsum("ecf,efd->ecd", h, lp["experts_down"].astype(x.dtype))

    # Combine: gather each (token, choice) back and weight.
    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(dest, e * cap - 1)], 0.0
    )
    out = (
        gathered.reshape(t, k, d)
        * weights[..., None].astype(x.dtype)
    ).sum(axis=1)

    # Shared experts (kimi-k2 style): always-on dense FFN on the side.
    if cfg.n_shared_experts:
        s_up = x @ lp["shared_up"].astype(x.dtype)
        if cfg.gated:
            s_gate = _act(x @ lp["shared_gate"].astype(x.dtype), cfg.act)
            s_h = s_gate * s_up
        else:
            s_h = _act(s_up, cfg.act)
        out = out + s_h @ lp["shared_down"].astype(x.dtype)

    return out.astype(x.dtype), aux
