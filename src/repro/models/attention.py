"""GQA attention: full, memory-efficient (chunked online-softmax), decode.

Supports grouped-query attention, causal and sliding-window masks, logit
softcapping (gemma2), and RoPE variants.  For long sequences the chunked
path streams KV blocks with an online softmax (flash-attention re-ordering
in pure JAX) so activation memory stays O(S·chunk) instead of O(S²) — the
same IO-aware re-ordering philosophy as the paper's KDE kernels, applied to
the LM substrate.

Shapes: q (B,S,Hq,hd), k/v (B,S,Hkv,hd); GQA groups G = Hq//Hkv.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.layers import softcap
from repro.models.rope import apply_rope

NEG_INF = -1e30
CHUNKED_THRESHOLD = 8192   # use online-softmax streaming above this S
Q_CHUNK = 1024
KV_CHUNK = 1024


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (prompt lengths like
    32768+2880 patch tokens or whisper's 1500 frames aren't chunk
    multiples; the streaming path must still tile them exactly)."""
    target = min(target, n)
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return 1


def _mask(
    qpos: jnp.ndarray,   # (Sq,) positions of queries
    kpos: jnp.ndarray,   # (Sk,) positions of keys
    *,
    causal: bool,
    window,              # None | int | traced scalar
) -> jnp.ndarray:
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def _scores(qg, k, scale, cap):
    # qg: (B,Sq,Hkv,G,hd), k: (B,Sk,Hkv,hd) -> (B,Hkv,G,Sq,Sk)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    return softcap(s, cap)


def full_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    cap: Optional[float] = None,
) -> jnp.ndarray:
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    s = _scores(qg, k, 1.0 / math.sqrt(hd), cap)
    mask = _mask(jnp.arange(sq), jnp.arange(sk), causal=causal, window=window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, hq, hd)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    cap: Optional[float] = None,
    q_chunk: int = Q_CHUNK,
    kv_chunk: int = KV_CHUNK,
) -> jnp.ndarray:
    """Online-softmax attention streaming KV chunks: O(S·chunk) memory."""
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    q_chunk = _pick_chunk(sq, q_chunk)
    kv_chunk = _pick_chunk(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk

    qg = q.reshape(b, nq, q_chunk, hkv, g, hd)
    kc = k.reshape(b, nk, kv_chunk, hkv, hd)
    vc = v.reshape(b, nk, kv_chunk, hkv, hd)

    def q_body(qi, q_blk):
        # q_blk: (b, q_chunk, hkv, g, hd)
        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, hd), jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, inputs):
            m, l, acc = carry
            ki, k_blk, v_blk = inputs
            s = _scores(q_blk, k_blk, scale, cap)  # (b,hkv,g,qc,kc)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(qpos, kpos, causal=causal, window=window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (b,hkv,g,qc,hd) -> (b,qc,hkv,g,hd)
        return out.transpose(0, 3, 1, 2, 4)

    outs = jax.lax.map(lambda args: q_body(*args),
                       (jnp.arange(nq), qg.swapaxes(0, 1)))
    # (nq, b, qc, hkv, g, hd) -> (b, sq, hq, hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    cap: Optional[float] = None,
) -> jnp.ndarray:
    """Dispatch: full for short sequences, streaming for long ones."""
    if q.shape[1] >= CHUNKED_THRESHOLD or k.shape[1] >= CHUNKED_THRESHOLD:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 cap=cap)
    return full_attention(q, k, v, causal=causal, window=window, cap=cap)


def decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, hd) — the new token's query
    k_cache: jnp.ndarray,  # (B, S, Hkv, hd)
    v_cache: jnp.ndarray,
    pos,                   # scalar: index of the new token
    *,
    window=None,
    cap: Optional[float] = None,
) -> jnp.ndarray:
    """Single-step decode: new query against the (length-masked) KV cache."""
    b, _, hq, hd = q.shape
    sk, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, hd)
    s = _scores(qg, k_cache, 1.0 / math.sqrt(hd), cap)  # (b,hkv,g,1,S)
    kpos = jnp.arange(sk)
    valid = kpos <= pos
    if window is not None:
        valid &= pos - kpos < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v_cache)
    return o.reshape(b, 1, hq, hd)


def qkv_project(x, lp, cfg: ModelConfig, positions, prefix: str = "w"):
    """Project to q/k/v heads and apply RoPE."""
    b, s, _ = x.shape
    q = (x @ lp[prefix + "q"].astype(x.dtype)).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (x @ lp[prefix + "k"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (x @ lp[prefix + "v"].astype(x.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, theta=cfg.rope_theta, variant=cfg.rope_variant)
    k = apply_rope(k, positions, theta=cfg.rope_theta, variant=cfg.rope_variant)
    return q, k, v
