"""Mamba1 selective-SSM block (falcon-mamba; also the SSM half of hymba).

Training path uses an associative scan over the sequence (parallel,
TPU-friendly: log-depth instead of the GPU kernel's sequential smem scan —
the hardware adaptation of Mamba's selective-scan).  Decode path carries
(conv_state, ssm_state) and costs O(1) per token, which is what makes the
``long_500k`` cell tractable for this family.

Recurrence (per channel c, state dim n):
  h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t
  y_t = C_t · h_t + D x_t
with A diagonal (d_inner, N), B/C input-dependent (selective).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


def _ssm_proj(x_in: jnp.ndarray, lp: dict, cfg: ModelConfig):
    """Input-dependent Δ, B, C from the x-projection."""
    n, dtr = cfg.ssm_state, cfg.dt_rank
    xbc = x_in @ lp["x_proj"].astype(x_in.dtype)          # (..., dtr+2N)
    dt, b, c = jnp.split(xbc, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        dt @ lp["dt_proj"].astype(x_in.dtype) + lp["dt_bias"].astype(x_in.dtype)
    )                                                      # (..., d_inner)
    return dt, b, c


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over the sequence. x: (B,S,di), w: (dc,di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(dc)
    )
    return out + b[None, None, :]


def mamba_block(
    x: jnp.ndarray,            # (B, S, d_model)
    lp: dict,
    cfg: ModelConfig,
    *,
    return_state: bool = False,
):
    """Full-sequence Mamba1 block via associative scan.

    With ``return_state`` also returns (conv_state, ssm_state) at the end of
    the sequence — the prefill path for serving.
    """
    xz = x @ lp["in_proj"].astype(x.dtype)                  # (B,S,2di)
    xi_pre, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_conv1d(xi_pre, lp["conv_w"].astype(x.dtype),
                             lp["conv_b"].astype(x.dtype)))

    dt, b, c = _ssm_proj(xi, lp, cfg)                       # (B,S,di),(B,S,N)x2
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))           # (di, N)

    if cfg.ssm_kernel:
        # Chunked Pallas selective scan: state stays in VMEM, the
        # (B,S,di,N) decay/drive tensors never hit HBM (the SSM-prefill
        # memory bottleneck in EXPERIMENTS.md §Roofline).
        from repro.kernels.selective_scan import selective_scan_pallas

        h0 = jnp.zeros((x.shape[0], cfg.d_inner, cfg.ssm_state),
                       jnp.float32)
        y, h_last = selective_scan_pallas(
            xi, dt, b, c, a, h0,
            block_d=min(256, cfg.d_inner), chunk=min(128, x.shape[1]),
            interpret=jax.default_backend() == "cpu",
        )
        hs = None
    else:
        # Discretize: decay = exp(Δ A), drive = Δ B x (ZOH for B ≈ Euler).
        dt32 = dt.astype(jnp.float32)
        decay = jnp.exp(dt32[..., None] * a[None, None])    # (B,S,di,N)
        drive = (dt32 * xi.astype(jnp.float32))[..., None] * b.astype(
            jnp.float32
        )[..., None, :]                                     # (B,S,di,N)

        # h_t = decay_t ⊙ h_{t-1} + drive_t — first-order linear
        # recurrence: associative over pairs (decay, drive).
        def combine(l, r):
            dl, vl = l
            dr, vr = r
            return dl * dr, vr + dr * vl

        _, hs = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c.astype(jnp.float32))
        h_last = hs[:, -1]
    y = y + lp["D"].astype(jnp.float32)[None, None] * xi.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ lp["out_proj"].astype(x.dtype)
    if return_state:
        dc = cfg.ssm_conv
        conv_state = xi_pre[:, -(dc - 1):, :]               # (B, dc-1, di)
        return out, conv_state, h_last                      # (B, di, N)
    return out


def mamba_decode_step(
    x: jnp.ndarray,            # (B, 1, d_model)
    conv_state: jnp.ndarray,   # (B, dc-1, d_inner)
    ssm_state: jnp.ndarray,    # (B, d_inner, N)
    lp: dict,
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) single-token decode; returns (out, conv_state', ssm_state')."""
    xz = x[:, 0] @ lp["in_proj"].astype(x.dtype)            # (B,2di)
    xi, z = jnp.split(xz, 2, axis=-1)

    w = lp["conv_w"].astype(x.dtype)                        # (dc, di)
    dc = w.shape[0]
    window = jnp.concatenate([conv_state, xi[:, None, :]], axis=1)  # (B,dc,di)
    conv = jnp.einsum("bcd,cd->bd", window, w) + lp["conv_b"].astype(x.dtype)
    xi = jax.nn.silu(conv)
    conv_state = window[:, 1:]

    dt, b, c = _ssm_proj(xi, lp, cfg)                       # (B,di),(B,N)x2
    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * a[None])              # (B,di,N)
    drive = (dt32 * xi.astype(jnp.float32))[..., None] * b.astype(jnp.float32)[
        :, None, :
    ]
    ssm_state = decay * ssm_state + drive
    y = jnp.einsum("bdn,bn->bd", ssm_state, c.astype(jnp.float32))
    y = y + lp["D"].astype(jnp.float32)[None] * xi.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ lp["out_proj"].astype(x.dtype))[:, None, :]
    return out, conv_state, ssm_state
