"""Model substrate: configuration, parameter trees, and sharding rules.

One ``ModelConfig`` dataclass covers all ten assigned architecture families
(dense / MoE / SSM / hybrid / VLM / audio enc-dec).  A single source of truth
— ``param_shapes(cfg)`` — defines every parameter's shape, dtype and logical
PartitionSpec; ``init_params`` materializes it for smoke tests / real
training and ``abstract_params`` produces sharded ShapeDtypeStructs for the
multi-pod dry-run (no allocation).

Sharding rules (Megatron-style TP over the ``model`` axis):
  * embeddings / lm_head: vocab-sharded over ``model``
  * attention qkv: output-feature sharded; wo: input-feature sharded
  * MLP: d_ff sharded (column- then row-parallel)
  * MoE: expert axis sharded over ``model`` when divisible (EP), else the
    per-expert d_ff axis (TP-in-expert)
  * SSM: d_inner sharded
  * norms / small vectors: replicated
Batch (and sequence for long-context decode caches) shards over ``data``
(+``pod``).  Head counts that don't divide the 16-way ``model`` axis rely on
GSPMD padding — the projection matrices shard on the flattened head*dim
feature axis, which is 128-aligned for every assigned config.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Literal, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    act: str = "swiglu"                # swiglu | geglu | gelu | relu2
    rms_one_plus: bool = False         # gemma-style (1 + w) RMSNorm scale
    post_norms: bool = False           # gemma2 sandwich norms
    rope_variant: str = "full"         # full | half (chatglm 2d rope)
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_alt: bool = False     # gemma2 alternating local/global
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0                   # per-expert FFN width
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # 2-D expert sharding (kimi-scale): expert axis over ``model`` AND the
    # per-expert d_ff over ``data`` — required to fit ~1T bf16 params/pod.
    expert_2d_sharding: bool = False
    # SSM (mamba1)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_free: bool = False            # falcon-mamba: no attention at all
    # enc-dec (whisper) — frontend is a stub; encoder sees frame embeddings
    n_enc_layers: int = 0
    enc_frames: int = 1500
    # VLM (llava) — patch frontend is a stub
    n_patches: int = 0
    # numerics
    dtype: Any = jnp.bfloat16          # activations
    param_dtype: Any = jnp.float32
    remat: str = "full"                # none | full
    loss_chunk: int = 512              # sequence chunking for the vocab loss
    # sequence-sharded attention hint: None = auto (hint only when n_heads
    # doesn't divide the model axis); measured per-arch overrides in §Perf.
    seq_shard_attn: Optional[bool] = None
    # int8 KV cache (serving): halves the decode memory term; per-position
    # per-head symmetric scales, dequant fused into the attention reads.
    kv_quant: bool = False
    # Mamba path: use the chunked Pallas selective-scan kernel
    # (kernels/selective_scan.py) instead of the XLA associative scan.
    # interpret=True on CPU (validation); compiled on TPU.
    ssm_kernel: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 — 16-way TP divisibility + lane alignment.

        Standard production practice (MaxText, Megatron): the embedding /
        lm_head vocab axis is padded so it shards evenly; padded ids are
        never produced by the tokenizer and their logits are free to float.
        """
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def gated(self) -> bool:
        return self.act in ("swiglu", "geglu")

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        shrink = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_dff=32 if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            sliding_window=8 if self.sliding_window else None,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_frames=16 if self.n_enc_layers else 1500,
            n_patches=8 if self.n_patches else 0,
            dtype=jnp.float32,
            param_dtype=jnp.float32,
            remat="none",
            loss_chunk=0,
        )
        shrink.update(overrides)
        return dataclasses.replace(self, **shrink)


ShapeSpec = Tuple[Tuple[int, ...], Any, P]  # (shape, dtype, pspec)


def _stack(layer_shapes: Dict[str, ShapeSpec], n_layers: int,
           prefix: str) -> Dict[str, ShapeSpec]:
    """Prepend the stacked-layer axis (scan-over-layers layout)."""
    out = {}
    for k, (shape, dt, spec) in layer_shapes.items():
        out[f"{prefix}{k}"] = ((n_layers, *shape), dt, P(None, *spec))
    return out


def _attn_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    d, pd = cfg.d_model, cfg.param_dtype
    return {
        "attn_norm": ((d,), pd, P(None)),
        "wq": ((d, cfg.q_dim), pd, P(None, "model")),
        "wk": ((d, cfg.kv_dim), pd, P(None, "model")),
        "wv": ((d, cfg.kv_dim), pd, P(None, "model")),
        "wo": ((cfg.q_dim, d), pd, P("model", None)),
    }


def _mlp_shapes(cfg: ModelConfig, d_ff: int) -> Dict[str, ShapeSpec]:
    d, pd = cfg.d_model, cfg.param_dtype
    out: Dict[str, ShapeSpec] = {
        "mlp_norm": ((d,), pd, P(None)),
        "w_up": ((d, d_ff), pd, P(None, "model")),
        "w_down": ((d_ff, d), pd, P("model", None)),
    }
    if cfg.gated:
        out["w_gate"] = ((d, d_ff), pd, P(None, "model"))
    return out


def _moe_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    d, pd, e, f = cfg.d_model, cfg.param_dtype, cfg.n_experts, cfg.moe_dff
    # EP if the expert count divides the model axis cleanly; else shard d_ff.
    ep = (e % 16 == 0)
    if cfg.expert_2d_sharding:
        # kimi-scale: experts over ``model``, per-expert d_ff over ``data``.
        es = ("model", None, "data")
        es_down = ("model", "data", None)
    elif ep:
        es = es_down = ("model", None, None)
    else:
        es = (None, None, "model")
        es_down = (None, "model", None)
    out: Dict[str, ShapeSpec] = {
        "mlp_norm": ((d,), pd, P(None)),
        "router": ((d, e), pd, P(None, None)),
        "experts_up": ((e, d, f), pd, P(*es)),
        "experts_down": ((e, f, d), pd, P(*es_down)),
    }
    if cfg.gated:
        out["experts_gate"] = ((e, d, f), pd, P(*es))
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        out["shared_up"] = ((d, fs), pd, P(None, "model"))
        out["shared_down"] = ((fs, d), pd, P("model", None))
        if cfg.gated:
            out["shared_gate"] = ((d, fs), pd, P(None, "model"))
    return out


def _ssm_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    d, pd = cfg.d_model, cfg.param_dtype
    di, n, dtr, dc = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    return {
        "ssm_norm": ((d,), pd, P(None)),
        "in_proj": ((d, 2 * di), pd, P(None, "model")),
        "conv_w": ((dc, di), pd, P(None, "model")),
        "conv_b": ((di,), pd, P("model")),
        "x_proj": ((di, dtr + 2 * n), pd, P("model", None)),
        "dt_proj": ((dtr, di), pd, P(None, "model")),
        "dt_bias": ((di,), pd, P("model")),
        "A_log": ((di, n), pd, P("model", None)),
        "D": ((di,), pd, P("model")),
        "out_proj": ((di, d), pd, P("model", None)),
    }


def _layer_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    d, pd = cfg.d_model, cfg.param_dtype
    shapes: Dict[str, ShapeSpec] = {}
    if cfg.family == "ssm":
        shapes.update(_ssm_shapes(cfg))
        return shapes
    if cfg.family == "hybrid":
        shapes.update(_attn_shapes(cfg))
        shapes.update(_ssm_shapes(cfg))
        shapes["fuse_attn_scale"] = ((d,), pd, P(None))
        shapes["fuse_ssm_scale"] = ((d,), pd, P(None))
        shapes.update(_mlp_shapes(cfg, cfg.d_ff))
        return shapes
    shapes.update(_attn_shapes(cfg))
    if cfg.family == "moe":
        shapes.update(_moe_shapes(cfg))
    else:
        shapes.update(_mlp_shapes(cfg, cfg.d_ff))
    if cfg.post_norms:
        shapes["post_attn_norm"] = ((d,), pd, P(None))
        shapes["post_mlp_norm"] = ((d,), pd, P(None))
    return shapes


def _enc_layer_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    """Whisper encoder layer: bidirectional attention + gelu MLP."""
    shapes = dict(_attn_shapes(cfg))
    shapes.update(_mlp_shapes(cfg, cfg.d_ff))
    return shapes


def _dec_cross_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    d, pd = cfg.d_model, cfg.param_dtype
    return {
        "xattn_norm": ((d,), pd, P(None)),
        "xwq": ((d, cfg.q_dim), pd, P(None, "model")),
        "xwk": ((d, cfg.kv_dim), pd, P(None, "model")),
        "xwv": ((d, cfg.kv_dim), pd, P(None, "model")),
        "xwo": ((cfg.q_dim, d), pd, P("model", None)),
    }


def param_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    """Flat dict path -> (shape, dtype, PartitionSpec) — the single source
    of truth for init, abstract specs and sharding."""
    d, v, pd = cfg.d_model, cfg.padded_vocab, cfg.param_dtype
    shapes: Dict[str, ShapeSpec] = {
        "embed": ((v, d), pd, P("model", None)),
        "final_norm": ((d,), pd, P(None)),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = ((d, v), pd, P(None, "model"))
    shapes.update(_stack(_layer_shapes(cfg), cfg.n_layers, "layers/"))
    if cfg.family == "audio":
        # Encoder stack + cross-attention in the decoder. Conv frontend is a
        # stub: the encoder consumes precomputed frame embeddings.
        shapes["enc_pos"] = ((cfg.enc_frames, d), pd, P(None, None))
        shapes["enc_final_norm"] = ((d,), pd, P(None))
        shapes.update(
            _stack(_enc_layer_shapes(cfg), cfg.n_enc_layers, "enc_layers/")
        )
        shapes.update(
            _stack(_dec_cross_shapes(cfg), cfg.n_layers, "layers/")
        )
    if cfg.family == "vlm":
        # Patch frontend is a stub: a single learned projection applied to
        # precomputed patch embeddings.
        shapes["patch_proj"] = ((d, d), pd, P(None, "model"))
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for s, _, _ in param_shapes(cfg).values())


def active_param_count(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: top_k of n_experts)."""
    if cfg.family != "moe" or not cfg.n_experts:
        return param_count(cfg)
    total = 0
    for name, (shape, _, _) in param_shapes(cfg).items():
        n = int(np.prod(shape))
        if "experts_" in name:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


def _init_one(key, name: str, shape, dtype):
    if not shape or shape[-1] == 0:
        return jnp.zeros(shape, dtype)
    last = name.split("/")[-1]
    if "norm" in last or last in ("conv_b", "dt_bias", "D"):
        return jnp.ones(shape, dtype)
    if last == "A_log":
        # mamba1 init: A = -(1..N) broadcast over channels
        n = shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), shape)
        return jnp.log(a).astype(dtype)
    if last in ("fuse_attn_scale", "fuse_ssm_scale"):
        return jnp.full(shape, 0.5, dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    return {
        name: _init_one(k, name, shape, dt)
        for k, (name, (shape, dt, _)) in zip(keys, sorted(shapes.items()))
    }


def param_pspecs(cfg: ModelConfig) -> Dict[str, P]:
    return {name: spec for name, (_, _, spec) in param_shapes(cfg).items()}


def abstract_params(
    cfg: ModelConfig, mesh: Mesh
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Sharded ShapeDtypeStructs for AOT lowering — no device allocation."""
    out = {}
    for name, (shape, dt, spec) in param_shapes(cfg).items():
        out[name] = jax.ShapeDtypeStruct(
            shape, dt, sharding=NamedSharding(mesh, spec)
        )
    return out


def layer_tree(params: Dict[str, jnp.ndarray], prefix: str = "layers/"):
    """Sub-dict of stacked per-layer params (leading axis = layer)."""
    plen = len(prefix)
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix)}
