"""Rotary position embeddings: full-dim and half-dim (chatglm 2d) variants."""

from __future__ import annotations

import jax.numpy as jnp


def _rotate(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply standard interleaved-pair RoPE over the full last dim.

    x: (..., S, H, D) with D even; positions: (..., S) int32.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    theta: float = 10000.0,
    variant: str = "full",
) -> jnp.ndarray:
    """Apply RoPE.  ``variant='half'`` rotates only the first half of the head
    dim (chatglm's 2d rope), leaving the rest as-is."""
    if variant == "half":
        d = x.shape[-1]
        rot = _rotate(x[..., : d // 2], positions, theta)
        return jnp.concatenate([rot, x[..., d // 2 :]], axis=-1)
    return _rotate(x, positions, theta)
