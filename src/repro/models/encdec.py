"""Whisper-style encoder-decoder backbone (conv frontend is a STUB).

Per the assignment, the audio frontend is stubbed: the encoder consumes
precomputed frame embeddings (B, T_enc, d_model) from ``input_specs()``.
The encoder is a bidirectional transformer; the decoder is the shared
``transformer.decoder_layer`` stack plus cross-attention to the encoder
output (cross K/V precomputed once per request and carried in the cache).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import ModelConfig, layer_tree
from repro.models.layers import embed_tokens, mlp, rmsnorm


def encoder_layer(x, lp, cfg: ModelConfig):
    h = rmsnorm(x, lp["attn_norm"])
    b, s, _ = h.shape
    q = (h @ lp["wq"].astype(h.dtype)).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (h @ lp["wk"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = (h @ lp["wv"].astype(h.dtype)).reshape(b, s, cfg.n_kv_heads, cfg.hd)
    o = attn.attention(q, k, v, causal=False)  # bidirectional, no RoPE
    x = x + o.reshape(b, s, cfg.q_dim) @ lp["wo"].astype(h.dtype)
    h = rmsnorm(x, lp["mlp_norm"])
    return x + mlp(h, lp, cfg)


def encode(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Frame embeddings -> encoder hidden states."""
    x = frames.astype(cfg.dtype) + params["enc_pos"].astype(cfg.dtype)[None]
    lt = layer_tree(params, "enc_layers/")

    def body(x, lp):
        return encoder_layer(x, lp, cfg), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, lt)
    return rmsnorm(x, params["enc_final_norm"])


def _cross_attend(x, lp, enc, cfg: ModelConfig):
    b, s, _ = x.shape
    h = rmsnorm(x, lp["xattn_norm"])
    q = (h @ lp["xwq"].astype(h.dtype)).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (enc @ lp["xwk"].astype(enc.dtype)).reshape(
        b, enc.shape[1], cfg.n_kv_heads, cfg.hd
    )
    v = (enc @ lp["xwv"].astype(enc.dtype)).reshape(
        b, enc.shape[1], cfg.n_kv_heads, cfg.hd
    )
    o = attn.attention(q, k, v, causal=False)
    return o.reshape(b, s, cfg.q_dim) @ lp["xwo"].astype(h.dtype)


def encdec_hidden(
    params: Dict,
    frames: jnp.ndarray,    # (B, T_enc, d) stub frame embeddings
    tokens: jnp.ndarray,    # (B, S) decoder token ids
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full enc-dec forward to decoder hidden states; returns (h, aux)."""
    from repro.models.attention import qkv_project
    from repro.models.transformer import layer_windows

    enc = encode(params, frames, cfg)
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.arange(x.shape[1])
    lt = layer_tree(params)
    windows = layer_windows(cfg)

    def body(carry, inputs):
        x, aux = carry
        lp, window = inputs
        h = rmsnorm(x, lp["attn_norm"])
        q, k, v = qkv_project(h, lp, cfg, positions)
        o = attn.attention(q, k, v, causal=True, window=window,
                           cap=cfg.attn_softcap)
        x = x + o.reshape(*x.shape[:-1], cfg.q_dim) @ lp["wo"].astype(x.dtype)
        x = x + _cross_attend(x, lp, enc, cfg)
        h = rmsnorm(x, lp["mlp_norm"])
        x = x + mlp(h, lp, cfg)
        return (x, aux), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), (lt, windows))
    return rmsnorm(x, params["final_norm"]), aux


def prefill_cross_cache(
    params: Dict, frames: jnp.ndarray, cfg: ModelConfig
) -> Dict[str, jnp.ndarray]:
    """Precompute per-layer cross K/V from the encoder output (serving)."""
    enc = encode(params, frames, cfg)
    lt = layer_tree(params)
    b, t = enc.shape[0], enc.shape[1]

    def body(_, lp):
        k = (enc @ lp["xwk"].astype(enc.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.hd
        )
        v = (enc @ lp["xwv"].astype(enc.dtype)).reshape(
            b, t, cfg.n_kv_heads, cfg.hd
        )
        return None, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    _, (xk, xv) = jax.lax.scan(body, None, lt)
    return {"xk": xk, "xv": xv}
