"""FLOP models: 6·N·D for LMs, and the paper's SD-KDE flop/byte model (§4.1).

``sdkde_flops`` reproduces the paper's tile-aware accounting exactly —
FLOPs_d(k) = (4d + 12 + d/4 + 3/2)·k² with n_test = k/8, each exp budgeted
at 8 FLOPs (the A6000's 128:16 FP32:SFU ratio; we keep the same budget for
comparability and report a TPU-specific budget separately) — validated
against the paper's 81.5·k² figure for d=16 in tests/test_flop_model.py.
"""

from __future__ import annotations

from repro.models.common import ModelConfig, active_param_count, param_count

EXP_FLOPS = 8  # paper's SFU accounting: 1 exp == 8 FP32 flops


# ---------------------------------------------------------------------------
# LM model FLOPs.
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, tokens: int, *, training: bool = True) -> float:
    """MODEL_FLOPS = 6·N·D (training) or 2·N·D (inference); N_active for MoE."""
    n = active_param_count(cfg)
    per_token = 6 * n if training else 2 * n
    return float(per_token) * tokens


# ---------------------------------------------------------------------------
# Paper §4.1: d-dimensional SD-KDE flop / byte / intensity model.
# ---------------------------------------------------------------------------


def sdkde_flops(k: int, d: int = 16, *, n_test: int | None = None) -> float:
    """Paper's FLOP model with n_test defaulting to k/8.

    Stages (§4.1): score Gram 2dk², score numerator GEMM 2dk² (+4k² scalar
    +8k² exp), final KDE 2dk·n_test (+4 k·n_test scalar +8 k·n_test exp).
    With n_test=k/8 this collapses to (4d + 12 + d/4 + 3/2)·k².
    """
    nt = k / 8 if n_test is None else n_test
    gram = 2.0 * d * k * k
    numer = 2.0 * d * k * k + (4.0 + EXP_FLOPS) * k * k
    final = 2.0 * d * k * nt + (4.0 + EXP_FLOPS) * k * nt
    return gram + numer + final


def sdkde_flops_coefficient(d: int = 16) -> float:
    """The k² coefficient (4d + 12 + d/4 + 3/2); 81.5 for d=16."""
    return 4.0 * d + 12.0 + d / 4.0 + 1.5


def sdkde_bytes(
    k: int,
    d: int = 16,
    *,
    block_m: int = 64,
    block_n: int = 1024,
    itemsize: int = 4,
) -> float:
    """Paper's tile-aware GDDR/HBM byte model (§4.1).

    Per tile: row tile loads (block_m·d), streamed column tile (block_n·d),
    partial output writes (block_m·(d+1) ≈ block_m·d + block_m); the full
    problem runs (k/block_m)·(k/block_n) tiles.
    """
    per_tile = itemsize * (
        2 * block_m * d + block_n * d + block_m
    )
    tiles = (k / block_m) * (k / block_n)
    return per_tile * tiles


def sdkde_intensity(k: int, d: int = 16, **kw) -> float:
    """Arithmetic intensity (flops/byte); ≈72 for d=16 at the paper's tiles."""
    return sdkde_flops(k, d) / sdkde_bytes(k, d, **kw)


def sdkde_flops_1d(k: int, *, n_test: int | None = None) -> float:
    """Appendix A 1-D model: c1·k² + c2·k·n_test with c1≈16, c2≈14."""
    nt = k / 8 if n_test is None else n_test
    return 16.0 * k * k + 14.0 * k * nt
