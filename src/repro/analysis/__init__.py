from repro.analysis.hlo import collective_bytes, hlo_collectives
from repro.analysis.roofline import RooflineTerms, roofline_from_compiled, HW
from repro.analysis.flops import model_flops, sdkde_flops, sdkde_bytes

__all__ = [
    "collective_bytes",
    "hlo_collectives",
    "RooflineTerms",
    "roofline_from_compiled",
    "HW",
    "model_flops",
    "sdkde_flops",
    "sdkde_bytes",
]
