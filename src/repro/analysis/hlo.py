"""HLO text analysis: collective traffic extraction.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
bytes; per the brief we parse the (lowered or compiled) HLO text and sum the
operand sizes of every collective op:

    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute

For each op we record the *output* shape bytes (the wire payload actually
moved per participating device, up to the algorithm factor — see
``ALGO_FACTOR`` for the per-collective bytes-on-the-link multiplier used by
the roofline model).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Bytes actually traversing a link per device, as a multiple of the payload
# (bandwidth-optimal ring algorithms): all-reduce moves ~2× the shard,
# all-gather/reduce-scatter ~1×, all-to-all ~1×, permute exactly 1×.
ALGO_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# e.g.  "bf16[2048,512]{1,0}"  or  "f32[]"; tuples appear as (a, b, ...)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(",
    re.MULTILINE,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int            # output payload bytes (per device)
    link_bytes: float     # bytes on the wire (payload × algo factor)


def hlo_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Every collective in the HLO with its payload size.

    ``-start``/``-done`` async pairs are counted once (on ``-start``;
    bare ops count directly).
    """
    ops: List[CollectiveOp] = []
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue   # counted at -start
        b = _shape_bytes(shape_str)
        ops.append(CollectiveOp(kind, b, b * ALGO_FACTOR[kind]))
    return ops


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Aggregate: payload + wire bytes per collective kind and total."""
    agg: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    wire = 0.0
    payload = 0
    for op in hlo_collectives(hlo_text):
        agg[op.kind] += op.bytes
        counts[op.kind] += 1
        wire += op.link_bytes
        payload += op.bytes
    out = {f"{k}_bytes": v for k, v in agg.items()}
    out.update({f"{k}_count": float(c) for k, c in counts.items()})
    out["payload_bytes"] = float(payload)
    out["wire_bytes"] = wire
    return out
