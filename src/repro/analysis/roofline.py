"""Three-term roofline model from the compiled dry-run artifact.

Per the brief (TPU v5e targets)::

    compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s)     [bf16 peak]
    memory term     = HLO_bytes / (chips × 819e9 B/s)         [HBM]
    collective term = collective_wire_bytes / (chips × 50e9)  [ICI per link]

Inputs come from ``compiled.cost_analysis()`` (flops, bytes accessed) and
the HLO collective parser (``analysis.hlo``).  **Measured fact** (verified
against a hand-computable GEMM in tests/test_roofline.py): cost_analysis on
an SPMD lowering reports PER-DEVICE flops/bytes — the partitioned module's
shapes — so the brief's ``HLO_FLOPs / (chips × peak)`` is implemented as
``flops_per_device / peak``; the two are identical for an evenly-sharded
program.  Collective payloads parsed from the HLO are per-device too.

The dominant term is the bottleneck; roofline fraction = dominant /
(sum of terms) is NOT meaningful (terms overlap on real hardware), so we
report each term in seconds plus ``bound`` = argmax.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link (~ per chip per dir)
    hbm_bytes: float = 16e9           # capacity per chip


HW = Hardware()


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                  # PER-DEVICE FLOPs (one execution)
    hlo_bytes: float                  # PER-DEVICE HBM bytes accessed
    collective_bytes: float           # per-device wire bytes
    model_flops: float = 0.0          # 6·N·D (or paper model for SD-KDE)
    bytes_per_device: float = 0.0     # peak memory from memory_analysis
    collective_detail: Optional[Dict[str, float]] = None

    # -- the three terms, in seconds --------------------------------------

    @property
    def t_compute(self) -> float:
        # hlo_flops is per-device ≡ global/chips for even sharding.
        return self.hlo_flops / HW.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HW.hbm_bw

    @property
    def t_collective(self) -> float:
        # per-device wire bytes over the per-chip link bandwidth
        return self.collective_bytes / HW.ici_bw

    @property
    def bound(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower-bound step time: max of the three overlapping terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector.
        model_flops is global; hlo_flops is per-device."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-limited step time."""
        t = self.step_time
        if not t:
            return 0.0
        return self.model_flops / (t * self.chips * HW.peak_flops)

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound": self.bound,
            "step_time_s": self.step_time,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "bytes_per_device": self.bytes_per_device,
        }


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    model_flops: float = 0.0,
    hlo_text: Optional[str] = None,
) -> RooflineTerms:
    """Build RooflineTerms from a jax compiled object (+ optional HLO text).

    FLOPs / bytes / collective payloads come from the loop-aware HLO
    executable analyzer (``analysis.hlo_exec``) — XLA's own cost_analysis
    counts while-loop bodies once, which under-reports scan-over-layers
    programs by ~(layers × microbatches)× (see hlo_exec docstring).  All
    quantities are per-device (the SPMD module's shapes are
    post-partitioning).
    """
    from repro.analysis.hlo_exec import analyze_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    ex = analyze_hlo(text)
    flops = ex.flops
    byts = ex.bytes
    coll = {
        "wire_bytes": ex.coll_wire,
        "payload_bytes": ex.coll_payload,
        "count": ex.coll_count,
        "transcendentals": ex.transcendentals,
        "unknown_trip_loops": ex.unknown_trip_loops,
        **{f"{k}_bytes": v for k, v in ex.coll_by_kind.items()},
    }

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem["bytes_per_device"] = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        mem["bytes_per_device"] = 0.0

    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll["wire_bytes"],
        model_flops=model_flops,
        bytes_per_device=mem["bytes_per_device"],
        collective_detail=coll,
    )


def format_table(rows) -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    hdr = (
        "| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
        "| bound | model/HLO flops | MFU@roofline | GB/device |"
    )
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        d = r.row() if isinstance(r, RooflineTerms) else r
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['t_compute_s']*1e3:.2f} | {d['t_memory_s']*1e3:.2f} "
            f"| {d['t_collective_s']*1e3:.2f} | {d['bound']} "
            f"| {d['useful_ratio']:.2f} | {d['mfu']*100:.1f}% "
            f"| {d['bytes_per_device']/2**30:.2f} |"
        )
    return "\n".join(lines)
