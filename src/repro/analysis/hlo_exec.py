"""Loop-aware cost analysis of compiled (post-SPMD, post-fusion) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts every while-loop
BODY ONCE — a scan-over-layers train step therefore under-reports FLOPs,
bytes and collectives by ~(n_layers × microbatches)×.  Verified in
tests/test_hlo_exec.py: a 10-iteration scan of matmuls reports 1 matmul of
flops.  Since every production program here is scan-based (that is what
keeps compile time depth-independent), the roofline would be garbage
without loop scaling.

This analyzer parses the compiled module text and propagates costs through
the call graph:

  * while loops   × their trip count — read from the instruction's
                    ``backend_config={"known_trip_count":{"n": T}}`` (XLA
                    emits it for counted loops), falling back to the
                    condition computation's comparison constant;
  * fusions       — FLOPs from the fused computation's instructions; HBM
                    bytes ONLY at the fusion boundary (that is what fusion
                    means), with dynamic-slice/gather-consumed parameters
                    counted at their slice size (a scanned layer reads one
                    layer's weights per iteration, not the whole stack);
  * collectives   — payload = result shape bytes; wire bytes apply the
                    ring-algorithm factor (all-reduce 2×, others 1×);
  * dots          — 2 · prod(result) · K, K from the lhs contracting dims.

Shapes in the compiled module are per-device (post-partitioning), so all
outputs are per-device quantities — exactly what the roofline terms want.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "power", "sine", "cosine", "logistic", "cbrt", "erf",
    "atan2",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "popcnt", "clz",
}

_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "partition-id", "replica-id", "after-all", "iota", "rng-bit-generator",
    "get-dimension-size", "domain", "opt-barrier", "custom-call",
}


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every array in a (possibly tuple) shape."""
    elems = byts = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]   # instr name -> result shape string


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([a-z][\w\-]*)\("
)


def _split_operands(s: str) -> List[str]:
    # Newer HLO dumps print typed operands — ``dot(f32[32,128]{1,0}
    # %Arg_0.1, ...)`` — whose shape strings contain commas, so the operand
    # names must be pulled out by the %-sigil, not by comma splitting.
    sigiled = re.findall(r"%([\w.\-]+)", s)
    if sigiled:
        return sigiled
    out = []
    for part in s.split(","):
        part = part.strip()
        if re.fullmatch(r"[\w.\-]+", part):
            out.append(part)
    return out


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse computations; returns (computations, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEAD.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        is_root = bool(m.group(1))
        name, shape, opcode = m.group(2), m.group(3), m.group(4)
        rest = line[m.end():]
        # operand section: up to the first unnested ')'
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_str, attrs = rest[:i], rest[i + 1:]
        instr = Instr(name, shape, opcode, _split_operands(operands_str),
                      attrs, is_root)
        cur.instrs.append(instr)
        cur.shapes[name] = shape
    return comps, entry


# ---------------------------------------------------------------------------
# Cost propagation.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_payload: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: float = 0.0
    unknown_trip_loops: int = 0

    def add(self, other: "Stats", scale: float = 1.0):
        self.flops += other.flops * scale
        self.transcendentals += other.transcendentals * scale
        self.bytes += other.bytes * scale
        self.coll_payload += other.coll_payload * scale
        self.coll_wire += other.coll_wire * scale
        self.coll_count += other.coll_count * scale
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * scale
        self.unknown_trip_loops += other.unknown_trip_loops


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BRANCH_RE = re.compile(r"(?:branch_computations=\{([^}]*)\}|"
                        r"true_computation=%?([\w.\-]+), "
                        r"false_computation=%?([\w.\-]+))")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_SLICE_SIZES_RE = re.compile(r"dynamic_slice_sizes=\{([0-9,]*)\}")


def _dot_flops(instr: Instr, comp: Computation) -> float:
    _, out_elems = 0, 0
    out_elems, _ = shape_elems_bytes(instr.shape)
    lhs_shape = comp.shapes.get(instr.operands[0], "") if instr.operands else ""
    dims = shape_dims(lhs_shape)
    m = _CONTRACT_RE.search(instr.attrs)
    k = 1
    if m and dims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(dims):
                    k *= dims[i]
    return 2.0 * out_elems * k


class _Analyzer:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self._memo: Dict[Tuple[str, bool], Stats] = {}
        # effective read bytes of each fusion computation's parameters
        self._param_reads: Dict[str, List[float]] = {}

    # -- fusion parameter read sizes ---------------------------------------

    def param_read_bytes(self, comp_name: str) -> List[float]:
        if comp_name in self._param_reads:
            return self._param_reads[comp_name]
        comp = self.comps[comp_name]
        uses: Dict[str, List[Instr]] = {}
        for ins in comp.instrs:
            for op in ins.operands:
                uses.setdefault(op, []).append(ins)
        # HLO prints parameters in declaration order, so enumerating them in
        # instruction order recovers the call-site operand mapping.
        reads: List[float] = []
        for ins in comp.instrs:
            if ins.opcode != "parameter":
                continue
            _, full = shape_elems_bytes(ins.shape)
            consumers = uses.get(ins.name, [])
            slicey = consumers and all(
                c.opcode in ("dynamic-slice", "gather") for c in consumers
            )
            if slicey:
                eff = 0.0
                for c in consumers:
                    _, b = shape_elems_bytes(c.shape)
                    eff += b
                reads.append(min(eff, full))
            else:
                reads.append(full)
        self._param_reads[comp_name] = reads
        return reads

    # -- main recursion ------------------------------------------------------

    def stats(self, comp_name: str, fused: bool) -> Stats:
        key = (comp_name, fused)
        if key in self._memo:
            return self._memo[key]
        out = Stats()
        self._memo[key] = out   # cycles can't occur in HLO; safe placeholder
        comp = self.comps.get(comp_name)
        if comp is None:
            return out
        for ins in comp.instrs:
            self._instr(ins, comp, out, fused)
        return out

    def _instr(self, ins: Instr, comp: Computation, out: Stats, fused: bool):
        op = ins.opcode
        res_elems, res_bytes = shape_elems_bytes(ins.shape)

        if op == "while":
            body = _BODY_RE.search(ins.attrs)
            cond = _COND_RE.search(ins.attrs)
            trip_m = _TRIP_RE.search(ins.attrs)
            trip = int(trip_m.group(1)) if trip_m else None
            if trip is None:
                trip = 1
                out.unknown_trip_loops += 1
            if body:
                out.add(self.stats(body.group(1), False), trip)
            if cond:
                out.add(self.stats(cond.group(1), False), trip)
            return

        if op == "conditional":
            m = _BRANCH_RE.search(ins.attrs)
            branches = []
            if m:
                if m.group(1):
                    branches = [b.strip().lstrip("%") for b in
                                m.group(1).split(",")]
                else:
                    branches = [m.group(2), m.group(3)]
            sub = [self.stats(b, False) for b in branches if b]
            if sub:
                worst = max(sub, key=lambda s: s.flops + s.bytes)
                out.add(worst)
            return

        if op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            if m:
                inner = self.stats(m.group(1), True)
                out.flops += inner.flops
                out.transcendentals += inner.transcendentals
                out.coll_payload += inner.coll_payload
                out.coll_wire += inner.coll_wire
                if not fused:
                    # HBM traffic only at the fusion boundary.
                    reads = self.param_read_bytes(m.group(1))
                    for i, opnd in enumerate(ins.operands):
                        if i < len(reads):
                            out.bytes += reads[i]
                        else:
                            _, b = shape_elems_bytes(
                                comp.shapes.get(opnd, ""))
                            out.bytes += b
                    out.bytes += res_bytes
            return

        if op == "call":
            m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
            if m:
                out.add(self.stats(m.group(1), fused))
            return

        if op in _COLLECTIVES:
            out.coll_payload += res_bytes
            out.coll_wire += res_bytes * _COLLECTIVES[op]
            out.coll_by_kind[op] = out.coll_by_kind.get(op, 0.0) + res_bytes
            out.coll_count += 1
            if not fused:
                out.bytes += 2 * res_bytes   # read + write at HBM
            return

        if op == "dot":
            out.flops += _dot_flops(ins, comp)
            if not fused:
                for opnd in ins.operands:
                    _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
                    out.bytes += b
                out.bytes += res_bytes
            return

        if op == "convolution":
            # rare here (stub frontends); approximate as output × kernel MACs
            out.flops += 2.0 * res_elems
            if not fused:
                out.bytes += res_bytes
            return

        if op in ("reduce", "reduce-window"):
            in_elems = 0
            for opnd in ins.operands[: max(1, len(ins.operands) // 2)]:
                e, _ = shape_elems_bytes(comp.shapes.get(opnd, ""))
                in_elems += e
            out.flops += in_elems
            if not fused:
                for opnd in ins.operands:
                    _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
                    out.bytes += b
                out.bytes += res_bytes
            return

        if op in ("dynamic-slice", "gather", "slice"):
            if not fused:
                out.bytes += 2 * res_bytes   # read slice + write result
            return

        if op in ("dynamic-update-slice", "scatter"):
            if not fused:
                upd = 0.0
                for opnd in ins.operands[1:]:
                    _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
                    upd += b
                out.bytes += 2 * upd         # read updates + write in place
            return

        if op in _TRANSCENDENTAL:
            out.flops += res_elems
            out.transcendentals += res_elems
            if not fused:
                out.bytes += 2 * res_bytes
            return

        if op in _ELEMENTWISE or op == "convert":
            out.flops += res_elems
            if not fused:
                for opnd in ins.operands:
                    _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
                    out.bytes += b
                out.bytes += res_bytes
            return

        if op in ("copy", "transpose", "reshape", "broadcast", "reverse",
                  "concatenate", "pad", "copy-start", "copy-done",
                  "all-gather-start", "all-gather-done", "select-and-scatter",
                  "sort"):
            if op in ("all-gather-start", "all-gather-done"):
                if op == "all-gather-start":
                    out.coll_payload += res_bytes
                    out.coll_wire += res_bytes
                    out.coll_by_kind["all-gather"] = (
                        out.coll_by_kind.get("all-gather", 0.0) + res_bytes
                    )
                    out.coll_count += 1
                return
            if not fused:
                for opnd in ins.operands:
                    _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
                    out.bytes += b
                out.bytes += res_bytes
            return

        if op in _FREE:
            return
        # unknown op: count result bytes conservatively
        if not fused:
            out.bytes += res_bytes


def analyze_hlo(text: str) -> Stats:
    """Loop-scaled per-device cost of one execution of the compiled module."""
    comps, entry = parse_module(text)
    if not entry:
        # pick the computation named *_spmd main, else the largest
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    an = _Analyzer(comps)
    return an.stats(entry, False)


# ---------------------------------------------------------------------------
# Profiling breakdown (the dry-run "profiler": who owns the bytes/flops?).
# ---------------------------------------------------------------------------


def breakdown(text: str, top: int = 20):
    """Loop-scaled per-instruction contributions, largest first.

    Returns a list of dicts {where, opcode, metadata_op, flops, bytes,
    coll_wire, trips} — the closest thing to a profile the dry-run offers;
    §Perf iterations read this to find the dominant traffic sources.
    """
    comps, entry = parse_module(text)
    an = _Analyzer(comps)
    rows = []

    def visit(comp_name: str, scale: float, fused: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = _BODY_RE.search(ins.attrs)
                trip_m = _TRIP_RE.search(ins.attrs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    visit(body.group(1), scale * trip, False)
                continue
            if ins.opcode == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if m:
                    visit(m.group(1), scale, fused)
                continue
            one = Stats()
            an._instr(ins, comp, one, fused)
            if one.flops or one.bytes or one.coll_wire:
                md = re.search(r'op_name="([^"]*)"', ins.attrs)
                rows.append({
                    "where": comp_name,
                    "opcode": ins.opcode,
                    "op_name": md.group(1) if md else "",
                    "flops": one.flops * scale,
                    "bytes": one.bytes * scale,
                    "coll_wire": one.coll_wire * scale,
                    "trips": scale,
                    "shape": ins.shape,
                })

    visit(entry, 1.0, False)
    rows.sort(key=lambda r: -(r["bytes"] + r["coll_wire"] * 16))
    return rows[:top]
