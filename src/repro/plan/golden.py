"""Golden-decision fixtures: pin the planner's choice for every committed
benchmark cell.

Every shape-bearing cell of ``BENCH_flash.json`` and
``benchmarks/BENCH_baseline.json`` derives one :class:`~repro.plan.planner.
PlanRequest` (same derivation everywhere — tests, the regen CLI, and the
benchmark harness all call :func:`request_for_cell`), and the fixture at
``tests/golden_plans.json`` records the planner's decision for each.

The suite in ``tests/test_planner.py`` recomputes every plan from the
committed artifacts and fails on any drift; ``python -m repro.plan
--regen-golden`` is the ONLY way the fixture changes — a deliberate,
reviewed rewrite, never a silent one.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.plan.planner import (
    DEFAULT_ACCURACY,
    DEFAULT_Q,
    EPS_SAFETY,
    TIER_RTOL,
    BenchModel,
    ExecutionPlan,
    PlanRequest,
    default_bench_paths,
    plan,
)

# "planner" cells are the planner's own benchmark output — deriving
# requests from them would feed the fixture back into itself.
_SKIP_CELLS = {"harness", "harness_error", "planner"}
_BACKENDS = {"jnp", "pallas", "ring"}


def default_golden_path() -> Path:
    """tests/golden_plans.json at the repo root."""
    return Path(__file__).resolve().parents[3] / "tests" / "golden_plans.json"


def request_for_cell(cell: dict) -> Optional[PlanRequest]:
    """The PlanRequest one benchmark cell derives (None = no shape info).

    Derivation rules (deterministic, shared by tests / regen / gate):
      * ``n`` and ``d`` must both be present and positive;
      * ``q`` = the cell's query rows (``m``, else ``batch``, else the
        serve default);
      * accuracy: an ``epsilon`` cell targets ``epsilon * EPS_SAFETY``
        (the loosest target that epsilon is admissible under, floored at
        the f32 default); a ``tier`` cell targets that tier's documented
        rtol; the bf16-vs-f32 ``precision_model`` cell targets bf16-grade;
        everything else targets the f32 default;
      * backend: taken from the cell when it names one, else "auto";
      * streaming cells plan ``stream=True``.
    """
    if not isinstance(cell, dict) or cell.get("cell") in _SKIP_CELLS:
        return None
    name = str(cell.get("cell", ""))
    try:
        n, d = int(cell["n"]), int(cell["d"])
    except (KeyError, TypeError, ValueError):
        return None
    if n < 1 or d < 1:
        return None
    q = cell.get("m", cell.get("batch", DEFAULT_Q))
    try:
        q = max(1, int(q))
    except (TypeError, ValueError):
        q = DEFAULT_Q

    accuracy = DEFAULT_ACCURACY
    rff = False
    if name == "rff_cascade":
        # the cascade cell carries its own accuracy target and is, by
        # construction, cascade-eligible traffic
        rff = True
        try:
            accuracy = float(cell.get("accuracy_target", DEFAULT_ACCURACY))
        except (TypeError, ValueError):
            accuracy = DEFAULT_ACCURACY
        if not accuracy > 0.0:
            accuracy = DEFAULT_ACCURACY
    elif "epsilon" in cell:
        try:
            eps = float(cell["epsilon"])
        except (TypeError, ValueError):
            eps = 0.0
        if eps > 0.0:
            accuracy = max(DEFAULT_ACCURACY, eps * EPS_SAFETY)
    elif cell.get("tier") in TIER_RTOL:
        accuracy = TIER_RTOL[str(cell["tier"])]
    elif name == "precision_model":
        accuracy = TIER_RTOL["bf16"]

    # normalize float-product dust (1e-6 * 100.0 != 1e-4 bitwise) so the
    # fixture keys and gated cells carry clean targets
    accuracy = float(f"{accuracy:.6g}")
    backend = cell.get("backend")
    backend = backend if backend in _BACKENDS else "auto"
    return PlanRequest(n=n, d=d, q=q, accuracy=accuracy, backend=backend,
                       stream=name.startswith("streaming"), rff=rff)


def request_key(req: PlanRequest) -> str:
    """Stable fixture key for one request.

    The ``rff`` marker is appended only for cascade-eligible requests so
    every pre-cascade fixture key stays byte-identical.
    """
    key = (f"n={req.n} d={req.d} q={req.q} accuracy={req.accuracy:g} "
           f"backend={req.backend} stream={req.stream}")
    return key + " rff=True" if req.rff else key


def requests_from_docs(docs: Sequence[dict]) -> List[PlanRequest]:
    """Every distinct request the docs' cells derive, in stable order."""
    seen: Dict[str, PlanRequest] = {}
    for doc in docs:
        for cell in (doc or {}).get("cells", ()):
            req = request_for_cell(cell)
            if req is not None:
                seen.setdefault(request_key(req), req)
    return [seen[k] for k in sorted(seen)]


def load_docs(paths: Optional[Sequence[Path]] = None) -> List[dict]:
    docs = []
    for p in (paths if paths is not None else default_bench_paths()):
        p = Path(p)
        if p.exists():
            with open(p) as f:
                docs.append(json.load(f))
    return docs


def golden_entries(paths: Optional[Sequence[Path]] = None
                   ) -> Dict[str, dict]:
    """key → {"request", "plan"} for every committed-cell request."""
    docs = load_docs(paths)
    bench = BenchModel(docs)
    out: Dict[str, dict] = {}
    for req in requests_from_docs(docs):
        p: ExecutionPlan = plan(req, bench=bench)
        out[request_key(req)] = {"request": req.as_dict(),
                                 "plan": p.as_dict()}
    return out


def write_golden(path: Optional[Path] = None,
                 bench_paths: Optional[Sequence[Path]] = None
                 ) -> Tuple[Path, int]:
    """(Re)write the golden fixture — the deliberate regen path."""
    path = Path(path) if path is not None else default_golden_path()
    entries = golden_entries(bench_paths)
    doc = {
        "meta": {
            "regen": "python -m repro.plan --regen-golden",
            "description": "pinned planner decisions per committed "
                           "benchmark cell (tests/test_planner.py)",
            "entries": len(entries),
        },
        "plans": {k: entries[k] for k in sorted(entries)},
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    return path, len(entries)


def load_golden(path: Optional[Path] = None) -> dict:
    path = Path(path) if path is not None else default_golden_path()
    with open(path) as f:
        return json.load(f)


__all__ = [
    "default_golden_path", "request_for_cell", "request_key",
    "requests_from_docs", "load_docs", "golden_entries",
    "write_golden", "load_golden",
]
