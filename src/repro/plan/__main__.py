"""Planner CLI.

Two jobs:

* ``python -m repro.plan --regen-golden`` — deliberately rewrite the
  golden-decision fixture (``tests/golden_plans.json``) from the committed
  benchmark artifacts. The conformance suite and the benchmark gate treat
  any other route to a changed fixture as drift and fail.
* ``python -m repro.plan --n 262144 --d 16 [--q --accuracy --backend
  --stream]`` — print the plan one request resolves to, as JSON.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.plan.golden import default_golden_path, write_golden
from repro.plan.planner import (
    DEFAULT_ACCURACY,
    DEFAULT_Q,
    plan_for,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.plan",
        description="Resolve execution plans / regenerate the golden "
                    "decision fixture.")
    ap.add_argument("--regen-golden", action="store_true",
                    help="rewrite the golden-decision fixture from the "
                         "committed benchmark artifacts")
    ap.add_argument("--golden", type=Path, default=None,
                    help=f"fixture path (default: {default_golden_path()})")
    ap.add_argument("--bench", type=Path, action="append", default=None,
                    help="benchmark JSON source (repeatable; default: "
                         "BENCH_flash.json + benchmarks/BENCH_baseline.json)")
    ap.add_argument("--n", type=int, default=None, help="train rows")
    ap.add_argument("--d", type=int, default=None, help="feature dim")
    ap.add_argument("--q", type=int, default=DEFAULT_Q, help="query rows")
    ap.add_argument("--accuracy", type=float, default=DEFAULT_ACCURACY,
                    help="relative accuracy target")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas", "ring"))
    ap.add_argument("--stream", action="store_true",
                    help="plan for a streaming estimator")
    args = ap.parse_args(argv)

    if args.regen_golden:
        path, count = write_golden(args.golden, bench_paths=args.bench)
        print(f"wrote {count} golden plans to {path}")
        return 0

    if args.n is None or args.d is None:
        ap.error("either --regen-golden or both --n and --d are required")

    p = plan_for(args.n, args.d, q=args.q, accuracy=args.accuracy,
                 backend=args.backend, stream=args.stream)
    doc = {"request": p.request.as_dict(), "plan": p.as_dict(),
           "plan_id": p.plan_id}
    json.dump(doc, sys.stdout, indent=2)
    print()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
