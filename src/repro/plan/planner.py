"""Cost-model-driven execution planner.

Before this module, the execution shape of a query — precision tier, prune
mode, launch tiles, backend, streaming staleness policy — was scattered
across per-call knobs in ``kernels/ops.py``, ``ServeConfig`` fields, and
CLI flags, with the autotuner's cost model consulted only for tile shapes.
The planner pulls every one of those choices behind a single deterministic
decision function:

    plan(PlanRequest(n, d, q, accuracy, backend, stream)) -> ExecutionPlan

Decision inputs (all deterministic — the planner never times hardware):

  * the modeled pass costs in ``kernels/tuning.py`` / ``kernels/autotune.py``
    (padding-aware, precision-derated, occupancy-scaled);
  * the *measured* cells of the committed benchmark artifacts
    (``BENCH_flash.json`` + ``benchmarks/BENCH_baseline.json``), wrapped by
    :class:`BenchModel` — measured prune occupancies and measured pruning
    error are what license an epsilon > 0 tier for a shape regime;
  * the documented accuracy bars of the precision tiers
    (``kernels/precision.py`` / the serve verify harness).

Decision rules (each one pinned by the golden-decision suite in
``tests/test_planner.py``):

  tier      — cheapest tier whose documented rtol meets the accuracy
              target (f32 is always admissible as the reference tier);
              ties break toward the MORE accurate tier.
  prune     — "off" below the ``ops.PRUNE_AUTO_MIN_COLS`` threshold;
              exact (epsilon=0, certified-underflow-only — bitwise the
              dense answer up to summation order) otherwise; promoted to
              the largest measured epsilon satisfying
              ``epsilon * EPS_SAFETY <= accuracy`` AND whose measured
              pruning error for this shape regime is within the target.
              Unmeasured regimes never get an epsilon > 0.
  blocks    — best modeled launch tile at the chosen tier and occupancy
              (``autotune.shortlist`` with the widest-tier VMEM gate, so
              per-request precision overrides stay feasible).
  backend   — "pallas" once the train set is large enough for the kernel
              path to win (``PALLAS_MIN_COLS``); "jnp" below; "ring" only
              ever by explicit request (multi-host is an deployment
              decision, not a per-query one).
  staleness — streaming only: the tighter the accuracy target, the fewer
              generations a served query may lag live (0 at f32-grade
              targets); background snapshot builds engage only when a
              nonzero budget makes them useful.

The modeled cost attached to the plan is the backend-agnostic pairwise
pass cost — one comparable currency across every decision, monotone in the
train count (property-tested).
"""

from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro import obs
from repro.kernels import autotune
from repro.kernels import precision as prec

# Documented per-tier relative accuracy of a served density (the serve
# verify bars: rtol of the tier vs the f32 reference path).
TIER_RTOL: Dict[str, float] = {"f32": 1e-5, "bf16x2": 5e-4, "bf16": 5e-2}

#: Tier preference order on cost ties: more accurate first.
TIER_ORDER: Tuple[str, ...] = ("f32", "bf16x2", "bf16")

#: Safety margin between a per-point prune epsilon and the accuracy
#: target: the certificate bounds the *unnormalized accumulator* error at
#: n·epsilon worst case, so the planner only spends epsilon two orders of
#: magnitude below the requested relative tolerance.
EPS_SAFETY = 100.0

#: Default accuracy target (matches the serve default: f32-grade answers).
DEFAULT_ACCURACY = 1e-5

#: Train count past which the planner routes to the Pallas kernel path
#: ("auto" backend); below it, jit dispatch overhead dominates and the
#: streaming-GEMM jnp reference is the cheaper executable.
PALLAS_MIN_COLS = 2048

#: Default per-dispatch query rows when the caller doesn't know the
#: traffic shape (the serve default max_batch).
DEFAULT_Q = 4096

_BACKENDS = ("jnp", "pallas", "ring")


def _bucket(x: int) -> int:
    return 1 << max(int(math.ceil(math.log2(max(int(x), 1)))), 0)


# ---------------------------------------------------------------------------
# Measured-cell model.
# ---------------------------------------------------------------------------


class BenchModel:
    """A read-only view of the committed benchmark cells the planner may
    consult: measured prune occupancies and measured pruning error per
    (shape-bucket, d, epsilon) regime.

    Deterministic by construction — it only ever reads the *committed*
    artifacts, never live autotuner EMA state, so the same repo state
    always plans the same way (the property the golden suite pins).
    """

    def __init__(self, docs: Sequence[dict] = ()):
        self._prune_cells: List[dict] = []
        self._rff_cells: List[dict] = []
        for doc in docs:
            for cell in (doc or {}).get("cells", ()):
                if not isinstance(cell, dict):
                    continue
                if cell.get("cell") == "pruning" and "epsilon" in cell:
                    self._prune_cells.append(cell)
                if cell.get("cell") == "rff_cascade" \
                        and "rff_hit_frac" in cell:
                    self._rff_cells.append(cell)

    @classmethod
    def load(cls, paths: Optional[Sequence[Union[str, Path]]] = None
             ) -> "BenchModel":
        """Load from the committed artifacts (missing files are skipped)."""
        if paths is None:
            paths = default_bench_paths()
        docs = []
        for p in paths:
            p = Path(p)
            if p.exists():
                with open(p) as f:
                    docs.append(json.load(f))
        return cls(docs)

    # -- lookups ---------------------------------------------------------

    def _regime_cells(self, n: int, d: int) -> List[dict]:
        nb = _bucket(n)
        return [c for c in self._prune_cells
                if _bucket(int(c.get("n", 0))) == nb
                and int(c.get("d", -1)) == int(d)]

    def measured_epsilons(self, n: int, d: int) -> List[float]:
        """Measured prune epsilons for this shape regime, ascending."""
        return sorted({float(c["epsilon"]) for c in self._regime_cells(n, d)
                       if float(c["epsilon"]) > 0.0})

    def occupancy_record(self, n: int, d: int, epsilon: float
                         ) -> Optional[Tuple[int, float]]:
        """(block_n, occupancy) measured for (regime, epsilon), or None."""
        for c in self._regime_cells(n, d):
            if float(c["epsilon"]) == float(epsilon) \
                    and "occupancy" in c and "block_n" in c:
                return int(c["block_n"]), float(c["occupancy"])
        return None

    def occupancy_fn(self, n: int, d: int, epsilon: float
                     ) -> Optional[Callable[[int], float]]:
        """Tile-width → expected occupancy from a measured record.

        Same extrapolation as ``autotune.expected_occupancy``: the keep
        fraction grows ~linearly with tile span (a tile wider than a
        cluster can't be skipped), capped at a dense pass.  None when the
        regime has no measurement.
        """
        rec = self.occupancy_record(n, d, epsilon)
        if rec is None:
            return None
        ref_bn, ref_occ = rec
        return lambda bn: min(1.0, ref_occ * bn / ref_bn)

    def measured_rel_err(self, n: int, d: int, epsilon: float
                         ) -> Optional[float]:
        """Measured pruning relative error for (regime, epsilon)."""
        for c in self._regime_cells(n, d):
            if float(c["epsilon"]) == float(epsilon) \
                    and "prune_rel_err" in c:
                return float(c["prune_rel_err"])
        return None

    def measured_rff_hit(self, n: int, d: int,
                         accuracy: float) -> Optional[float]:
        """Measured RFF-tier hit fraction for this regime and target.

        Only cells measured at an accuracy target at least as *tight* as
        the request's are admissible (a looser target can only raise the
        hit fraction, so the measurement is a safe lower bound); returns
        the best such fraction, or None when the regime is unmeasured —
        and an unmeasured regime never engages the fast tier in a plan,
        mirroring the prune-epsilon rule.
        """
        nb = _bucket(n)
        best = None
        for c in self._rff_cells:
            if _bucket(int(c.get("n", 0))) != nb \
                    or int(c.get("d", -1)) != int(d):
                continue
            if float(c.get("accuracy_target", float("inf"))) > accuracy:
                continue
            frac = float(c["rff_hit_frac"])
            if best is None or frac > best:
                best = frac
        return best


def default_bench_paths() -> List[Path]:
    """The committed benchmark artifacts, repo-root-relative."""
    root = Path(__file__).resolve().parents[3]
    return [root / "BENCH_flash.json",
            root / "benchmarks" / "BENCH_baseline.json"]


# ---------------------------------------------------------------------------
# Request / plan schema.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """What the planner needs to know about a workload: shape bucket,
    accuracy target, backend constraint, and whether the dataset streams."""

    n: int                          # train points
    d: int                          # dimension
    q: int = DEFAULT_Q              # query rows per dispatch (bucket top)
    accuracy: float = DEFAULT_ACCURACY   # target max relative error
    backend: str = "auto"           # "auto" | "jnp" | "pallas" | "ring"
    stream: bool = False
    # Whether the workload is *eligible* for the RFF fast tier + accuracy
    # cascade (serve/cascade.py): the estimator method supports it and the
    # config hasn't disabled it.  Eligibility is not engagement — the
    # planner still demands a measured ``rff_cascade`` cell and a modeled
    # expected-cost win before a plan routes through the cascade.
    rff: bool = False

    def __post_init__(self):
        if self.n < 1 or self.d < 1 or self.q < 1:
            raise ValueError(f"bad plan shape n={self.n} d={self.d} "
                             f"q={self.q} (all must be >= 1)")
        if not (self.accuracy > 0.0):
            raise ValueError(f"accuracy target must be > 0, "
                             f"got {self.accuracy}")
        if self.backend not in _BACKENDS + ("auto",):
            raise ValueError(f"bad backend {self.backend!r}")

    def as_dict(self) -> dict:
        out = {"n": self.n, "d": self.d, "q": self.q,
               "accuracy": self.accuracy, "backend": self.backend,
               "stream": self.stream}
        if self.rff:                 # keep pre-cascade golden keys stable
            out["rff"] = True
        return out


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One validated execution shape: every knob the serve path threads.

    ``prune`` is ``"off"`` or a per-point epsilon float (0.0 = exact
    certified-underflow pruning — dense up to summation order).
    ``block_m``/``block_n`` are resolved launch tiles on the pallas
    backend, None elsewhere.  ``modeled_cost_s`` is the backend-agnostic
    modeled pairwise-pass time the decision was priced at.
    """

    request: PlanRequest
    backend: str
    precision: str
    prune: Union[str, float]
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    staleness_budget: int = 0
    stream_background: bool = False
    prewarm: bool = True
    modeled_cost_s: float = 0.0
    bound: str = ""                 # which resource the model says saturates
    occupancy: float = 1.0          # expected visit fraction priced in
    # Route through the RFF fast tier with cascade escalation to the exact
    # plan above.  When True, ``precision``/``prune``/blocks describe the
    # *escalation* tier and ``rff_hit_frac``/``modeled_rff_cost_s`` carry
    # the measured hit fraction and modeled feature-GEMM cost the
    # expected-cost decision was priced at.
    rff: bool = False
    rff_hit_frac: float = 0.0
    modeled_rff_cost_s: float = 0.0

    @property
    def plan_id(self) -> str:
        """Short stable id for spans/log lines."""
        blocks = (f"{self.block_m}x{self.block_n}"
                  if self.block_m is not None else "-")
        pr = self.prune if isinstance(self.prune, str) else f"{self.prune:g}"
        base = f"{self.backend}/{self.precision}/prune={pr}/{blocks}"
        return f"rff+{base}" if self.rff else base

    def as_dict(self) -> dict:
        """The golden-pinned decision record (JSON-stable field order)."""
        out = {
            "backend": self.backend,
            "precision": self.precision,
            "prune": self.prune,
            "block_m": self.block_m,
            "block_n": self.block_n,
            "staleness_budget": self.staleness_budget,
            "stream_background": self.stream_background,
            "modeled_cost_us": round(self.modeled_cost_s * 1e6, 3),
            "bound": self.bound,
            "occupancy": round(self.occupancy, 4),
        }
        if self.rff:                 # keep pre-cascade golden plans stable
            out["rff"] = True
            out["rff_hit_frac"] = round(self.rff_hit_frac, 4)
            out["modeled_rff_cost_us"] = round(
                self.modeled_rff_cost_s * 1e6, 3)
        return out

    # -- validity --------------------------------------------------------

    def validate(self) -> List[str]:
        """Every constraint a plan must satisfy to be launchable; returns
        the violations (empty list = valid).  The hypothesis suite asserts
        this is empty over randomized requests."""
        problems: List[str] = []
        req = self.request
        if self.backend not in _BACKENDS:
            problems.append(f"bad backend {self.backend!r}")
        try:
            prec.validate(self.precision)
        except ValueError as e:
            problems.append(str(e))
        if TIER_RTOL.get(self.precision, 0.0) > req.accuracy \
                and self.precision != "f32":
            problems.append(
                f"tier {self.precision} rtol "
                f"{TIER_RTOL.get(self.precision)} exceeds accuracy "
                f"target {req.accuracy}")
        if self.backend == "pallas":
            if not (isinstance(self.block_m, int) and self.block_m > 0
                    and isinstance(self.block_n, int) and self.block_n > 0):
                problems.append(
                    f"pallas plan needs int blocks, got "
                    f"{self.block_m}x{self.block_n}")
            else:
                if self.block_m % 8:
                    problems.append(
                        f"block_m {self.block_m} not a sublane multiple of 8")
                if self.block_n % 128:
                    problems.append(
                        f"block_n {self.block_n} not a lane multiple of 128")
                from repro.kernels import ops

                # the widest-tier gate (itemsize 4): serving reuses one
                # tile across per-request precision overrides
                try:
                    ops._check_vmem(self.block_m, self.block_n, req.d,
                                    itemsize=4, out_width=1)
                except ValueError as e:
                    problems.append(str(e))
        else:
            if self.prune != "off":
                problems.append(
                    f"prune={self.prune!r} needs the pallas backend, "
                    f"plan says {self.backend}")
        if not isinstance(self.prune, str):
            eps = float(self.prune)
            if eps < 0.0:
                problems.append(f"prune epsilon {eps} < 0")
            elif eps > 0.0 and eps * EPS_SAFETY > req.accuracy:
                problems.append(
                    f"prune epsilon {eps:g} spends more than "
                    f"accuracy/{EPS_SAFETY:g} of the {req.accuracy:g} target")
        elif self.prune != "off":
            problems.append(f"bad prune {self.prune!r}")
        if self.rff:
            if not req.rff:
                problems.append(
                    "rff routing planned for a request that is not "
                    "cascade-eligible")
            if not (0.0 < self.rff_hit_frac <= 1.0):
                problems.append(
                    f"rff plan without a measured hit fraction "
                    f"({self.rff_hit_frac})")
            if not (self.modeled_rff_cost_s > 0.0):
                problems.append(
                    f"rff plan with non-positive modeled feature-GEMM "
                    f"cost {self.modeled_rff_cost_s}")
        if self.staleness_budget < 0:
            problems.append("staleness_budget < 0")
        if not req.stream and self.staleness_budget != 0:
            problems.append("non-streaming plan carries a staleness budget")
        if not (0.0 < self.occupancy <= 1.0):
            problems.append(f"occupancy {self.occupancy} outside (0, 1]")
        if not (self.modeled_cost_s >= 0.0):
            problems.append(f"bad modeled cost {self.modeled_cost_s}")
        return problems

    def check(self) -> "ExecutionPlan":
        problems = self.validate()
        if problems:
            raise ValueError("invalid execution plan: " + "; ".join(problems))
        return self


# ---------------------------------------------------------------------------
# The decision function.
# ---------------------------------------------------------------------------


def _admissible_tiers(accuracy: float) -> List[str]:
    tiers = [t for t in TIER_ORDER if TIER_RTOL[t] <= accuracy]
    return tiers or ["f32"]          # f32 is the reference: always allowed


def _prune_decision(req: PlanRequest, bench: BenchModel
                    ) -> Tuple[Union[str, float],
                               Optional[Callable[[int], float]]]:
    """(prune mode, occupancy_fn) for the request.

    Mirrors ``ops.resolve_prune``'s size gate, then promotes the epsilon
    using measured evidence only.
    """
    from repro.kernels import ops

    if req.n < ops.PRUNE_AUTO_MIN_COLS:
        return "off", None
    eps = 0.0
    for cand in bench.measured_epsilons(req.n, req.d):
        if cand * EPS_SAFETY > req.accuracy:
            continue
        measured = bench.measured_rel_err(req.n, req.d, cand)
        if measured is not None and measured <= req.accuracy:
            eps = max(eps, cand)
    return eps, bench.occupancy_fn(req.n, req.d, eps)


def _staleness_policy(req: PlanRequest) -> Tuple[int, bool]:
    if not req.stream:
        return 0, False
    if req.accuracy <= 1e-5:
        budget = 0
    elif req.accuracy <= 5e-4:
        budget = 1
    else:
        budget = 2
    return budget, budget > 0


def _best_candidate(req: PlanRequest, tier: str,
                    occupancy_fn: Optional[Callable[[int], float]]
                    ) -> Optional[autotune.TunedConfig]:
    """Best modeled launch config at one tier (pure model, no timing)."""
    cands = autotune.shortlist(
        req.q, req.n, req.d, out_width=1, precision=tier,
        vmem_itemsize=4,
        occupancy_fn=occupancy_fn,
    )
    return cands[0] if cands else None


def plan(req: PlanRequest, bench: Optional[BenchModel] = None
         ) -> ExecutionPlan:
    """The planner entry point: one validated ExecutionPlan per request.

    Deterministic in (request, committed benchmark artifacts) — golden-
    pinned in ``tests/test_planner.py``, regenerated deliberately via
    ``python -m repro.plan --regen-golden``.
    """
    if bench is None:
        bench = BenchModel.load()

    with obs.span("plan.decide", n=req.n, d=req.d, q=req.q,
                  accuracy=req.accuracy, backend=req.backend,
                  stream=req.stream) as sp:
        backend = req.backend
        if backend == "auto":
            backend = "pallas" if req.n >= PALLAS_MIN_COLS else "jnp"

        prune: Union[str, float] = "off"
        occ_fn: Optional[Callable[[int], float]] = None
        if backend == "pallas":
            prune, occ_fn = _prune_decision(req, bench)

        # Tier choice: cheapest admissible tier by modeled cost; ties
        # break toward the more accurate tier (TIER_ORDER).  The jnp/ring
        # paths compute in f32 end to end, so only pallas routes tiers.
        tiers = _admissible_tiers(req.accuracy) if backend == "pallas" \
            else ["f32"]
        best_tier, best_cand = None, None
        for tier in tiers:
            cand = _best_candidate(req, tier, occ_fn)
            if cand is None:
                continue
            if best_cand is None or cand.step_time < best_cand.step_time:
                best_tier, best_cand = tier, cand
        if best_cand is None:
            # No feasible pruned-occupancy candidate (can't happen today —
            # small tiles always fit — but stay total): fall back dense.
            prune, occ_fn = "off", None
            for tier in tiers:
                cand = _best_candidate(req, tier, None)
                if cand is not None and (
                        best_cand is None
                        or cand.step_time < best_cand.step_time):
                    best_tier, best_cand = tier, cand
        if best_cand is None:
            raise ValueError(
                f"no feasible launch config for plan request {req}")

        # Pruning must pay for itself: compare against the dense pass at
        # the chosen tier and keep the cheaper (ties keep the certified
        # pruned pass — it never costs accuracy at epsilon admissibility).
        occupancy = 1.0
        if prune != "off":
            dense = _best_candidate(req, best_tier, None)
            if dense is not None and dense.step_time < best_cand.step_time:
                prune, best_cand, occ_fn = "off", dense, None
            else:
                occupancy = (occ_fn(best_cand.block_n)
                             if occ_fn is not None else 1.0)

        # RFF fast tier: engage only when the request is cascade-eligible,
        # a measured rff_cascade cell covers this (regime, accuracy), and
        # the *expected* cascade cost — every row pays the feature GEMM,
        # escalated rows additionally pay the exact pass — beats the
        # all-exact pass.  That reduces to rff_cost < hit_frac · exact.
        rff_on, rff_hit, rff_cost = False, 0.0, 0.0
        if req.rff:
            hit = bench.measured_rff_hit(req.n, req.d, req.accuracy)
            if hit is not None and hit > 0.0:
                from repro.kernels import flash_rff

                rff_cost = flash_rff.modeled_query_cost_us(
                    req.q, req.d) / 1e6
                if rff_cost < hit * best_cand.step_time:
                    rff_on, rff_hit = True, hit

        staleness, background = _staleness_policy(req)
        p = ExecutionPlan(
            request=req,
            backend=backend,
            precision=best_tier,
            prune=prune,
            block_m=best_cand.block_m if backend == "pallas" else None,
            block_n=best_cand.block_n if backend == "pallas" else None,
            staleness_budget=staleness,
            stream_background=background,
            prewarm=True,
            modeled_cost_s=best_cand.step_time,
            bound=best_cand.bound,
            occupancy=occupancy,
            rff=rff_on,
            rff_hit_frac=rff_hit,
            modeled_rff_cost_s=rff_cost,
        ).check()
        sp.set(plan=p.plan_id, tier=p.precision,
               modeled_us=round(p.modeled_cost_s * 1e6, 2))
        obs.counter(
            "plan.decisions", "planner decisions",
            labels={"backend": p.backend, "tier": p.precision,
                    "prune": "off" if p.prune == "off" else "eps"},
        ).inc()
        obs.histogram("plan.modeled_s", "modeled cost of planned passes (s)",
                      lo=1e-9, hi=1e3).observe(p.modeled_cost_s)
    return p


def plan_for(n: int, d: int, q: int = DEFAULT_Q,
             accuracy: float = DEFAULT_ACCURACY, backend: str = "auto",
             stream: bool = False,
             bench: Optional[BenchModel] = None) -> ExecutionPlan:
    """Convenience wrapper over :func:`plan`."""
    return plan(PlanRequest(n=n, d=d, q=q, accuracy=accuracy,
                            backend=backend, stream=stream), bench=bench)


# ---------------------------------------------------------------------------
# Serve-config resolution (override precedence).
# ---------------------------------------------------------------------------


def _explicit_fields(cfg) -> set:
    """Config fields the user set away from their dataclass defaults.

    This is the documented override precedence: an explicitly-set knob
    (value != the field default) beats the plan; the plan beats the
    built-in default.  Setting a knob *to* its default value reads as
    "unset" — pass ``plan="off"`` to pin every knob by hand.
    """
    out = set()
    for f in dataclasses.fields(cfg):
        if f.default is not dataclasses.MISSING \
                and getattr(cfg, f.name) != f.default:
            out.add(f.name)
    return out


def resolve_config(cfg, n: int, d: int,
                   bench: Optional[BenchModel] = None):
    """Resolve a ``ServeConfig(plan="auto")`` into concrete knobs.

    Returns ``(resolved_config, ExecutionPlan)``.  Only knobs still at
    their dataclass defaults are overwritten by the plan; the request's
    accuracy target comes from ``cfg.accuracy_target`` (default
    f32-grade).  Works on any dataclass with the ServeConfig knob names —
    the serve layer is not imported here.
    """
    explicit = _explicit_fields(cfg)
    req = PlanRequest(
        n=n, d=d, q=cfg.max_batch,
        accuracy=getattr(cfg, "accuracy_target", None) or DEFAULT_ACCURACY,
        backend=cfg.backend if "backend" in explicit else "auto",
        stream=bool(getattr(cfg, "stream", False)),
        rff=(getattr(cfg, "rff", "off") != "off"
             and getattr(cfg, "method", "sdkde") in ("kde", "sdkde")),
    )
    p = plan(req, bench=bench)
    updates = {}

    def take(name, value):
        if name not in explicit:
            updates[name] = value

    take("backend", p.backend)
    take("prune", p.prune)      # "off" on non-pallas backends
    if p.backend == "pallas":
        take("precision", p.precision)
        if p.block_m is not None:
            take("block_m", p.block_m)
            take("block_n", p.block_n)
    if p.rff:
        # the plan says the cascade pays for itself for this traffic —
        # fit the RFF state eagerly with the debias pass instead of on
        # the first cascade-routed request
        take("rff", "on")
    if req.stream:
        take("staleness_budget", p.staleness_budget)
        take("stream_background", p.stream_background)
    resolved = dataclasses.replace(cfg, **updates)
    obs.counter("plan.config_resolves",
                "ServeConfigs resolved through the planner").inc()
    return resolved, p


__all__ = [
    "TIER_RTOL", "TIER_ORDER", "EPS_SAFETY", "DEFAULT_ACCURACY",
    "PALLAS_MIN_COLS", "DEFAULT_Q",
    "BenchModel", "default_bench_paths",
    "PlanRequest", "ExecutionPlan",
    "plan", "plan_for", "resolve_config",
]
