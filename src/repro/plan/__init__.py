"""Cost-model-driven execution planning.

``repro.plan`` turns a (shape, accuracy target, backend hint) request into
one validated :class:`ExecutionPlan` — precision tier, prune mode, tile
blocks, backend, and stream-staleness policy — using the autotuner's
modeled costs plus the committed benchmark cells. See
``docs/architecture.md`` ("Execution planning") for the decision rules and
override precedence.
"""

from repro.plan.golden import (
    default_golden_path,
    golden_entries,
    load_docs,
    load_golden,
    request_for_cell,
    request_key,
    requests_from_docs,
    write_golden,
)
from repro.plan.planner import (
    DEFAULT_ACCURACY,
    DEFAULT_Q,
    EPS_SAFETY,
    PALLAS_MIN_COLS,
    TIER_ORDER,
    TIER_RTOL,
    BenchModel,
    ExecutionPlan,
    PlanRequest,
    default_bench_paths,
    plan,
    plan_for,
    resolve_config,
)

__all__ = [
    "DEFAULT_ACCURACY",
    "DEFAULT_Q",
    "EPS_SAFETY",
    "PALLAS_MIN_COLS",
    "TIER_ORDER",
    "TIER_RTOL",
    "BenchModel",
    "ExecutionPlan",
    "PlanRequest",
    "default_bench_paths",
    "default_golden_path",
    "golden_entries",
    "load_docs",
    "load_golden",
    "plan",
    "plan_for",
    "request_for_cell",
    "request_key",
    "requests_from_docs",
    "resolve_config",
    "write_golden",
]
