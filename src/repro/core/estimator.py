"""High-level estimator API: KDE / SDKDE / LaplaceKDE with backend dispatch.

Backends:
  * ``jnp``    — streaming GEMM-form pure JAX (works everywhere, any scale).
  * ``pallas`` — the Flash kernels (``repro.kernels``): explicit VMEM tiling,
                 MXU GEMMs, sequential-grid streaming accumulation.  On CPU
                 they run in interpret mode (validation); on TPU, compiled.
  * ``ring``   — multi-device ring-sharded execution (``repro.distributed``).

This is the "paper's contribution as a composable JAX module": estimators are
pytrees of arrays + static config, usable under jit/vmap/shard_map.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandwidth as bw
from repro.core import kde as ref

Backend = Literal["jnp", "pallas", "ring"]


@dataclasses.dataclass
class EstimatorConfig:
    backend: Backend = "jnp"
    block: int = 1024            # streaming column-block size (jnp backend)
    block_m: "int | str" = 128   # Pallas row tile (int or "auto" = autotuned)
    block_n: "int | str" = 512   # Pallas column tile (int or "auto")
    interpret: bool = True       # Pallas interpret mode (CPU validation)
    score_h: Optional[float] = None  # score-estimation bandwidth (None = h)
    dtype: jnp.dtype = jnp.float32
    precision: str = "f32"       # Pallas GEMM-operand tier (kernels/precision)
    prune: "str | float" = "auto"  # cluster pruning (kernels/spatial):
    #                              # "auto" | "off" | certified epsilon >= 0


class KDE:
    """Classical Gaussian KDE."""

    def __init__(self, h=None, config: EstimatorConfig | None = None):
        self.h = h
        self.config = config or EstimatorConfig()
        self.x_train: jnp.ndarray | None = None

    def fit(self, x: jnp.ndarray) -> "KDE":
        self.x_train = jnp.asarray(x, self.config.dtype)
        if self.h is None:
            self.h = bw.silverman_bandwidth(self.x_train)
        return self

    def _train_points(self) -> jnp.ndarray:
        assert self.x_train is not None, "call fit() first"
        return self.x_train

    def evaluate(self, y: jnp.ndarray) -> jnp.ndarray:
        x = self._train_points()
        y = jnp.asarray(y, self.config.dtype)
        cfg = self.config
        if cfg.backend == "pallas":
            from repro.kernels import ops

            return ops.flash_kde(
                x, y, self.h, precision=cfg.precision,
                block_m=cfg.block_m, block_n=cfg.block_n,
                interpret=cfg.interpret, prune=cfg.prune,
            )
        if cfg.backend == "ring":
            from repro.distributed import ring

            return ring.ring_kde(x, y, self.h)
        return ref.kde_eval(x, y, self.h, block=cfg.block)

    __call__ = evaluate


class SDKDE(KDE):
    """Score-debiased KDE: empirical-score shift + KDE on debiased samples.

    ``fit`` performs the quadratic score pass (the paper's hot spot) and
    caches the debiased samples; ``evaluate`` is then a standard KDE pass.

    ``append``/``evict`` update a fitted estimator *incrementally* — the
    O(n·b·d) delta score pass of ``repro.stream.delta`` instead of a fresh
    O(n²·d) fit.  The first incremental call pays one full pass to seed
    float64 score statistics; every later update is a delta against them,
    and the debiased samples are recomputed from the maintained statistics
    (matching a from-scratch refit to float tolerance).  The bandwidth
    stays the fit-time one — streaming updates change the data, not ``h``.
    """

    def __init__(self, h=None, config: EstimatorConfig | None = None):
        super().__init__(h, config)
        self.x_sd: jnp.ndarray | None = None
        self._s0 = self._s1 = None       # f64 score stats (lazy, streaming)

    def fit(self, x: jnp.ndarray) -> "SDKDE":
        self.x_train = jnp.asarray(x, self.config.dtype)
        self._s0 = self._s1 = None       # a refit invalidates seeded stats
        if self.h is None:
            self.h = bw.sdkde_bandwidth(self.x_train)
        cfg = self.config
        if cfg.backend == "pallas":
            from repro.kernels import ops

            self.x_sd = ops.flash_sdkde_shift(
                self.x_train, self.h, score_h=cfg.score_h,
                precision=cfg.precision,
                block_m=cfg.block_m, block_n=cfg.block_n,
                interpret=cfg.interpret, prune=cfg.prune,
            )
        elif cfg.backend == "ring":
            from repro.distributed import ring

            self.x_sd = ring.ring_sdkde_shift(
                self.x_train, self.h, score_h=cfg.score_h
            )
        else:
            self.x_sd = ref.sdkde_shift(
                self.x_train, self.h, score_h=cfg.score_h, block=cfg.block
            )
        return self

    def _train_points(self) -> jnp.ndarray:
        assert self.x_sd is not None, "call fit() first"
        return self.x_sd

    # -- incremental updates (repro.stream.delta) ------------------------

    def _score_h(self) -> float:
        sh = self.config.score_h
        return float(self.h if sh is None else sh)

    def _seed_stats(self, x_live):
        from repro.stream import delta

        if self._s0 is None:
            self._s0, self._s1 = delta.initial_stats(x_live, self._score_h())

    def _refresh_shift(self) -> None:
        from repro.stream import delta

        x_live = np.asarray(self.x_train, np.float32)
        self.x_sd = jnp.asarray(
            delta.apply_shift(
                x_live, self._s0, self._s1, float(self.h), self._score_h()
            ).astype(np.float32)
        )

    def append(self, x_new) -> "SDKDE":
        """Fold new points into a fitted estimator without a refit."""
        from repro.stream import delta

        assert self.x_sd is not None, "call fit() first"
        x_new = np.atleast_2d(np.asarray(x_new, np.float32))
        x_live = np.asarray(self.x_train, np.float32)
        self._seed_stats(x_live)
        ds0, ds1, s0n, s1n = delta.append_delta(
            x_live, x_new, self._score_h()
        )
        self._s0 = np.concatenate([self._s0 + ds0, s0n])
        self._s1 = np.concatenate([self._s1 + ds1, s1n])
        self.x_train = jnp.concatenate(
            [self.x_train, jnp.asarray(x_new, self.config.dtype)]
        )
        self._refresh_shift()
        return self

    def evict(self, idx) -> "SDKDE":
        """Remove train rows (by position) without a refit."""
        from repro.stream import delta

        assert self.x_sd is not None, "call fit() first"
        x_live = np.asarray(self.x_train, np.float32)
        out = np.zeros(x_live.shape[0], bool)
        out[np.atleast_1d(np.asarray(idx, np.int64))] = True
        if out.all():
            raise ValueError("cannot evict every train point")
        self._seed_stats(x_live)
        ds0, ds1 = delta.evict_delta(
            x_live[~out], x_live[out], self._score_h()
        )
        self._s0 = self._s0[~out] - ds0
        self._s1 = self._s1[~out] - ds1
        self.x_train = self.x_train[jnp.asarray(~out)]
        self._refresh_shift()
        return self


class LaplaceKDE(KDE):
    """Laplace-corrected KDE (Flash-Laplace-KDE when fused)."""

    def __init__(self, h=None, config: EstimatorConfig | None = None,
                 fused: bool = True):
        super().__init__(h, config)
        self.fused = fused

    def evaluate(self, y: jnp.ndarray) -> jnp.ndarray:
        x = self._train_points()
        y = jnp.asarray(y, self.config.dtype)
        cfg = self.config
        if cfg.backend == "pallas":
            from repro.kernels import ops

            if self.fused:
                return ops.flash_laplace_kde(
                    x, y, self.h, precision=cfg.precision,
                    block_m=cfg.block_m, block_n=cfg.block_n,
                    interpret=cfg.interpret, prune=cfg.prune,
                )
            return ops.laplace_kde_nonfused(
                x, y, self.h, precision=cfg.precision,
                block_m=cfg.block_m, block_n=cfg.block_n,
                interpret=cfg.interpret,
            )
        if cfg.backend == "ring":
            from repro.distributed import ring

            return ring.ring_laplace_kde(x, y, self.h)
        if self.fused:
            return ref.laplace_kde_eval(x, y, self.h, block=cfg.block)
        return ref.laplace_kde_eval_nonfused(x, y, self.h, block=cfg.block)

    __call__ = evaluate
