"""Pure-JAX GEMM-form KDE / SD-KDE / Laplace-KDE (the reference path).

This module is the paper's computation expressed with `jnp` matmuls and a
streaming (chunked) accumulation so that the n×n pairwise matrices are never
materialized — the same re-ordering that enables Tensor Cores / the TPU MXU,
but at the XLA level.  The Pallas kernels in ``repro.kernels`` implement the
same math with explicit VMEM tiling; ``repro.distributed.ring`` shards it
over a device mesh.  All three paths agree to float tolerance (tested).

Math (Gaussian kernel, bandwidth h):

  p̂(y)    = 1/(n (2π)^{d/2} h^d) · Σ_i exp(-‖y-x_i‖²/(2h²))
  ŝ(x)    = Σ_j -(x-x_j)·φ_j(x) / (h² Σ_j φ_j(x))      [empirical score]
          = (S1(x) - x·S0(x)) / (h² S0(x)),   S0 = Σφ, S1 = Σφx_j
  x^SD    = x + (h²/2)·ŝ(x)
  K^LC(u) = K_h(u)·(1 + d/2 - ‖u‖²/(2h²))              [Laplace-corrected]

The GEMM structure: ‖x-y‖² = ‖x‖² + ‖y‖² - 2·x·y  (Gram matrix), and
S1 = Φ X (the score-numerator GEMM) — Section 4 of the paper.
"""

from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bandwidth import gaussian_norm_const

# Far-away coordinate used to pad point sets: exp(-‖pad - x‖²/(2h²)) == 0.0
# exactly in f32 for any realistic data scale, so padded points contribute
# nothing to any accumulated statistic.
PAD_VALUE = 1.0e6


def pad_rows(x: jnp.ndarray, block: int, value: float = PAD_VALUE) -> jnp.ndarray:
    """Pad the leading axis of ``x`` up to a multiple of ``block``."""
    n = x.shape[0]
    rem = (-n) % block
    if rem == 0:
        return x
    pad_width = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width, constant_values=value)


def sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """GEMM-form pairwise squared distances, shape (n, m).

    ‖x_i - y_j‖² = ‖x_i‖² + ‖y_j‖² - 2 x_i·y_j — the re-ordering that maps
    the quadratic interaction onto a matrix multiply.
    """
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    g = x @ y.T
    return jnp.maximum(xn + yn - 2.0 * g, 0.0)


def _phi(sq: jnp.ndarray, h) -> jnp.ndarray:
    return jnp.exp(-sq / (2.0 * h * h))


# ---------------------------------------------------------------------------
# Streaming accumulation over train-column blocks.
# ---------------------------------------------------------------------------


def _stream_blocks(x_train: jnp.ndarray, block: int, body, init):
    """Fold ``body(carry, x_block)`` over column blocks of the train set.

    ``x_train`` is padded (with PAD_VALUE sentinels) to a block multiple and
    reshaped to (num_blocks, block, d); ``lax.scan`` streams the blocks so
    peak memory is O(rows · block) rather than O(rows · n).
    """
    xp = pad_rows(x_train, block)
    nb = xp.shape[0] // block
    xb = xp.reshape(nb, block, x_train.shape[-1])

    def step(carry, xblk):
        return body(carry, xblk), None

    carry, _ = jax.lax.scan(step, init, xb)
    return carry


# ---------------------------------------------------------------------------
# KDE evaluation.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block",))
def kde_eval(
    x_train: jnp.ndarray,
    y_query: jnp.ndarray,
    h,
    *,
    block: int = 1024,
) -> jnp.ndarray:
    """Gaussian KDE densities at ``y_query`` — streaming GEMM form."""
    n, d = x_train.shape

    def body(acc, xblk):
        sq = sqdist(y_query, xblk)
        return acc + jnp.sum(_phi(sq, h), axis=1)

    s = _stream_blocks(x_train, block, body, jnp.zeros(y_query.shape[0]))
    return s / (n * gaussian_norm_const(d, 1.0) * h**d)


def kde_eval_naive(x_train: jnp.ndarray, y_query: jnp.ndarray, h) -> jnp.ndarray:
    """Naive O(n·m·d) elementwise KDE (no GEMM re-ordering) — the slow
    baseline used in the Fig. 1 runtime reproduction."""
    n, d = x_train.shape
    diff = y_query[:, None, :] - x_train[None, :, :]
    sq = jnp.sum(diff * diff, axis=-1)
    s = jnp.sum(_phi(sq, h), axis=1)
    return s / (n * gaussian_norm_const(d, 1.0) * h**d)


# ---------------------------------------------------------------------------
# Empirical score and SD-KDE shift.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block",))
def score_stats(
    x_eval: jnp.ndarray,
    x_train: jnp.ndarray,
    h,
    *,
    block: int = 1024,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming (S0, S1) = (Σ_j φ_ij, Σ_j φ_ij x_j) for rows ``x_eval``.

    This is the paper's score-numerator identity: instead of forming
    Σ_j (x_i - x_j) φ_ij elementwise, accumulate the GEMM T = Φ X and the
    row-sum S0, then combine as x_i·S0_i - S1_i.
    """
    m, d = x_eval.shape

    def body(carry, xblk):
        s0, s1 = carry
        sq = sqdist(x_eval, xblk)                       # (m, block) via GEMM
        phi = _phi(sq, h)
        s0 = s0 + jnp.sum(phi, axis=1)                  # Σ_j φ_ij
        s1 = s1 + phi @ xblk                            # Φ X   (MXU GEMM)
        return s0, s1

    init = (jnp.zeros(m), jnp.zeros((m, d)))
    return _stream_blocks(x_train, block, body, init)


def empirical_score(
    x_eval: jnp.ndarray,
    x_train: jnp.ndarray,
    h,
    *,
    block: int = 1024,
    eps: float = 1e-30,
) -> jnp.ndarray:
    """Empirical KDE score ŝ(x) = (S1 - x·S0) / (h² S0)."""
    s0, s1 = score_stats(x_eval, x_train, h, block=block)
    return (s1 - x_eval * s0[:, None]) / (h * h * s0[:, None] + eps)


def sdkde_shift(
    x_train: jnp.ndarray,
    h,
    *,
    score_h=None,
    block: int = 1024,
) -> jnp.ndarray:
    """Debiased samples x^SD = x + (h²/2)·ŝ(x).

    ``score_h`` is the bandwidth of the score-estimation KDE; the paper's
    Section-1 formula uses ``h`` (default), while the Section-5 semigroup
    analysis suggests ``h/sqrt(2)`` (``repro.core.bandwidth.score_bandwidth``).
    """
    sh = h if score_h is None else score_h
    s = empirical_score(x_train, x_train, sh, block=block)
    return x_train + 0.5 * h * h * s


def sdkde_eval(
    x_train: jnp.ndarray,
    y_query: jnp.ndarray,
    h,
    *,
    score_h=None,
    block: int = 1024,
) -> jnp.ndarray:
    """Full empirical SD-KDE: score pass + shift + KDE on debiased samples."""
    x_sd = sdkde_shift(x_train, h, score_h=score_h, block=block)
    return kde_eval(x_sd, y_query, h, block=block)


def sdkde_eval_oracle(
    x_train: jnp.ndarray,
    y_query: jnp.ndarray,
    h,
    oracle_score_fn,
    *,
    block: int = 1024,
) -> jnp.ndarray:
    """SD-KDE with an oracle score (ablation: removes score-estimation error)."""
    x_sd = x_train + 0.5 * h * h * oracle_score_fn(x_train)
    return kde_eval(x_sd, y_query, h, block=block)


# ---------------------------------------------------------------------------
# Laplace-corrected KDE (Section 5).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block",))
def laplace_kde_eval(
    x_train: jnp.ndarray,
    y_query: jnp.ndarray,
    h,
    *,
    block: int = 1024,
) -> jnp.ndarray:
    """Fused Laplace-corrected KDE (Flash-Laplace-KDE math).

    K^LC(u) = K_h(u)·(1 + d/2 - ‖u‖²/(2h²)); the affine factor is applied in
    the same streaming pass that computes the distances and exponentials.
    May be slightly negative for large ‖u‖ — by design (signed estimator).
    """
    n, d = x_train.shape
    c0 = 1.0 + d / 2.0

    def body(acc, xblk):
        sq = sqdist(y_query, xblk)
        phi = _phi(sq, h)
        return acc + jnp.sum(phi * (c0 - sq / (2.0 * h * h)), axis=1)

    s = _stream_blocks(x_train, block, body, jnp.zeros(y_query.shape[0]))
    return s / (n * gaussian_norm_const(d, 1.0) * h**d)


def laplace_kde_eval_nonfused(
    x_train: jnp.ndarray,
    y_query: jnp.ndarray,
    h,
    *,
    block: int = 1024,
) -> jnp.ndarray:
    """Non-fused Laplace correction: two separate quadratic passes.

    Pass 1 computes the plain KDE; pass 2 recomputes distances to form the
    Laplacian term Σ φ·‖u‖²/(2h²).  Statistically identical to the fused
    version (Fig. 2/3 overlap) but with ~2× the memory traffic and kernel
    launches — the baseline for the Fig. 4 fusion-speedup reproduction.
    """
    n, d = x_train.shape
    base = kde_eval(x_train, y_query, h, block=block)

    def body(acc, xblk):
        sq = sqdist(y_query, xblk)
        phi = _phi(sq, h)
        return acc + jnp.sum(phi * sq, axis=1)

    sq_term = _stream_blocks(x_train, block, body, jnp.zeros(y_query.shape[0]))
    sq_term = sq_term / (n * gaussian_norm_const(d, 1.0) * h**d)
    return base * (1.0 + d / 2.0) - sq_term / (2.0 * h * h)


__all__ = [
    "PAD_VALUE",
    "pad_rows",
    "sqdist",
    "kde_eval",
    "kde_eval_naive",
    "score_stats",
    "empirical_score",
    "sdkde_shift",
    "sdkde_eval",
    "sdkde_eval_oracle",
    "laplace_kde_eval",
    "laplace_kde_eval_nonfused",
]
