"""Oracle-error metrics: MISE, MIAE, negative-mass diagnostic.

The paper reports Mean Integrated Squared Error and Mean Integrated Absolute
Error against the known mixture density ("oracle error", Figs. 2-3), computed
on the *signed* estimator because the Laplace-corrected kernel can go
negative; the integrated negative mass is logged separately as a diagnostic.

In 1-D the integrals are computed on a uniform grid.  In 16-D a grid is
infeasible, so we use self-normalized importance sampling with a widened
version of the oracle mixture as the proposal:

    ∫ f(x) dx ≈ (1/m) Σ f(z_k)/q(z_k),   z_k ~ q.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.mixtures import GaussianMixture


@dataclasses.dataclass(frozen=True)
class OracleErrors:
    mise: float
    miae: float
    neg_mass: float


def widened_proposal(mix: GaussianMixture, widen: float = 1.5) -> GaussianMixture:
    """Proposal q = oracle mixture with stds widened (covers the tails)."""
    return GaussianMixture(
        means=mix.means, stds=mix.stds * widen, weights=mix.weights
    )


def oracle_errors_grid(
    estimate_fn: Callable[[jnp.ndarray], jnp.ndarray],
    mix: GaussianMixture,
    lo: float,
    hi: float,
    n_grid: int = 2048,
) -> OracleErrors:
    """1-D grid integration of (p̂-p)², |p̂-p| and max(-p̂, 0)."""
    assert mix.dim == 1
    grid = jnp.linspace(lo, hi, n_grid)[:, None]
    dx = (hi - lo) / (n_grid - 1)
    p_hat = estimate_fn(grid)
    p = mix.pdf(grid)
    err = p_hat - p
    return OracleErrors(
        mise=float(jnp.sum(err**2) * dx),
        miae=float(jnp.sum(jnp.abs(err)) * dx),
        neg_mass=float(jnp.sum(jnp.maximum(-p_hat, 0.0)) * dx),
    )


def oracle_errors_importance(
    estimate_fn: Callable[[jnp.ndarray], jnp.ndarray],
    mix: GaussianMixture,
    key: jax.Array,
    n_mc: int = 8192,
    widen: float = 1.5,
) -> OracleErrors:
    """High-dimensional oracle error via importance sampling."""
    q = widened_proposal(mix, widen)
    z = q.sample(key, n_mc)
    qz = q.pdf(z)
    p_hat = estimate_fn(z)
    p = mix.pdf(z)
    err = p_hat - p
    inv_q = 1.0 / jnp.maximum(qz, 1e-300)
    return OracleErrors(
        mise=float(jnp.mean(err**2 * inv_q)),
        miae=float(jnp.mean(jnp.abs(err) * inv_q)),
        neg_mass=float(jnp.mean(jnp.maximum(-p_hat, 0.0) * inv_q)),
    )


def oracle_errors(
    estimate_fn: Callable[[jnp.ndarray], jnp.ndarray],
    mix: GaussianMixture,
    key: jax.Array | None = None,
    **kw,
) -> OracleErrors:
    """Dispatch: grid in 1-D, importance sampling otherwise."""
    if mix.dim == 1:
        span = float(mix.stds.max()) * 6.0
        lo = float(mix.means.min()) - span
        hi = float(mix.means.max()) + span
        return oracle_errors_grid(estimate_fn, mix, lo, hi, **kw)
    if key is None:
        key = jax.random.PRNGKey(0)
    return oracle_errors_importance(estimate_fn, mix, key, **kw)
