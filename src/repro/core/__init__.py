"""Core SD-KDE library — the paper's contribution as composable JAX modules."""

from repro.core.bandwidth import (
    gaussian_norm_const,
    score_bandwidth,
    sdkde_bandwidth,
    silverman_bandwidth,
)
from repro.core.estimator import KDE, SDKDE, EstimatorConfig, LaplaceKDE
from repro.core.kde import (
    empirical_score,
    kde_eval,
    kde_eval_naive,
    laplace_kde_eval,
    laplace_kde_eval_nonfused,
    score_stats,
    sdkde_eval,
    sdkde_eval_oracle,
    sdkde_shift,
    sqdist,
)
from repro.core.metrics import OracleErrors, oracle_errors
from repro.core.mixtures import (
    GaussianMixture,
    benchmark_mixture_1d,
    benchmark_mixture_16d,
    mixture_for_dim,
)

__all__ = [
    "KDE", "SDKDE", "LaplaceKDE", "EstimatorConfig",
    "kde_eval", "kde_eval_naive", "sdkde_eval", "sdkde_eval_oracle",
    "sdkde_shift", "score_stats", "empirical_score", "sqdist",
    "laplace_kde_eval", "laplace_kde_eval_nonfused",
    "silverman_bandwidth", "sdkde_bandwidth", "score_bandwidth",
    "gaussian_norm_const",
    "GaussianMixture", "benchmark_mixture_16d", "benchmark_mixture_1d",
    "mixture_for_dim",
    "OracleErrors", "oracle_errors",
]
