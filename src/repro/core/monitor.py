"""Serving-time activation-density monitor (DESIGN.md §4.3).

The paper's estimator as an operations tool: fit Flash-SD-KDE over a
reference sample of pooled decoder activations (projected to a low
dimension), then score incoming requests' activations at serve time —
low density ⇒ out-of-distribution input (prompt injection, domain drift,
garbage encodings).  The score pass runs once offline; the per-request
cost is ONE streamed GEMM pass against the debiased reference set
(O(n_ref·d) per query — microseconds at serving batch sizes).

The projection is a fixed random Gaussian map (JL-style): architecture
agnostic, no training, distance-preserving enough for density ranking.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.estimator import EstimatorConfig, SDKDE


def pool_activations(hidden: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) hidden states -> (B, d) mean-pooled, f32."""
    return jnp.mean(hidden.astype(jnp.float32), axis=1)


@dataclasses.dataclass
class ActivationMonitor:
    """Streaming OOD scorer over (projected) activations.

    ``fit`` on a reference corpus of pooled activations; ``score`` returns
    log-densities, ``flag`` thresholds them at a reference-quantile.
    """

    proj_dim: int = 16
    quantile: float = 0.01          # flag below the 1st percentile
    config: EstimatorConfig = dataclasses.field(default_factory=EstimatorConfig)
    seed: int = 0
    _proj: Optional[jnp.ndarray] = None
    _est: Optional[SDKDE] = None
    _threshold: float = float("-inf")

    def _project(self, acts: jnp.ndarray) -> jnp.ndarray:
        acts = acts.astype(jnp.float32)
        if self._proj is None:
            d = acts.shape[-1]
            self._proj = jax.random.normal(
                jax.random.PRNGKey(self.seed), (d, self.proj_dim)
            ) / jnp.sqrt(self.proj_dim)
        return acts @ self._proj

    def fit(self, reference_acts: jnp.ndarray) -> "ActivationMonitor":
        """Fit on 80% of the reference; threshold on the held-out 20%.

        Scoring the fit points themselves inflates density (each point sees
        its own kernel mass), so a threshold quantile taken on them
        over-flags genuine in-distribution traffic — measured 58% false
        positives at the 2% quantile before the split.
        """
        z = self._project(reference_acts)
        n = z.shape[0]
        split = max(1, int(0.8 * n))
        perm = jax.random.permutation(
            jax.random.PRNGKey(self.seed + 1), n
        )
        fit_z, held_z = z[perm[:split]], z[perm[split:]]
        self._est = SDKDE(config=self.config).fit(fit_z)
        held_scores = jnp.log(
            jnp.maximum(self._est.evaluate(held_z), 1e-300)
        )
        self._threshold = float(jnp.quantile(held_scores, self.quantile))
        return self

    def score(self, acts: jnp.ndarray) -> jnp.ndarray:
        """Log-density of each (pooled) activation row."""
        assert self._est is not None, "call fit() first"
        p = self._est.evaluate(self._project(acts))
        return jnp.log(jnp.maximum(p, 1e-300))

    def flag(self, acts: jnp.ndarray) -> jnp.ndarray:
        """True where the activation is OOD (below the fit quantile)."""
        return self.score(acts) < self._threshold
