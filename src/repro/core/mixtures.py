"""Oracle Gaussian-mixture densities used by the paper's benchmarks.

The paper evaluates on "a simple 16-D Gaussian mixture" (Fig. 1/2) and a 1-D
mixture (Fig. 3).  We implement a generic isotropic Gaussian mixture with an
exact log-pdf (the oracle), deterministic sampling, and the two default
benchmark instances.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GaussianMixture:
    """Isotropic Gaussian mixture with exact pdf — the benchmark oracle."""

    means: np.ndarray    # (k, d)
    stds: np.ndarray     # (k,)  isotropic per component
    weights: np.ndarray  # (k,)  sums to 1

    @property
    def dim(self) -> int:
        return int(self.means.shape[1])

    @property
    def n_components(self) -> int:
        return int(self.means.shape[0])

    def sample(self, key: jax.Array, n: int) -> jnp.ndarray:
        """Draw ``n`` iid samples; deterministic in ``key``."""
        k_comp, k_noise = jax.random.split(key)
        comps = jax.random.choice(
            k_comp, self.n_components, shape=(n,), p=jnp.asarray(self.weights)
        )
        means = jnp.asarray(self.means)[comps]                      # (n, d)
        stds = jnp.asarray(self.stds)[comps][:, None]               # (n, 1)
        noise = jax.random.normal(k_noise, (n, self.dim))
        return means + stds * noise

    def log_pdf(self, x: jnp.ndarray) -> jnp.ndarray:
        """Exact log density at ``x`` of shape (m, d)."""
        mu = jnp.asarray(self.means)[None]                          # (1, k, d)
        std = jnp.asarray(self.stds)[None]                          # (1, k)
        sqd = jnp.sum((x[:, None, :] - mu) ** 2, axis=-1)           # (m, k)
        d = self.dim
        log_comp = (
            -0.5 * sqd / (std**2)
            - d * jnp.log(std)
            - 0.5 * d * math.log(2.0 * math.pi)
        )
        logw = jnp.log(jnp.asarray(self.weights))[None]
        return jax.scipy.special.logsumexp(log_comp + logw, axis=1)

    def pdf(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.exp(self.log_pdf(x))

    def score(self, x: jnp.ndarray) -> jnp.ndarray:
        """Exact oracle score ``∇ log p`` (for SD-KDE-with-oracle ablations)."""
        grad_logp = jax.vmap(jax.grad(lambda z: self.log_pdf(z[None])[0]))
        return grad_logp(x)


def benchmark_mixture_16d(separation: float = 4.0) -> GaussianMixture:
    """The paper's 16-D benchmark family: a simple well-separated mixture.

    Two isotropic components separated along the first coordinates, matching
    the "simple 16-D Gaussian mixture" described in Section 6.
    """
    d = 16
    m0 = np.zeros((d,))
    m1 = np.zeros((d,))
    m1[:4] = separation / 2.0
    m0[:4] = -separation / 2.0
    return GaussianMixture(
        means=np.stack([m0, m1]),
        stds=np.array([1.0, 0.7]),
        weights=np.array([0.6, 0.4]),
    )


def benchmark_mixture_1d() -> GaussianMixture:
    """Trimodal 1-D benchmark mixture (Fig. 3 family)."""
    return GaussianMixture(
        means=np.array([[-3.0], [0.0], [2.5]]),
        stds=np.array([0.8, 0.5, 1.2]),
        weights=np.array([0.3, 0.4, 0.3]),
    )


def mixture_for_dim(d: int) -> GaussianMixture:
    """A benchmark mixture for arbitrary d (tests sweep dimensions)."""
    if d == 1:
        return benchmark_mixture_1d()
    m0 = np.zeros((d,))
    m1 = np.zeros((d,))
    m1[: min(4, d)] = 2.0
    m0[: min(4, d)] = -2.0
    return GaussianMixture(
        means=np.stack([m0, m1]),
        stds=np.array([1.0, 0.7]),
        weights=np.array([0.6, 0.4]),
    )
