"""Bandwidth selection rules for KDE / SD-KDE.

The paper (and the underlying SD-KDE paper, Epstein et al. 2025) uses the
Gaussian kernel throughout.  Classical KDE with Silverman's rule scales the
bandwidth as ``n^{-1/(d+4)}``; SD-KDE's improved AMISE ``O(n^{-8/(d+8)})`` is
attained with the wider ``n^{-1/(d+8)}`` scaling.  Both are provided, plus the
score-estimation bandwidth convention ``t' = h^2/2`` (i.e. ``h_score = h/sqrt(2)``)
from the paper's semigroup analysis (Section 5).
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def silverman_bandwidth(x: jnp.ndarray) -> jnp.ndarray:
    """Silverman's rule of thumb, isotropic, d-dimensional.

    ``h = (4 / (d + 2))^{1/(d+4)} * n^{-1/(d+4)} * sigma_bar``

    where ``sigma_bar`` is the average per-dimension standard deviation.
    """
    n, d = x.shape
    sigma = jnp.std(x, axis=0).mean()
    factor = (4.0 / (d + 2.0)) ** (1.0 / (d + 4.0))
    return factor * (n ** (-1.0 / (d + 4.0))) * sigma


def sdkde_bandwidth(x: jnp.ndarray, scale: float = 1.0) -> jnp.ndarray:
    """SD-KDE-rate bandwidth: ``h ∝ n^{-1/(d+8)}``.

    SD-KDE cancels the leading ``O(h^2)`` bias term, so the AMISE-optimal
    bandwidth is wider than Silverman's; we keep Silverman's constant and
    swap the exponent (the constant is absorbed into ``scale`` which users
    may tune).
    """
    n, d = x.shape
    sigma = jnp.std(x, axis=0).mean()
    factor = (4.0 / (d + 2.0)) ** (1.0 / (d + 4.0))
    return scale * factor * (n ** (-1.0 / (d + 8.0))) * sigma


def score_bandwidth(h: jnp.ndarray | float) -> jnp.ndarray | float:
    """Bandwidth for the empirical-score KDE.

    The paper's operator analysis (Section 5) uses ``t' = h^2 / 2`` for the
    score-estimation kernel, i.e. ``h_score = h / sqrt(2)``.  The Section-1
    formula uses the same ``h``; both conventions are supported — this helper
    implements the semigroup convention, and estimators accept an explicit
    ``score_h`` to override.
    """
    return h / math.sqrt(2.0)


def gaussian_norm_const(d: int, h: float) -> float:
    """Normalizer ``(2*pi)^{d/2} * h^d`` of the isotropic Gaussian kernel."""
    return (2.0 * math.pi) ** (d / 2.0) * float(h) ** d
