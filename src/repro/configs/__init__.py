"""Architecture / workload registry.

Every assigned architecture is a module in this package exporting ``ARCH``
(an :class:`ArchSpec` with the exact published numbers from the brief) — the
launcher resolves ``--arch <id>`` here.  The paper's own SD-KDE workloads
are registered alongside the LM architectures so the multi-pod dry-run
treats them as first-class cells.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.common import ModelConfig

# ---------------------------------------------------------------------------
# Shapes (the assigned input-shape set; identical across LM architectures).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input shape.

    ``kind`` selects the lowered program:
      * ``train``   — full train_step (fwd+bwd+optimizer), grad accumulation
                      over ``microbatches``.
      * ``prefill`` — serve-side prefill: forward over ``seq_len`` tokens
                      producing the KV cache + last-token logits.
      * ``decode``  — serve_step: ONE new token against a ``seq_len``-token
                      KV cache (the brief's decode_*/long_* semantics).
    """

    name: str
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1    # train only: grad-accumulation steps


TRAIN_4K = ShapeCfg("train_4k", "train", 4096, 256, microbatches=8)
PREFILL_32K = ShapeCfg("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCfg("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCfg("long_500k", "decode", 524288, 1)

LM_SHAPES: Tuple[ShapeCfg, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES: Dict[str, ShapeCfg] = {s.name: s for s in LM_SHAPES}


# ---------------------------------------------------------------------------
# Architecture spec.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: ModelConfig
    # shape name -> reason, for cells the assignment designates as skips
    # (e.g. long_500k on pure full-attention archs).
    skips: Dict[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""
    # training policy (memory-driven at the ~1T scale)
    optimizer: str = "adamw"          # adamw | adafactor
    accum_dtype: str = "float32"      # gradient-accumulator dtype
    # Override the shape's grad-accumulation count.  FSDP-gathered expert
    # weights are re-gathered per microbatch, so fewer/larger microbatches
    # amortize that traffic (§Perf kimi iteration 4: 8 -> 2 quarters it).
    train_microbatches: Optional[int] = None

    def shape_applicable(self, shape: ShapeCfg) -> Optional[str]:
        """None if the (arch, shape) cell runs; else the skip reason."""
        return self.skips.get(shape.name)


FULL_ATTN_LONG_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is pure "
    "full-attention (see DESIGN.md §Arch-applicability)"
)


# ---------------------------------------------------------------------------
# SD-KDE workloads (the paper's own tables, as dry-run cells).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KdeWorkload:
    arch_id: str
    n_train: int
    n_test: int
    dim: int
    source: str = "Flash-SD-KDE paper §6"


KDE_WORKLOADS: Dict[str, KdeWorkload] = {
    # Figure 1 / Table 1 scale: 32k train, n_test = n/8.
    "flash_sdkde_32k": KdeWorkload("flash_sdkde_32k", 32768, 4096, 16),
    # "1M-sample 16-dimensional task evaluated on 131k queries" (§1, §7).
    "flash_sdkde_1m": KdeWorkload("flash_sdkde_1m", 1048576, 131072, 16),
}


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "minitron_8b",
    "phi3_mini_3p8b",
    "gemma2_2b",
    "chatglm3_6b",
    "kimi_k2_1t_a32b",
    "granite_moe_3b_a800m",
    "hymba_1p5b",
    "llava_next_34b",
    "whisper_large_v3",
    "falcon_mamba_7b",
)

_ALIASES = {
    "minitron-8b": "minitron_8b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "gemma2-2b": "gemma2_2b",
    "chatglm3-6b": "chatglm3_6b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "hymba-1.5b": "hymba_1p5b",
    "llava-next-34b": "llava_next_34b",
    "whisper-large-v3": "whisper_large_v3",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def get_arch(arch_id: str) -> ArchSpec:
    arch_id = _ALIASES.get(arch_id, arch_id).replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        )
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.ARCH


def list_archs() -> Tuple[str, ...]:
    return ARCH_IDS


def arch_cells(arch: ArchSpec):
    """All (shape, skip_reason) cells for an arch — skips included so the
    roofline table can record WHY a cell is absent."""
    return [(s, arch.shape_applicable(s)) for s in LM_SHAPES]
