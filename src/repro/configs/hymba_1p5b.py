"""Hymba-1.5B — parallel attention+Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention and a Mamba SSM in PARALLEL on the same normed
input and fuses them with learned per-channel scales (models/transformer
``hybrid`` family).  The SSM half gives O(1) decode state → long_500k RUNS.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,                 # 1600 / 25
    act="swiglu",
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="hymba_1p5b",
    model=MODEL,
    skips={},
    source="arXiv:2411.13676; hf",
)
