"""Kimi-K2 1T-A32B — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(per-expert) vocab=163840,
MoE 384 experts top-8 + 1 always-on shared expert (paper table).  The brief
specifies the GQA attention variant (not MLA).  Pure full-attention →
long_500k is an assigned skip.

At this scale the expert tensors dominate (~1T params); they are sharded
2-D — expert axis over ``model`` (384/16 = 24 experts per device) and the
per-expert d_ff over ``data`` — so bf16 parameters fit a single v5e pod
(~8 GB/chip).  See EXPERIMENTS.md §Dry-run for the memory ledger.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,                   # dense-equivalent width unused; experts rule
    vocab_size=163840,
    head_dim=112,                # 7168 / 64
    act="swiglu",
    n_experts=384,
    top_k=8,
    moe_dff=2048,
    n_shared_experts=1,
    rope_theta=50000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    expert_2d_sharding=True,     # expert axis over model, d_ff over data
    # 64 q-heads divide the model axis but the 8 KV heads don't; measured
    # better with sequence-sharded attention (§Perf kimi iteration 4).
    seq_shard_attn=True,
)

ARCH = ArchSpec(
    arch_id="kimi_k2_1t_a32b",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="arXiv:2501.kimi2 (paper-table); unverified",
    # ~1T params: factored second moments + bf16 grad accumulators are the
    # difference between 1 and 4 pods of optimizer state (EXPERIMENTS.md).
    optimizer="adafactor",
    accum_dtype="bfloat16",
    # Expert weights are FSDP-gathered per microbatch; 2 large microbatches
    # quarter that wire traffic vs the default 8 (§Perf kimi iteration 4).
    train_microbatches=2,
)
