"""Falcon-Mamba 7B — pure Mamba1, attention-free [arXiv:2410.05355; unverified].

64L d_model=4096 (no attention) vocab=65024, ssm_state=16, expand=2.
O(1) decode state (conv window + SSM state) → BOTH long-context cells run:
prefill_32k uses the associative-scan training path, decode shapes carry
(conv_state, ssm_state) only — no KV cache at all.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=32,                  # unused (attn-free); kept for cfg validity
    n_kv_heads=8,
    d_ff=0,
    vocab_size=65024,
    head_dim=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    attn_free=True,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="falcon_mamba_7b",
    model=MODEL,
    skips={},
    source="arXiv:2410.05355; unverified",
)
