"""Whisper large-v3 backbone [arXiv:2212.04356; unverified].

32L(dec) d_model=1280 20H (kv=20, i.e. MHA) d_ff=5120 vocab=51866.
Encoder-decoder: 32 encoder layers over 1500 stub frame embeddings (the
conv frontend is a STUB per the brief — ``input_specs()`` provides
precomputed (B, 1500, d) frames), decoder with self- + cross-attention.

decode_32k runs via the decoder self-attn cache + precomputed cross-attn
K/V; long_500k is an assigned skip (full-attention decoder).
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    act="gelu",
    n_enc_layers=32,
    enc_frames=1500,
    tie_embeddings=True,         # whisper ties decoder embed / proj
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="whisper_large_v3",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="arXiv:2212.04356; unverified",
)
