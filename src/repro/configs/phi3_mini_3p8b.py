"""Phi-3-mini 3.8B [arXiv:2404.14219; unverified].

32L d_model=3072 32H (GQA kv=32 — i.e. MHA) d_ff=8192 vocab=32064.
RoPE + SwiGLU.  Pure full-attention → long_500k is an assigned skip.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    act="swiglu",
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="phi3_mini_3p8b",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="arXiv:2404.14219; unverified",
)
