"""Minitron-8B — width/depth-pruned Nemotron-4 [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.  Pure
full-attention dense decoder → long_500k is an assigned skip.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    act="relu2",                 # nemotron uses squared-ReLU FFN
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,    # f32 master copies live in the optimizer
)

ARCH = ArchSpec(
    arch_id="minitron_8b",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="arXiv:2407.14679 (pruned nemotron); hf",
)
