"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512(per-expert) vocab=49155,
MoE 40 experts top-8.  40 experts don't divide the 16-way ``model`` axis, so
experts shard on the per-expert d_ff axis instead (TP-in-expert; see
models/common._moe_shapes).  Pure full-attention → long_500k skip.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    act="swiglu",
    n_experts=40,
    top_k=8,
    moe_dff=512,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="granite_moe_3b_a800m",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
