"""Gemma-2 2B [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.  Alternating
local(4096-window)/global layers, attn + final logit softcapping, sandwich
norms, (1+w) RMSNorm, tied embeddings scaled by sqrt(d).

long_500k RUNS for this arch: decode against a 524k cache is O(S) per token
and the alternating local layers bound half the cache traffic to the 4096
window (DESIGN.md §Arch-applicability).
"""

import jax.numpy as jnp

from repro.configs import ArchSpec
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    act="geglu",
    rms_one_plus=True,
    post_norms=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_alt=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="gemma2_2b",
    model=MODEL,
    skips={},
    source="arXiv:2408.00118; hf",
)
