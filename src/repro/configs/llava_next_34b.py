"""LLaVA-NeXT 34B backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.  The vision
frontend is a STUB per the brief: ``input_specs()`` supplies precomputed
anyres patch embeddings (B, n_patches, d_model) which a learned projection
maps into the token stream before the text tokens.  Pure full-attention →
long_500k is an assigned skip.

``n_patches=2880`` models anyres tiling: 4 high-res tiles + 1 base tile ×
576 patches each.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    act="swiglu",
    n_patches=2880,              # anyres: (4 tiles + base) x 576
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="llava_next_34b",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres tiling); unverified",
    accum_dtype="bfloat16",
)
