"""ChatGLM3-6B [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.  2D RoPE (rotate
only the first half of the head dim), SwiGLU.  Pure full-attention →
long_500k is an assigned skip.
"""

import jax.numpy as jnp

from repro.configs import ArchSpec, FULL_ATTN_LONG_SKIP
from repro.models.common import ModelConfig

MODEL = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    act="swiglu",
    rope_variant="half",         # chatglm 2d rope
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
)

ARCH = ArchSpec(
    arch_id="chatglm3_6b",
    model=MODEL,
    skips={"long_500k": FULL_ATTN_LONG_SKIP},
    source="arXiv:2406.12793; hf",
)
