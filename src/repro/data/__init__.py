from repro.data.synthetic import (
    lm_batch,
    batch_specs,
    host_local_batch,
    PrefetchLoader,
)
from repro.data.density import DensityWeighting, density_weights

__all__ = [
    "lm_batch",
    "batch_specs",
    "host_local_batch",
    "PrefetchLoader",
    "DensityWeighting",
    "density_weights",
]
