"""Deterministic synthetic data shards (token LM + modality stubs).

Design goals mirror a production loader at the interface level:

  * **Determinism / restart safety** — a batch is a pure function of
    (seed, step), so checkpoint-restart resumes the exact stream, and a
    re-dispatched straggler microbatch is bit-identical.
  * **Host-sharded generation** — each host materializes only its slice of
    the global batch (``host_local_batch``), then ``device_put``s with the
    global sharding; no host ever holds the full global batch.
  * **Prefetch** — ``PrefetchLoader`` overlaps generation of step t+1 with
    compute of step t (a thread, matching the usual double-buffer depth).

Token streams are Zipf-distributed (more realistic logits/loss than uniform)
with a deterministic per-(seed, step) key.  VLM patches and audio frames are
Gaussian stub embeddings, per the brief (frontends are stubs).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def _zipf_tokens(key, shape, vocab: int, alpha: float = 1.1) -> jnp.ndarray:
    """Zipf-ish token ids via the inverse CDF of a bounded power law.

    Continuous p(r) ∝ r^{-alpha} on [1, V]:  CDF⁻¹(u) = (1 + u·(V^{1-a}−1))
    ^{1/(1-a)} — low ids are far more frequent, like real text.
    """
    u = jax.random.uniform(key, shape, minval=0.0, maxval=1.0)
    a = 1.0 - alpha
    r = (1.0 + u * (float(vocab) ** a - 1.0)) ** (1.0 / a)
    r = jnp.clip(r, 1.0, float(vocab))
    return (r - 1.0).astype(jnp.int32)


def lm_batch(cfg: ModelConfig, seed: int, step: int, batch: int,
             seq: int) -> Dict[str, jnp.ndarray]:
    """Global batch as a dict of arrays — pure function of (seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_tok, k_mod = jax.random.split(key)
    out = {"tokens": _zipf_tokens(k_tok, (batch, seq), cfg.vocab_size)}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k_mod, (batch, cfg.n_patches, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            k_mod, (batch, cfg.enc_frames, cfg.d_model), jnp.float32
        ).astype(cfg.dtype)
    return out


def batch_pspecs(cfg: ModelConfig, batch_axes=("data",)) -> Dict[str, P]:
    """Batch shards over the data(+pod) axes; seq/features replicated."""
    ax = tuple(batch_axes)
    specs = {"tokens": P(ax, None)}
    if cfg.family == "vlm":
        specs["patches"] = P(ax, None, None)
    if cfg.family == "audio":
        specs["frames"] = P(ax, None, None)
    return specs


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                batch_axes=("data",)) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    specs = batch_pspecs(cfg, batch_axes)
    shapes = {"tokens": ((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        shapes["patches"] = ((batch, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        shapes["frames"] = ((batch, cfg.enc_frames, cfg.d_model), cfg.dtype)
    return {
        k: jax.ShapeDtypeStruct(s, dt, sharding=NamedSharding(mesh, specs[k]))
        for k, (s, dt) in shapes.items()
    }


def host_local_batch(
    cfg: ModelConfig, seed: int, step: int, batch: int, seq: int,
    mesh: Mesh, batch_axes=("data",),
) -> Dict[str, jax.Array]:
    """Generate this host's slice of the global batch and assemble the
    globally-sharded arrays via ``make_array_from_callback`` — each host
    computes only the rows it owns."""
    specs = batch_pspecs(cfg, batch_axes)
    full = lm_batch(cfg, seed, step, batch, seq)  # traced lazily per-slice

    out = {}
    for name, arr in full.items():
        sharding = NamedSharding(mesh, specs[name])
        np_arr = np.asarray(arr)

        def cb(index, _a=np_arr):
            return _a[index]

        out[name] = jax.make_array_from_callback(np_arr.shape, sharding, cb)
    return out


class PrefetchLoader:
    """Double-buffered loader: generates batch t+1 while t is consumed."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
