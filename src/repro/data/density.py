"""SD-KDE density weighting — the paper's estimator as a data-pipeline stage.

Given per-example embeddings (any pooled representation projected to a low
dimension), fit Flash-SD-KDE over the corpus sample and weight examples by
``p̂^{-alpha}``: up-weights low-density tail examples, down-weights
near-duplicates.  This is the framework-level integration of the paper's
technique (DESIGN.md §4) — architecture-agnostic, applies to all ten
assigned archs.

The quadratic SD-KDE pass runs on the same backends as the standalone
estimator (jnp / pallas / ring), so corpus-scale weighting (the paper's 1M
regime) distributes over the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.estimator import EstimatorConfig, SDKDE


def density_weights(
    embeddings: jnp.ndarray,
    *,
    alpha: float = 0.5,
    h: Optional[float] = None,
    config: EstimatorConfig | None = None,
    eps: float = 1e-12,
) -> jnp.ndarray:
    """w_i ∝ p̂(e_i)^{-alpha}, normalized to mean 1 over the corpus sample."""
    est = SDKDE(h, config or EstimatorConfig()).fit(embeddings)
    p = jnp.maximum(est.evaluate(embeddings), eps)
    w = p ** (-alpha)
    return w / jnp.mean(w)


@dataclasses.dataclass
class DensityWeighting:
    """Stateful pipeline stage: fit on a corpus sample, weight every batch.

    ``fit`` runs the SD-KDE score pass once on a representative embedding
    sample; ``__call__`` evaluates the debiased KDE on incoming batch
    embeddings (a single streamed GEMM pass) and returns sampling weights.
    """

    alpha: float = 0.5
    h: Optional[float] = None
    config: EstimatorConfig = dataclasses.field(default_factory=EstimatorConfig)
    eps: float = 1e-12
    _est: Optional[SDKDE] = None
    _norm: float = 1.0

    def fit(self, corpus_embeddings: jnp.ndarray) -> "DensityWeighting":
        self._est = SDKDE(self.h, self.config).fit(corpus_embeddings)
        p = jnp.maximum(self._est.evaluate(corpus_embeddings), self.eps)
        self._norm = float(jnp.mean(p ** (-self.alpha)))
        return self

    def __call__(self, batch_embeddings: jnp.ndarray) -> jnp.ndarray:
        assert self._est is not None, "call fit() first"
        p = jnp.maximum(self._est.evaluate(batch_embeddings), self.eps)
        return (p ** (-self.alpha)) / self._norm

    def resample_indices(self, batch_embeddings: jnp.ndarray,
                         key: jax.Array, k: int) -> jnp.ndarray:
        """Importance-resample ``k`` batch rows by density weight."""
        w = self(batch_embeddings)
        return jax.random.choice(
            key, batch_embeddings.shape[0], shape=(k,),
            p=w / jnp.sum(w), replace=False,
        )
