"""Process-wide observability switches.

Two independent planes, both togglable at runtime:

  * **metrics** (default ON) — counters / gauges / histograms.  Each
    mutation is one flag check + one locked scalar update; a disabled
    plane short-circuits at the flag check.
  * **tracing** (default OFF) — structured spans into a bounded ring
    buffer.  Disabled tracing returns a shared no-op context manager, so
    the hot serve loop pays a single attribute read per ``span()`` call.

The flags live here (not on a registry object) so the fast-path check is
a module-attribute load, with no import cycle between the metric and
trace modules.
"""

from __future__ import annotations

from typing import Optional

metrics_on: bool = True
trace_on: bool = False


def configure(metrics: Optional[bool] = None,
              trace: Optional[bool] = None) -> None:
    """Flip either observability plane (None leaves it unchanged)."""
    global metrics_on, trace_on
    if metrics is not None:
        metrics_on = bool(metrics)
    if trace is not None:
        trace_on = bool(trace)


def enabled() -> dict:
    return {"metrics": metrics_on, "trace": trace_on}


__all__ = ["configure", "enabled", "metrics_on", "trace_on"]
