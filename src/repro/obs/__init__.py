"""Unified observability: metrics, trace spans, and profiler hooks.

One process-wide layer every subsystem reports into:

    from repro import obs

    obs.counter("serve.requests").inc()
    obs.histogram("serve.latency_s", lo=1e-5, hi=100).observe(dt)
    with obs.span("serve.dispatch", key=key, bucket=bucket) as sp:
        sp.set(cache="hit")
        ...

Metrics (counters / gauges / fixed log-bucketed histograms — bounded
state, no sample lists) are ON by default; trace spans (bounded ring
buffer, parent ids, monotonic µs timestamps) are OFF by default and cost
one branch per ``span()`` call while off.  ``obs.configure(metrics=...,
trace=...)`` flips either plane at runtime.

Export surfaces:

  * ``obs.metrics_snapshot()`` — JSON-safe dict of every instrument;
  * ``obs.prometheus_text()`` — Prometheus text exposition
    (``lint_prometheus`` / ``python -m repro.obs`` validate it in CI);
  * ``obs.trace_events()`` / ``obs.span_tree()`` — buffered span events
    and their parent-id reconstruction.

Instrumented layers: ``serve/engine.py`` (request → dispatch → bucket →
compile spans, latency + staleness + pad-ratio histograms),
``serve/batching.py`` (bucket-cache hit/miss/eviction counters),
``stream/estimator.py`` (append/evict/flush/rebuild spans, dirty-tile and
slack-occupancy gauges), ``kernels/ops.py`` (prune visit fraction,
certificate budgets, kernel-launch profiler annotations) and
``kernels/autotune.py`` (resolve decisions, probe timings, occupancy
updates).  See docs/architecture.md § Observability for the span
taxonomy and metric names.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    gauge,
    histogram,
    lint_prometheus,
    log_bucket_bounds,
    metrics_snapshot,
    prometheus_text,
    registry,
)
from repro.obs import state
from repro.obs.state import configure, enabled
from repro.obs.trace import (
    Span,
    annotate,
    clear_trace,
    set_trace_capacity,
    span,
    span_tree,
    trace_events,
)

__all__ = [
    "state", "configure", "enabled",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "counter", "gauge", "histogram",
    "log_bucket_bounds", "lint_prometheus",
    "metrics_snapshot", "prometheus_text",
    "Span", "span", "annotate",
    "trace_events", "clear_trace", "set_trace_capacity", "span_tree",
]
