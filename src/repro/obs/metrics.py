"""Process-wide metrics: counters, gauges, log-bucketed histograms.

One module-level ``MetricsRegistry`` holds every instrument, created on
first use and addressed by dot-separated name (``serve.requests``,
``kernels.prune.visit_fraction``).  Design constraints, in order:

  1. **Bounded memory.**  Histograms keep fixed log-spaced bucket counts
     plus (count, sum, min, max) — never a sample list — so a month-long
     serving process holds exactly as much telemetry state as a fresh one.
  2. **~Free when disabled.**  Every mutation checks ``state.metrics_on``
     first; the disabled path is one attribute read and a branch.
  3. **Exportable.**  ``snapshot()`` returns a JSON-safe dict;
     ``prometheus_text()`` renders the standard text exposition
     (``name{labels} value`` plus ``_bucket/_sum/_count`` for histograms)
     that ``lint_prometheus`` — and CI — validates.

Percentiles from a log-bucketed histogram are estimates: geometric
interpolation inside the winning bucket, clamped to the exact tracked
[min, max].  Adjacent bucket edges are ``10^(1/per_decade)`` apart, so a
quantile is exact for 0/1-sample histograms and within one edge ratio
otherwise — the documented resolution, asserted in tests.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, List, Optional, Tuple

from repro.obs import state

_NAME_RE_HELP = "metric names: dot-separated [a-zA-Z0-9_] segments"


def _check_name(name: str) -> str:
    if not name or not all(
        seg and all(c.isalnum() or c == "_" for c in seg)
        for seg in name.split(".")
    ):
        raise ValueError(f"bad metric name {name!r} ({_NAME_RE_HELP})")
    return name


def log_bucket_bounds(lo: float, hi: float,
                      per_decade: int = 6) -> Tuple[float, ...]:
    """Fixed log-spaced upper bucket edges covering [lo, hi].

    Edge ``i`` is ``lo · 10^(i/per_decade)``; the last edge is the first
    one ≥ ``hi``.  Values ≤ lo land in the first bucket, values past the
    last edge in the overflow bucket — both bounded, neither lost.
    """
    if not (lo > 0 and hi > lo and per_decade >= 1):
        raise ValueError(f"bad histogram range lo={lo} hi={hi} "
                         f"per_decade={per_decade}")
    n = math.ceil(math.log10(hi / lo) * per_decade)
    return tuple(lo * 10.0 ** (i / per_decade) for i in range(n + 1))


class Counter:
    """Monotonic float counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = _check_name(name)
        self.help = help
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not state.metrics_on:
            return
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._lock:
            self.value += n

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Dict[str, str]] = None):
        self.name = _check_name(name)
        self.help = help
        self.labels = dict(labels or {})
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        if not state.metrics_on:
            return
        with self._lock:
            self.value = float(v)

    def reset(self) -> None:
        with self._lock:
            self.value = 0.0

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self.value}


class Histogram:
    """Fixed log-spaced-bucket histogram: bounded state, estimated tails.

    ``observe(v, k)`` folds ``k`` identical samples in O(log buckets) —
    the serving engine uses the weight to record one latency per request
    of a coalesced dispatch without looping.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", *,
                 lo: float = 1e-6, hi: float = 1e3, per_decade: int = 6,
                 labels: Optional[Dict[str, str]] = None):
        self.name = _check_name(name)
        self.help = help
        self.labels = dict(labels or {})
        self.bounds = log_bucket_bounds(lo, hi, per_decade)
        self._lock = threading.Lock()
        self._zero()

    def _zero(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)  # +1 = overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float, k: int = 1) -> None:
        if not state.metrics_on or k <= 0:
            return
        v = float(v)
        idx = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[idx] += k
            self.count += k
            self.sum += v * k
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def reset(self) -> None:
        with self._lock:
            self._zero()

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0 when empty): geometric interpolation
        inside the winning bucket, clamped to the exact [min, max]."""
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = max(1, math.ceil(q * self.count))
            acc = 0
            for i, c in enumerate(self.counts):
                acc += c
                if acc >= rank:
                    break
            lo = self.bounds[i - 1] if i > 0 else max(self.min, 1e-300)
            hi = self.bounds[i] if i < len(self.bounds) else max(
                self.max, self.bounds[-1]
            )
            est = math.sqrt(max(lo, 1e-300) * max(hi, 1e-300))
            return min(max(est, self.min), self.max)

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            nonzero = [[self.bounds[i] if i < len(self.bounds) else "+Inf",
                        c]
                       for i, c in enumerate(self.counts) if c]
            snap = {"type": self.kind, "count": self.count,
                    "sum": self.sum,
                    "min": self.min if self.count else 0.0,
                    "max": self.max if self.count else 0.0,
                    "buckets": nonzero}
        for q, key in ((0.5, "p50"), (0.99, "p99")):
            snap[key] = self.quantile(q)
        return snap


class MetricsRegistry:
    """Name-addressed instrument store; instruments are created once."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: "Dict[Tuple[str, tuple], object]" = {}

    def _get(self, cls, name: str, help: str, labels, **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, help, labels=labels, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{inst.kind}, not {cls.kind}"
                )
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", *,
                  lo: float = 1e-6, hi: float = 1e3, per_decade: int = 6,
                  labels: Optional[Dict[str, str]] = None) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         lo=lo, hi=hi, per_decade=per_decade)

    def instruments(self) -> List[object]:
        with self._lock:
            return list(self._instruments.values())

    def reset(self) -> None:
        """Zero every instrument's state; the instrument set survives, so
        a snapshot taken across a reset reports the same metric names."""
        for inst in self.instruments():
            inst.reset()

    def clear(self) -> None:
        """Drop every instrument (tests only — serving code never needs
        to forget an instrument, just ``reset`` its state)."""
        with self._lock:
            self._instruments.clear()

    def snapshot(self) -> Dict[str, dict]:
        """JSON-safe dump of every instrument, sorted by name."""
        out = {}
        for inst in sorted(self.instruments(),
                           key=lambda i: (i.name, sorted(i.labels.items()))):
            key = inst.name
            if inst.labels:
                key += "{" + ",".join(
                    f"{k}={v}" for k, v in sorted(inst.labels.items())
                ) + "}"
            out[key] = inst.snapshot()
        return out

    # -- Prometheus text exposition --------------------------------------

    def prometheus_text(self) -> str:
        """Standard text exposition (one HELP/TYPE block per metric)."""
        by_name: Dict[str, List[object]] = {}
        for inst in self.instruments():
            by_name.setdefault(inst.name, []).append(inst)
        lines = []
        for name in sorted(by_name):
            insts = by_name[name]
            pname = _prom_name(name)
            kind = insts[0].kind
            help_text = next((i.help for i in insts if i.help), name)
            lines.append(f"# HELP {pname} {_prom_escape(help_text)}")
            lines.append(f"# TYPE {pname} {kind}")
            for inst in insts:
                if kind == "histogram":
                    lines.extend(_prom_histogram(pname, inst))
                else:
                    lines.append(
                        f"{pname}{_prom_labels(inst.labels)} "
                        f"{_prom_value(inst.value)}"
                    )
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(name: str) -> str:
    return name.replace(".", "_")


def _prom_escape(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _prom_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_prom_escape(str(v))}"'
             for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _prom_histogram(pname: str, h: Histogram) -> List[str]:
    lines, acc = [], 0
    with h._lock:
        counts = list(h.counts)
        total, tsum = h.count, h.sum
    for i, c in enumerate(counts):
        acc += c
        le = _prom_value(h.bounds[i]) if i < len(h.bounds) else "+Inf"
        le_label = 'le="' + le + '"'
        lines.append(
            f"{pname}_bucket{_prom_labels(h.labels, le_label)} {acc}"
        )
    lines.append(f"{pname}_sum{_prom_labels(h.labels)} {_prom_value(tsum)}")
    lines.append(f"{pname}_count{_prom_labels(h.labels)} {total}")
    return lines


# ---------------------------------------------------------------------------
# Exposition lint (the CI smoke gate).
# ---------------------------------------------------------------------------

_PROM_NAME_OK = lambda s: (  # noqa: E731 - [a-zA-Z_:][a-zA-Z0-9_:]*
    bool(s) and (s[0].isalpha() or s[0] in "_:")
    and all(c.isalnum() or c in "_:" for c in s)
)


def lint_prometheus(text: str) -> List[str]:
    """Problems found in a Prometheus text exposition (empty = clean).

    Checks the properties a scraper depends on: legal metric names, every
    sample preceded by a TYPE for its family, parseable sample values,
    histogram families exposing ``_bucket``/``_sum``/``_count``, and no
    duplicate TYPE declarations.
    """
    problems: List[str] = []
    typed: Dict[str, str] = {}
    sampled: Dict[str, set] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                problems.append(f"line {ln}: bad comment {line!r}")
                continue
            if parts[1] == "TYPE":
                name, kind = parts[2], (parts[3] if len(parts) > 3 else "")
                if not _PROM_NAME_OK(name):
                    problems.append(f"line {ln}: bad metric name {name!r}")
                if kind not in ("counter", "gauge", "histogram", "summary",
                                "untyped"):
                    problems.append(f"line {ln}: bad TYPE {kind!r}")
                if name in typed:
                    problems.append(f"line {ln}: duplicate TYPE for {name}")
                typed[name] = kind
            elif not _PROM_NAME_OK(parts[2]):
                problems.append(f"line {ln}: bad metric name {parts[2]!r}")
            continue
        # sample line: name[{labels}] value
        body = line.strip()
        brace = body.find("{")
        if brace >= 0:
            name = body[:brace]
            close = body.rfind("}")
            if close < brace:
                problems.append(f"line {ln}: unbalanced labels {line!r}")
                continue
            rest = body[close + 1:].split()
        else:
            fields = body.split()
            name, rest = fields[0], fields[1:]
        if not _PROM_NAME_OK(name):
            problems.append(f"line {ln}: bad metric name {name!r}")
            continue
        if not rest:
            problems.append(f"line {ln}: sample without a value")
            continue
        try:
            float(rest[0].replace("+Inf", "inf").replace("-Inf", "-inf"))
        except ValueError:
            problems.append(f"line {ln}: bad sample value {rest[0]!r}")
        family = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base and typed.get(base) == "histogram":
                family = base
                sampled.setdefault(base, set()).add(suffix)
                break
        if family not in typed:
            problems.append(f"line {ln}: sample {name} has no TYPE")
        else:
            sampled.setdefault(family, set()).add("")
    for name, kind in typed.items():
        if kind == "histogram":
            missing = {"_bucket", "_sum", "_count"} - sampled.get(name, set())
            if missing:
                problems.append(
                    f"histogram {name} missing series: {sorted(missing)}"
                )
    return problems


#: The process-wide registry every instrumented module shares.
registry = MetricsRegistry()

counter = registry.counter
gauge = registry.gauge
histogram = registry.histogram
metrics_snapshot = registry.snapshot
prometheus_text = registry.prometheus_text

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "log_bucket_bounds", "lint_prometheus",
    "registry", "counter", "gauge", "histogram",
    "metrics_snapshot", "prometheus_text",
]
