"""Prometheus exposition lint CLI — the CI metrics smoke gate.

    python -m repro.obs serve_metrics.json   # the --metrics-json artifact
    python -m repro.obs metrics.prom         # raw text exposition

JSON inputs are the ``serve_kde --metrics-json`` document (its
``prometheus`` field holds the exposition); anything else is linted as
raw text.  Exits nonzero listing every problem found, so a malformed
metric name or a histogram missing its ``_count`` series fails the build
instead of breaking whichever scraper meets it first.
"""

from __future__ import annotations

import json
import sys

from repro.obs.metrics import lint_prometheus


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    path = argv[0]
    with open(path) as f:
        raw = f.read()
    text = raw
    if path.endswith(".json"):
        doc = json.loads(raw)
        text = doc.get("prometheus")
        if not isinstance(text, str):
            print(f"{path}: no 'prometheus' text field in JSON document",
                  file=sys.stderr)
            return 1
    problems = lint_prometheus(text)
    n_samples = sum(
        1 for ln in text.splitlines() if ln.strip() and not ln.startswith("#")
    )
    if problems:
        print(f"{path}: {len(problems)} exposition problem(s):",
              file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"{path}: prometheus exposition clean ({n_samples} samples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
