"""Structured trace spans: lightweight, bounded, reconstructable.

``span(name, **attrs)`` is a context manager that records one event per
exit into a process-wide **ring buffer** (``collections.deque(maxlen)``, so
a long-lived server keeps the most recent window and nothing grows).  Each
event carries:

  * monotonic timestamps (``perf_counter_ns``-based start + duration, µs),
  * a process-unique span id and its **parent id** (a thread-local stack,
    so nested spans — request → dispatch → bucket → kernel — reconstruct
    into a tree even across the stream's background-flush thread, which
    gets its own stack),
  * the caller's attributes (JSON-safe-coerced), plus any added mid-span
    via ``sp.set(...)`` — how the engine attaches "cache hit/miss" after
    the lookup resolves.

When tracing is disabled (the default) ``span()`` returns one shared
no-op context manager: the hot loop pays an attribute read and a branch.

``annotate(name)`` additionally brackets a region with
``jax.profiler.TraceAnnotation`` when tracing is on and a profiler is
available, so kernel launches line up with device timelines in
``jax.profiler.trace`` captures; it degrades to a no-op everywhere else.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.obs import state

#: Default ring capacity — ~a few MB of events at worst, never more.
DEFAULT_CAPACITY = 8192

_ORIGIN_NS = time.perf_counter_ns()
_SEQ = itertools.count(1)
_EVENTS: Deque[dict] = deque(maxlen=DEFAULT_CAPACITY)
_TLS = threading.local()


def _stack() -> List[int]:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


def _safe(v):
    """JSON-safe attribute value (numpy/jax scalars → python, else str)."""
    if isinstance(v, (int, float, str, bool)) or v is None:
        return v
    try:
        return v.item()
    except (AttributeError, ValueError):
        return str(v)


class _NullSpan:
    """The shared disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """One live span; use via ``with obs.span("serve.dispatch", ...):``."""

    __slots__ = ("name", "attrs", "id", "parent", "_t0")

    def __init__(self, name: str, attrs: Dict):
        self.name = name
        self.attrs = {k: _safe(v) for k, v in attrs.items()}
        self.id = next(_SEQ)
        self.parent: Optional[int] = None
        self._t0 = 0

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (e.g. cache hit/miss)."""
        for k, v in attrs.items():
            self.attrs[k] = _safe(v)
        return self

    def __enter__(self) -> "Span":
        st = _stack()
        self.parent = st[-1] if st else None
        st.append(self.id)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0
        st = _stack()
        if st and st[-1] == self.id:
            st.pop()
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        _EVENTS.append({
            "name": self.name,
            "id": self.id,
            "parent": self.parent,
            "ts_us": (self._t0 - _ORIGIN_NS) / 1e3,
            "dur_us": dur_ns / 1e3,
            "thread": threading.current_thread().name,
            "attrs": self.attrs,
        })
        return False


def span(name: str, **attrs):
    """A trace span (the shared no-op when tracing is disabled)."""
    if not state.trace_on:
        return _NULL_SPAN
    return Span(name, attrs)


def trace_events() -> List[dict]:
    """The buffered events, oldest first (each is a JSON-safe dict)."""
    return list(_EVENTS)


def clear_trace() -> None:
    _EVENTS.clear()


def set_trace_capacity(capacity: int) -> None:
    """Re-bound the ring buffer (drops buffered events)."""
    global _EVENTS
    if capacity < 1:
        raise ValueError("trace capacity must be >= 1")
    _EVENTS = deque(maxlen=int(capacity))


def span_tree(events: Optional[List[dict]] = None) -> Dict[Optional[int],
                                                           List[dict]]:
    """Events grouped by parent id — the reconstruction helper tests and
    trace readers use to walk request → dispatch → kernel chains."""
    by_parent: Dict[Optional[int], List[dict]] = {}
    for ev in (trace_events() if events is None else events):
        by_parent.setdefault(ev["parent"], []).append(ev)
    return by_parent


class _Annotation:
    """TraceAnnotation when available + tracing on; no-op otherwise."""

    __slots__ = ("_inner",)

    def __init__(self, name: str):
        self._inner = None
        if state.trace_on:
            try:
                from jax.profiler import TraceAnnotation

                self._inner = TraceAnnotation(name)
            except Exception:  # noqa: BLE001 - profiler optional everywhere
                self._inner = None

    def __enter__(self):
        if self._inner is not None:
            self._inner.__enter__()
        return self

    def __exit__(self, *exc):
        if self._inner is not None:
            self._inner.__exit__(*exc)
        return False


def annotate(name: str) -> _Annotation:
    """Bracket a kernel launch for ``jax.profiler`` device timelines."""
    return _Annotation(name)


__all__ = [
    "DEFAULT_CAPACITY", "Span", "span", "annotate",
    "trace_events", "clear_trace", "set_trace_capacity", "span_tree",
]
