"""Adafactor: factored second moments, sub-linear optimizer memory.

For the ~1T-parameter cells (kimi-k2) even bf16 Adam moments are the
difference between fitting one pod or needing two; Adafactor stores row/col
second-moment factors (O(n+m) per matrix instead of O(n·m)) and no first
moment, shrinking optimizer state to roughly the master-copy size.

Reference: Shazeer & Stern, 2018.  Matches adamw.py's pure-pytree API.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Dict[str, jnp.ndarray]):
    def init_one(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),        # row
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
    )
    v = {k: init_one(p) for k, p in params.items()}
    return {"step": jnp.int32(0), "master": master, "v": v}


def adafactor_state_pspecs(param_shapes, data_size: int, *, axis="data"):
    """PartitionSpecs matching ``adafactor_init``'s structure.

    Masters get ZeRO-1 extension (adamw.opt_state_pspecs rules); the factored
    moments inherit the param spec with the averaged-out dim dropped.
    """
    from jax.sharding import PartitionSpec as P

    from repro.optim.adamw import _zero1_spec

    master, v = {}, {}
    for name, (shape, _, spec) in param_shapes.items():
        master[name] = _zero1_spec(shape, spec, data_size, axis)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        if _factored(shape):
            v[name] = {
                "vr": P(*entries[:-1]),
                "vc": P(*(entries[:-2] + entries[-1:])),
            }
        else:
            v[name] = {"v": P(*entries)}
    return {"step": P(), "master": master, "v": v}


def adafactor_update(
    grads: Dict[str, jnp.ndarray],
    state,
    params: Dict[str, jnp.ndarray],
    lr,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Dict[str, jnp.ndarray], dict]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)                 # increasing-decay schedule

    def upd(g, m, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if "vr" in v:
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            u = g * jax.lax.rsqrt(
                vr[..., None] / jnp.maximum(denom[..., None], eps)
            ) * jax.lax.rsqrt(vc[..., None, :])
            v_new = {"vr": vr, "vc": vc}
        else:
            vf = beta * v["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(vf)
            v_new = {"v": vf}
        # update clipping (RMS <= threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        m_new = m - lr * (u + weight_decay * m)
        return m_new, v_new

    new_master, new_v = {}, {}
    for k in params:
        new_master[k], new_v[k] = upd(grads[k], state["master"][k],
                                      state["v"][k])
    new_params = {k: new_master[k].astype(params[k].dtype) for k in params}
    return new_params, {"step": step, "master": new_master, "v": new_v}
