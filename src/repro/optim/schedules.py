"""Learning-rate schedules (pure functions of the step index)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, peak_lr: float, warmup_steps: int):
    s = jnp.minimum(step.astype(jnp.float32), warmup_steps)
    return peak_lr * s / jnp.maximum(warmup_steps, 1)


def cosine_schedule(step, peak_lr: float, warmup_steps: int,
                    total_steps: int, final_frac: float = 0.1):
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""
    s = step.astype(jnp.float32)
    warm = linear_warmup(step, peak_lr, warmup_steps)
    prog = jnp.clip(
        (s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
        0.0, 1.0,
    )
    cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup_steps, warm, peak_lr * cos)
