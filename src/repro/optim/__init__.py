from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    opt_state_pspecs,
)
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.optim.clipping import clip_by_global_norm

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_state_pspecs",
    "adafactor_init",
    "adafactor_update",
    "cosine_schedule",
    "linear_warmup",
    "clip_by_global_norm",
]
