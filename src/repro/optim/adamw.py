"""AdamW with mixed-precision master weights and ZeRO-1 state sharding.

Parameters may live in bf16 (the large configs do); the optimizer carries
f32 master copies plus the two Adam moments.  ZeRO-1 is expressed through
GSPMD: optimizer-state PartitionSpecs extend each parameter's spec by
sharding one additional (previously unsharded, divisible) dimension over the
``data`` axis — state memory then scales 1/(data·model) instead of 1/model,
and GSPMD materializes the reduce-scatter/all-gather pair around the update.

All functions are pure pytree->pytree (usable inside a pjit'd train step).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # bf16 moments halve optimizer memory (needed for the ~1T configs);
    # master copies stay f32.
    moment_dtype: jnp.dtype = jnp.float32


def adamw_init(params: Dict[str, jnp.ndarray],
               cfg: AdamWConfig = AdamWConfig()):
    """State: (step, master(f32), mu, nu)."""
    # copy=True: a no-op astype would alias the param buffer and break
    # donate_argnums (same buffer donated twice in the train step).
    master = jax.tree.map(
        lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
    )
    mu = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    nu = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    return {"step": jnp.int32(0), "master": master, "mu": mu, "nu": nu}


def adamw_update(
    grads: Dict[str, jnp.ndarray],
    state,
    params: Dict[str, jnp.ndarray],
    lr,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Dict[str, jnp.ndarray], dict]:
    """One AdamW step; returns (new_params, new_state).

    Decoupled weight decay is applied to master weights; new params are cast
    back to each param's storage dtype.
    """
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / c1
        vhat = nu32 / c2
        m_new = m - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * m
        )
        return m_new, mu32.astype(cfg.moment_dtype), nu32.astype(cfg.moment_dtype)

    master, mu, nu = {}, {}, {}
    for k in params:
        master[k], mu[k], nu[k] = upd(
            grads[k], state["master"][k], state["mu"][k], state["nu"][k]
        )
    new_params = {k: master[k].astype(params[k].dtype) for k in params}
    return new_params, {"step": step, "master": master, "mu": mu, "nu": nu}


# ---------------------------------------------------------------------------
# ZeRO-1 PartitionSpecs.
# ---------------------------------------------------------------------------


def _zero1_spec(shape: Tuple[int, ...], spec: P, data_size: int,
                axis="data") -> P:
    """Extend ``spec`` by sharding one extra dimension over ``axis``.

    ``axis`` may be a single mesh axis or a tuple (("pod", "data") on the
    multi-pod mesh).  Picks the first dimension that is (a) unsharded in
    ``spec`` and (b) divisible by the axis size; replicates (keeps the param
    spec) when none qualifies — small vectors don't matter for ZeRO.
    """
    axis_names = axis if isinstance(axis, tuple) else (axis,)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if used & set(axis_names):
        return spec
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % data_size == 0 and dim >= data_size:
            new = list(entries)
            new[i] = axis
            return P(*new)
    return spec


def opt_state_pspecs(
    param_shapes: Dict[str, Tuple[Tuple[int, ...], object, P]],
    data_size: int,
    *,
    axis="data",
) -> dict:
    """PartitionSpec pytree matching ``adamw_init``'s state structure."""
    z = {
        name: _zero1_spec(shape, spec, data_size, axis)
        for name, (shape, _, spec) in param_shapes.items()
    }
    return {
        "step": P(),
        "master": dict(z),
        "mu": dict(z),
        "nu": dict(z),
    }
