"""KDE query-serving driver: fit once, answer ragged query traffic.

The density analogue of ``repro.launch.serve`` (the LM serving driver):
registers a dataset with the ``repro.serve`` engine (the one-time quadratic
debias pass — "prefill"), then serves a stream of variable-size query
batches (cheap GEMMs — "decode") and reports throughput, tail latency, and
shape-bucket cache efficiency.

  PYTHONPATH=src python -m repro.launch.serve_kde \\
      --backend pallas --method sdkde --n 8192 --d 8 --requests 64
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.core import kde as ref
from repro.core.mixtures import mixture_for_dim
from repro.serve import QueryRequest, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    # Plannable knobs default to None = "not supplied": under --plan auto
    # they stay unset so the planner fills them (a supplied flag always
    # wins — override precedence); under --plan off they fall back to the
    # historical CLI defaults below.
    ap.add_argument("--backend", default=None,
                    choices=["jnp", "pallas", "ring"])
    ap.add_argument("--method", default="sdkde",
                    choices=["kde", "sdkde", "laplace"])
    ap.add_argument("--n", type=int, default=8192, help="train samples")
    ap.add_argument("--d", type=int, default=8, help="dimension")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=512,
                    help="largest query batch in the traffic mix")
    ap.add_argument("--min-batch", type=int, default=32,
                    help="smallest shape bucket")
    block_arg = lambda s: s if s == "auto" else int(s)  # noqa: E731
    ap.add_argument("--block-m", type=block_arg, default=None,
                    help="Pallas row tile (int or 'auto' = autotuned)")
    ap.add_argument("--block-n", type=block_arg, default=None,
                    help="Pallas column tile (int or 'auto')")
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16x2", "rff"],
                    help="Pallas GEMM-operand tier (kernels/precision.py) "
                         "or 'rff' to pin the random-feature fast tier")
    ap.add_argument("--rff", default=None, choices=["auto", "on", "off"],
                    help="random-feature fast tier policy "
                         "(kernels/flash_rff.py; 'auto' fits lazily on "
                         "first cascade-eligible query)")
    ap.add_argument("--rff-features", type=int, default=None,
                    help="random Fourier features D (cos+sin pairs; "
                         "default 8192)")
    prune_arg = lambda s: s if s in ("auto", "off") else float(s)  # noqa: E731
    ap.add_argument("--prune", type=prune_arg, default=None,
                    help="cluster pruning: 'auto' (exact, epsilon=0, on for "
                         "large sets), 'off' (dense), or a per-point "
                         "contribution epsilon like 1e-9 "
                         "(kernels/spatial.py)")
    ap.add_argument("--plan", default="off", choices=["off", "auto"],
                    help="'auto' resolves unset knobs through the "
                         "repro.plan cost-model planner at fit time")
    ap.add_argument("--accuracy-target", type=float, default=None,
                    help="certified relative-error budget: the planner's "
                         "accuracy request AND the per-query accuracy-"
                         "cascade gate (queries whose RFF band fits are "
                         "answered at the fast tier, the rest escalate)")
    ap.add_argument("--plan-json", metavar="PATH", default=None,
                    help="write the resolved execution plan (request, "
                         "decision, resolved knobs) to PATH")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check a batch against the jnp reference")
    ap.add_argument("--stream", action="store_true",
                    help="register a streaming estimator (repro.stream) and "
                         "interleave appends/evictions with the query "
                         "traffic — the O(n·b·d) delta pass instead of a "
                         "refit per update")
    ap.add_argument("--staleness-budget", type=int, default=None,
                    help="generations a streamed query may lag live "
                         "(stream mode; 0 = always fresh)")
    ap.add_argument("--append-batch", type=int, default=64,
                    help="points per streaming append (stream mode)")
    ap.add_argument("--updates", type=int, default=16,
                    help="append/evict updates interleaved with the "
                         "traffic (stream mode)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through the resilient dispatch layer with "
                         "this many replica engines per shard (>1 enables "
                         "repro.serve.ResilientEngine)")
    ap.add_argument("--shards", type=int, default=2,
                    help="cluster-partitioned shards (resilient mode)")
    ap.add_argument("--chaos", default=None, metavar="MODES",
                    help="comma-separated fault modes to inject "
                         "(shard_kill,slow_shard,compile_fail,nan_poison,"
                         "staleness_blowout,client_burst,admit_stall); "
                         "shard_kill also schedules a sustained kill + "
                         "recovery window")
    ap.add_argument("--deadline-ms", type=float, default=5000.0,
                    help="per-request deadline (resilient and open-loop "
                         "modes)")
    ap.add_argument("--open-loop", action="store_true",
                    help="drive traffic open-loop through the admission "
                         "front end (repro.serve.AsyncFrontend): arrivals "
                         "are paced by --qps, not by answers, so overload "
                         "actually overloads; closed-loop stays the "
                         "default")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="open-loop steady arrival rate in requests/s "
                         "(0 = auto: half the probed capacity)")
    ap.add_argument("--burst", type=float, default=4.0,
                    help="mid-run burst arrival rate, as a multiple of "
                         "the steady --qps (open-loop mode)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="admission queue bound (open-loop mode)")
    ap.add_argument("--expect-shed", action="store_true",
                    help="exit nonzero unless the run shed at least one "
                         "request with a typed Overloaded AND every "
                         "request resolved (the CI overload smoke "
                         "contract)")
    ap.add_argument("--metrics-json", metavar="PATH", default=None,
                    help="write a telemetry document (metrics snapshot, "
                         "Prometheus exposition, trace events if --trace) "
                         "to PATH on exit")
    ap.add_argument("--trace", action="store_true",
                    help="record structured spans for every request "
                         "(repro.obs; also enables jax.profiler "
                         "annotations on real devices)")
    args = ap.parse_args()

    if args.trace:
        obs.configure(trace=True)

    mix = mixture_for_dim(args.d)
    key = jax.random.PRNGKey(args.seed)
    x = mix.sample(key, args.n)
    pool = mix.sample(jax.random.fold_in(key, 1), 4 * args.max_batch)

    # Historical CLI defaults, applied only when the planner is off; under
    # --plan auto an unsupplied knob stays at its ServeConfig default,
    # which the planner reads as "mine to fill".
    cli_defaults = dict(backend="jnp", block_m=32, block_n=512,
                        precision="f32", prune="auto", staleness_budget=2)
    knobs = {}
    for name in cli_defaults:
        v = getattr(args, name)
        if v is None and args.plan == "off":
            v = cli_defaults[name]
        if v is not None:
            knobs[name] = v
    if isinstance(knobs.get("block_n"), int):
        knobs["block_n"] = min(knobs["block_n"], args.n)
    for name in ("rff", "rff_features"):
        v = getattr(args, name)
        if v is not None:
            knobs[name] = v
    cfg = ServeConfig(
        method=args.method, interpret=True,
        min_batch=args.min_batch, max_batch=args.max_batch,
        stream=args.stream, plan=args.plan,
        accuracy_target=args.accuracy_target, **knobs,
    )

    if args.open_loop:
        if args.stream:
            ap.error("--open-loop and --stream are mutually exclusive "
                     "(drive streaming updates closed-loop)")
        _run_open_loop(args, cfg, x, pool)
        return

    if args.replicas > 1 or args.chaos:
        if args.stream:
            ap.error("--replicas/--chaos and --stream are mutually "
                     "exclusive (the resilient layer replicates static "
                     "engines)")
        _run_resilient(args, cfg, x, pool)
        return

    eng = ServeEngine(cfg)

    t0 = time.perf_counter()
    prep = eng.register("traffic", x)
    fit_ms = 1e3 * (time.perf_counter() - t0)
    rcfg = prep.config          # plan-resolved (== cfg when --plan off)
    print(f"registered: backend={rcfg.backend} method={args.method} "
          f"n={args.n} d={args.d} h={prep.h:.4f} precision={rcfg.precision} "
          f"prune={rcfg.prune} "
          f"fit={fit_ms:.0f}ms (debias amortized; never re-run per query)")
    if prep.plan is not None:
        print(f"plan: {prep.plan.plan_id} "
              f"(accuracy target {prep.plan.request.accuracy:g}, modeled "
              f"{prep.plan.modeled_cost_s * 1e6:.0f}us/pass, "
              f"bound {prep.plan.bound})")
    if prep.block_m is not None:
        print(f"launch tiles: block_m={prep.block_m} block_n={prep.block_n}"
              + (" (autotuned)" if "auto" in (args.block_m, args.block_n)
                 else ""))
    print(f"shape buckets: "
          f"{rcfg.bucket_sizes(prep.ring_size, prep.block_m)}")

    if args.plan_json:
        import json

        doc = {
            "request": (prep.plan.request.as_dict()
                        if prep.plan is not None else None),
            "plan": (prep.plan.as_dict()
                     if prep.plan is not None else None),
            "plan_id": (prep.plan.plan_id
                        if prep.plan is not None else None),
            "resolved": {
                "backend": rcfg.backend, "precision": rcfg.precision,
                "prune": rcfg.prune, "block_m": prep.block_m,
                "block_n": prep.block_n,
                "staleness_budget": rcfg.staleness_budget,
                "stream_background": rcfg.stream_background,
            },
        }
        with open(args.plan_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"plan json -> {args.plan_json}")

    # Ragged traffic: log-uniform batch sizes, like real query fan-in.
    rng = np.random.default_rng(args.seed)
    sizes = np.exp(rng.uniform(np.log(1), np.log(args.max_batch),
                               args.requests)).astype(int).clip(1)
    update_every = (max(1, args.requests // max(args.updates, 1))
                    if args.stream else 0)
    # warm the largest bucket
    eng.query(QueryRequest(key="traffic", points=pool[: args.max_batch]))
    eng.latency.reset()
    append_s, n_updates = 0.0, 0
    rff_hits = escalated = 0
    t0 = time.perf_counter()
    for i, m in enumerate(sizes):
        if update_every and i % update_every == 0:
            # sliding-window update: append a fresh batch, evict the
            # oldest as many — the O(n·b·d) delta pass, never a refit
            fresh = mix.sample(jax.random.fold_in(key, 100 + i),
                               args.append_batch)
            ta = time.perf_counter()
            eng.registry.slide("traffic", fresh)
            append_s += time.perf_counter() - ta
            n_updates += 1
        off = int(rng.integers(0, pool.shape[0] - m))
        ans = eng.query(QueryRequest(key="traffic",
                                     points=pool[off:off + m]))
        rff_hits += ans.rff_hits
        escalated += ans.escalated
    wall = time.perf_counter() - t0

    s = eng.latency.summary()
    print(f"served {s.count} requests / {s.queries} queries in {wall:.2f}s: "
          f"{s.queries / wall:.0f} q/s  p50={s.p50_ms:.2f}ms "
          f"p99={s.p99_ms:.2f}ms")
    print(f"bucket cache: {eng.cache.hits} hits / {eng.cache.misses} misses "
          f"/ {eng.cache.evictions} evictions "
          f"({len(eng.cache)} resident executables)")
    if rff_hits or escalated:
        total = rff_hits + escalated
        print(f"cascade: {rff_hits}/{total} query rows answered at the "
              f"RFF tier ({rff_hits / total:.0%}), {escalated} escalated "
              f"to {rcfg.exact_precision}")
    if args.stream and n_updates:
        st = eng.registry.get("traffic").stream
        stale = eng.staleness_summary()
        appends = n_updates * args.append_batch
        print(f"streamed {n_updates} sliding-window updates "
              f"({appends} appends + {appends} evictions) in "
              f"{append_s:.2f}s: {appends / append_s:.0f} appends/s  "
              f"staleness p50={stale.get('p50', 0)} "
              f"p99={stale.get('p99', 0)} (budget "
              f"{rcfg.staleness_budget})  rebuilds={st.rebuilds}"
              + (f" (last: {st.last_rebuild_reason})"
                 if st.rebuilds else ""))

    if args.verify:
        import sys

        yv = pool[:256]
        if args.stream:
            # the engine may legally serve up to staleness_budget
            # generations behind live; force a flush so the verify query
            # and the live-set reference see the same generation
            eng.registry.get("traffic").stream.ensure(0)
        vans = eng.query(QueryRequest(key="traffic", points=yv))
        got = np.asarray(vans.value)
        ref_fn = {"kde": ref.kde_eval, "sdkde": ref.sdkde_eval,
                  "laplace": ref.laplace_kde_eval}[args.method]
        # stream mode: the reference is the *current* live set, not the
        # registered one — the whole point of the updates
        x_ref = (eng.registry.get("traffic").stream.x
                 if args.stream else x)
        want = np.asarray(ref_fn(x_ref, yv, prep.h, block=1024))
        cascaded = vans.rff_hits or rff_hits
        if cascaded:
            # cascade verification: the certified per-row bound must
            # dominate the realized error (flash_rff's tail-floored
            # relative metric), and the fast tier must actually answer
            from repro.kernels import flash_rff

            state = eng.registry.get("traffic").rff.state
            realized = flash_rff.realized_error(got, want, state.p_scale)
            bounds = np.asarray(vans.rel_err_bounds, np.float64)
            worst = float((realized - bounds).max())
            if worst > 1e-6:
                print(f"FAIL: realized error exceeds the certified band "
                      f"by {worst:.2e}", file=sys.stderr)
                sys.exit(1)
            hits = rff_hits + vans.rff_hits
            total = (rff_hits + escalated + vans.rff_hits
                     + vans.escalated)
            if hits == 0:
                print("FAIL: accuracy cascade engaged but zero rows "
                      "resolved at the RFF tier (loosen "
                      "--accuracy-target or raise --rff-features)",
                      file=sys.stderr)
                sys.exit(1)
            print(f"verify: certified bands dominate realized error "
                  f"(worst slack {-worst:.1e}); {hits}/{total} rows "
                  f"({hits / total:.0%}) answered at the RFF tier")
        else:
            # the f32 reference path; reduced tiers verify at their
            # documented accuracy bars (rtol + peak-relative atol for
            # deep-tail densities, see kernels/precision.py)
            tier = rcfg.exact_precision
            rtol = {"f32": 1e-5, "bf16": 5e-2, "bf16x2": 5e-4}[tier]
            atol_frac = {"f32": 1e-6, "bf16": 5e-3, "bf16x2": 1e-5}[tier]
            np.testing.assert_allclose(
                got, want, rtol=rtol,
                atol=atol_frac * float(np.max(np.abs(want))))
            print(f"verify: serve path matches jnp reference "
                  f"(rtol {rtol:g})")

    if args.metrics_json:
        import json

        events = eng.trace_events() if args.trace else []
        doc = {
            "args": {k: v for k, v in vars(args).items()
                     if isinstance(v, (int, float, str, bool, type(None)))},
            "metrics": eng.metrics(),
            "prometheus": obs.prometheus_text(),
            "trace_events": events,
        }
        with open(args.metrics_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        n_metrics = len(doc["metrics"]["registry"])
        print(f"telemetry: {n_metrics} registry metrics"
              + (f", {len(events)} trace events" if args.trace else "")
              + f" -> {args.metrics_json}")


def _run_open_loop(args, cfg, x, pool) -> None:
    """Open-loop traffic through the admission front end.

    Arrivals follow a steady → burst → steady schedule paced by the
    wall clock, NOT by answers — the regime where the admission queue,
    backpressure, and shedding actually engage.  Reports the frontend's
    full shed/brownout ledger; with ``--expect-shed`` (the CI smoke
    contract) exits nonzero unless at least one request was shed with a
    typed ``Overloaded`` and every submitted request resolved.
    """
    import json
    import sys

    from repro.fault_injection import ChaosConfig, FaultInjector
    from repro import fault_injection
    from repro.serve import (AsyncFrontend, FrontendConfig, Overloaded,
                             ResilienceConfig, ResilientEngine, ServeError)

    resilient = args.replicas > 1
    if resilient:
        eng = ResilientEngine(cfg, ResilienceConfig(
            shards=args.shards, replicas=args.replicas,
            deadline_ms=args.deadline_ms, seed=args.seed, backoff_ms=1.0))
    else:
        eng = ServeEngine(cfg)
    t0 = time.perf_counter()
    prep = eng.register("traffic", x)
    h = getattr(prep, "h", None)
    print(f"registered: backend={cfg.backend} method={args.method} "
          f"n={args.n} d={args.d} h={h:.4f} "
          f"fit={1e3 * (time.perf_counter() - t0):.0f}ms"
          + (f" ({args.shards} shards x {args.replicas} replicas)"
             if resilient else ""))
    if args.chaos:
        print(f"chaos: {args.chaos} seed={args.seed}")

    rng = np.random.default_rng(args.seed)
    # warm the buckets the traffic will hit, then probe capacity with a
    # saturated all-at-once window if --qps was not pinned
    for b in cfg.bucket_sizes():
        eng.query(QueryRequest(key="traffic", points=pool[:b]))
    qps = args.qps
    if qps <= 0:
        probe = AsyncFrontend(eng, FrontendConfig(
            workers=1, max_queue=72, default_deadline_ms=60_000.0))
        t0 = time.perf_counter()
        fs = []
        for _ in range(64):
            m = int(rng.integers(1, max(2, args.max_batch // 8)))
            off = int(rng.integers(0, pool.shape[0] - m))
            fs.append(probe.submit(
                QueryRequest(key="traffic", points=pool[off:off + m])))
        probe.drain(timeout=60.0)
        probe.close()
        qps = 0.5 * 64 / (time.perf_counter() - t0)
        print(f"probed capacity: steady qps auto-set to {qps:.0f}")

    injector = None
    if args.chaos and not resilient:
        # installed AFTER the probe so chaos hits the measured run, not
        # the capacity measurement; the resilient engine installs its own
        injector = fault_injection.install(FaultInjector(
            ChaosConfig.from_modes(args.chaos, requests=args.requests,
                                   seed=args.seed)))
    fe = AsyncFrontend(eng, FrontendConfig(
        workers=1, max_queue=args.max_queue,
        default_deadline_ms=args.deadline_ms,
        rate=max(qps, 8.0), p99_slo_ms=args.deadline_ms))
    # steady for the first/last third, --burst x in the middle
    third = max(args.requests // 3, 1)
    futs, shed, answered, expired, degraded, browned = [], 0, 0, 0, 0, 0
    start = time.perf_counter()
    t_next = 0.0
    t0 = time.perf_counter()
    for i in range(args.requests):
        rate = qps * (args.burst if third <= i < 2 * third else 1.0)
        while (now := time.perf_counter() - start) < t_next:
            time.sleep(min(2e-3, t_next - now))
        t_next += 1.0 / rate
        m = int(rng.integers(1, max(2, args.max_batch // 8)))
        off = int(rng.integers(0, pool.shape[0] - m))
        try:
            futs.append(fe.submit(
                QueryRequest(key="traffic", points=pool[off:off + m])))
        except Overloaded:
            shed += 1
    fe.drain(timeout=60.0)
    wall = time.perf_counter() - t0
    unresolved = 0
    for f in futs:
        if not f.done():
            unresolved += 1
        elif f.exception() is None:
            answered += 1
            degraded += int(f.result().degraded)
            browned += int(f.result().browned)
        elif isinstance(f.exception(), Overloaded):
            shed += 1
        elif isinstance(f.exception(), ServeError):
            expired += 1
        else:
            raise f.exception()

    rep = fe.report()
    silent = fe.unaccounted() + unresolved
    print(f"open-loop: {args.requests} arrivals in {wall:.2f}s "
          f"(steady {qps:.0f} rps, burst x{args.burst:g}): "
          f"answered={answered} shed={shed} expired={expired} "
          f"degraded={degraded} browned={browned} silent={silent}")
    print(f"admission: state={rep['state']} "
          f"rejected_by={rep['rejected_by']} "
          f"admit_rate={rep['admit_rate']:.0f} rps "
          f"queue_wait p50={rep['queue_wait_ms']['p50']}ms "
          f"p99={rep['queue_wait_ms']['p99']}ms "
          f"transitions={rep['transitions']}")
    if injector is not None:
        print(f"faults injected: {injector.snapshot()}")

    if args.metrics_json:
        doc = {
            "args": {k: v for k, v in vars(args).items()
                     if isinstance(v, (int, float, str, bool, type(None)))},
            "frontend": rep,
            "outcomes": {"answered": answered, "shed": shed,
                         "expired": expired, "degraded": degraded,
                         "browned": browned, "silent": silent},
            "metrics": obs.metrics_snapshot(),
            "prometheus": obs.prometheus_text(),
            "trace_events": obs.trace_events() if args.trace else [],
        }
        with open(args.metrics_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"telemetry: {len(doc['metrics'])} registry metrics "
              f"-> {args.metrics_json}")

    fe.close()
    if resilient:
        eng.close()
    if injector is not None:
        fault_injection.uninstall()
    if silent:
        print(f"FAIL: {silent} requests without a typed outcome",
              file=sys.stderr)
        sys.exit(1)
    if args.expect_shed and not shed:
        print("FAIL: --expect-shed but the run shed nothing (raise "
              "--burst or lower --max-queue)", file=sys.stderr)
        sys.exit(1)


def _run_resilient(args, cfg, x, pool) -> None:
    """Traffic loop through the resilient dispatch layer (optionally
    under chaos), reporting the full fault-tolerance story: retries,
    hedges, breaker states, fenced/readmitted hosts, degraded answers —
    and a nonzero exit if any query was dropped under chaos."""
    import json
    import sys

    from repro.fault_injection import ChaosConfig
    from repro.serve import (ResilienceConfig, ResilientEngine, ServeError)

    replicas = max(args.replicas, 2)   # chaos without a sibling = drops
    chaos = (ChaosConfig.from_modes(args.chaos, requests=args.requests,
                                    seed=args.seed)
             if args.chaos else None)
    rcfg = ResilienceConfig(
        shards=args.shards, replicas=replicas,
        deadline_ms=args.deadline_ms, seed=args.seed, backoff_ms=1.0,
    )
    eng = ResilientEngine(cfg, rcfg, chaos=chaos)
    t0 = time.perf_counter()
    table = eng.register("traffic", x)
    fit_ms = 1e3 * (time.perf_counter() - t0)
    print(f"registered: backend={cfg.backend} method={args.method} "
          f"n={args.n} d={args.d} h={table.h:.4f} -> "
          f"{table.n_shards} shards x {replicas} replicas "
          f"(shard sizes {table.shard_n}) fit={fit_ms:.0f}ms")
    if chaos is not None:
        active = [m for m in ("shard_kill", "slow_shard", "compile_fail",
                              "nan_poison", "staleness_blowout")
                  if getattr(chaos, m) > 0 or any(
                      e.kind == m for e in chaos.events)]
        windows = [f"{e.kind}@s{e.shard}r{e.replica}[{e.start},{e.stop})"
                   for e in chaos.events]
        print(f"chaos: {','.join(active)} seed={chaos.seed} "
              f"events={windows}")

    rng = np.random.default_rng(args.seed)
    sizes = np.exp(rng.uniform(np.log(1), np.log(args.max_batch),
                               args.requests)).astype(int).clip(1)
    degraded = errors = rff_hits = 0
    t0 = time.perf_counter()
    for m in sizes:
        off = int(rng.integers(0, pool.shape[0] - m))
        try:
            ans = eng.query(QueryRequest(key="traffic",
                                         points=pool[off:off + m]))
            degraded += int(ans.degraded)
            rff_hits += ans.rff_hits
        except ServeError as e:
            errors += 1
            print(f"  shed: {type(e).__name__}: {e}")
    wall = time.perf_counter() - t0

    s = eng.latency.summary()
    st = eng.stats
    print(f"served {s.count} requests / {s.queries} queries in {wall:.2f}s: "
          f"{s.queries / wall:.0f} q/s  p50={s.p50_ms:.2f}ms "
          f"p99={s.p99_ms:.2f}ms")
    print(f"resilience: retries={st['retries']} hedges={st['hedges']} "
          f"(won {st['hedge_wins']}) fenced={st['fenced']} "
          f"probes={st['probes']} readmits={st['readmits']} "
          f"degraded={degraded} shed={st['shed']} "
          f"dropped={st['dropped']}"
          + (f" rff_rows={rff_hits}" if rff_hits else ""))
    open_brk = [k for k, v in eng.breaker_states().items() if v != "closed"]
    if open_brk:
        print(f"breakers not closed: {open_brk}")
    if eng.injector is not None:
        print(f"faults injected: {eng.injector.snapshot()}")

    if args.verify:
        # post-traffic (outside any scheduled chaos window): the resilient
        # answer must match the full-data reference exactly — and must NOT
        # be degraded, so disallow uncertified fallbacks here
        yv = pool[:256]
        ans = eng.query(QueryRequest(key="traffic", points=yv,
                                     allow_degraded=False,
                                     deadline_s=60.0))
        ref_fn = {"kde": ref.kde_eval, "sdkde": ref.sdkde_eval,
                  "laplace": ref.laplace_kde_eval}[args.method]
        want = np.asarray(ref_fn(x, yv, table.h, block=1024))
        rtol = {"f32": 1e-5, "bf16": 5e-2,
                "bf16x2": 5e-4}[cfg.exact_precision]
        np.testing.assert_allclose(
            np.asarray(ans.value), want, rtol=rtol,
            atol=1e-6 * float(np.max(np.abs(want))))
        print(f"verify: resilient path matches full-data jnp reference "
              f"(rtol {rtol:g})")

    if args.metrics_json:
        doc = {
            "args": {k: v for k, v in vars(args).items()
                     if isinstance(v, (int, float, str, bool, type(None)))},
            "metrics": eng.metrics(),
            "prometheus": obs.prometheus_text(),
            "trace_events": obs.trace_events() if args.trace else [],
        }
        with open(args.metrics_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"telemetry: {len(doc['metrics']['registry'])} registry "
              f"metrics -> {args.metrics_json}")

    eng.close()
    if st["dropped"]:
        print(f"FAIL: {st['dropped']} dropped queries under "
              f"{'chaos' if chaos else 'steady state'}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
