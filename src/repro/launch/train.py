"""Training driver: the end-to-end loop with the production substrate.

Runs the same step-program the dry-run lowers, with the full supervision
stack wired in:

  * deterministic host-sharded data (data/synthetic.py) + prefetch,
  * periodic ASYNC checkpointing + restore-on-restart (checkpoint/),
  * failure injection (--inject-failure N kills the loop at step N and
    proves restart-from-checkpoint resumes bit-exact),
  * elastic restart (--elastic simulates losing a host: the mesh is
    re-planned, state resharded through checkpoint restore),
  * straggler-aware step loop (EWMA step times feed the Supervisor).

On this CPU container use --reduced (default) for a real optimization run
of the reduced config; the full configs are exercised by the dry-run.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2_2b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch hymba_1p5b --steps 60 \\
      --inject-failure 25 --ckpt-every 10
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ShapeCfg, get_arch
from repro.data.synthetic import lm_batch
from repro.distributed.elastic import make_mesh, plan_mesh
from repro.distributed.fault import Supervisor
from repro.launch.steps import (
    abstract_opt_state,
    abstract_params,
    make_train_step,
)
from repro.models.common import init_params, param_count
from repro.optim.adafactor import adafactor_init
from repro.optim.adamw import adamw_init


def shaped_batch(cfg, seed, step, shape: ShapeCfg):
    """(microbatches, mb, ...) batch matching abstract_train_batch layout."""
    b = lm_batch(cfg, seed, step, shape.global_batch, shape.seq_len)
    nmb = shape.microbatches
    mb = shape.global_batch // nmb
    return {
        k: v.reshape(nmb, mb, *v.shape[1:]) for k, v in b.items()
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-failure", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = dataclasses.replace(
            arch, model=arch.model.reduced(dtype=jnp.float32)
        )
    cfg = arch.model
    print(f"arch={arch.arch_id} params={param_count(cfg)/1e6:.2f}M "
          f"optimizer={arch.optimizer}")

    plan = plan_mesh(len(jax.devices()),
                     model_parallel=min(2, len(jax.devices())))
    mesh = make_mesh(plan)
    print(f"mesh: {plan.shape} {plan.axes} {plan.note}")

    shape = ShapeCfg("train", "train", args.seq, args.global_batch,
                     microbatches=args.microbatches)
    step_fn, abstract, donate = make_train_step(arch, mesh, shape)
    jitted = jax.jit(step_fn, donate_argnums=donate)

    # -- init or restore -----------------------------------------------------
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    params = None
    if ckpt and ckpt.latest_step() is not None:
        shardings = {
            "params": jax.tree.map(lambda a: a.sharding,
                                   abstract_params(cfg, mesh)),
            "opt": jax.tree.map(lambda a: a.sharding,
                                abstract_opt_state(arch, mesh)),
        }
        state = ckpt.restore(shardings)
        params, opt_state = state["params"], state["opt"]
        start_step = ckpt.latest_step()
        print(f"restored checkpoint at step {start_step}")
    if params is None:
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = (adafactor_init(params) if arch.optimizer == "adafactor"
                     else adamw_init(params))

    # -- loop -----------------------------------------------------------------
    sup = Supervisor(1, timeout=3600.0)
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        if step == args.inject_failure:
            print(f"!! injected failure at step {step} — restart to resume "
                  f"(rerun the same command)")
            raise SystemExit(42)
        batch = shaped_batch(cfg, args.seed, step, shape)
        t0 = time.time()
        params, opt_state, metrics = jitted(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        sup.beat(0, step)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({time.time()-t0:.2f}s/step)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses[-1]), "non-finite loss"


if __name__ == "__main__":
    main()
