import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

The two lines above run before ANY other import (jax locks the device count
on first init): the dry-run — and only the dry-run — sees 512 placeholder
host devices so ``jax.make_mesh`` can build the production meshes.

For every cell we:
  1. build (step_fn, abstract_inputs) via launch/steps.py,
  2. ``jax.jit(fn, donate_argnums=...).lower(*abstract)`` →  ``.compile()``,
  3. print ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. parse collective bytes from the HLO and emit the three roofline terms,
  5. append a JSON record to ``results/dryrun_<mesh>.json``.

Usage:
  python -m repro.launch.dryrun                       # every cell, both meshes
  python -m repro.launch.dryrun --arch gemma2_2b      # one arch
  python -m repro.launch.dryrun --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch flash_sdkde_1m # paper workload cells
"""

import argparse
import json
import time
import traceback

import jax

from repro.analysis.flops import model_flops, sdkde_flops
from repro.analysis.roofline import roofline_from_compiled
from repro.configs import (
    KDE_WORKLOADS,
    LM_SHAPES,
    SHAPES,
    get_arch,
    list_archs,
)
from repro.launch.mesh import make_production_mesh, mesh_desc
from repro.launch.steps import build_cell, make_kde_step


def run_cell(arch_id: str, shape_name: str, mesh, *, verbose: bool = True):
    """Lower+compile one cell; returns the roofline record dict."""
    chips = mesh.devices.size
    t0 = time.time()

    if arch_id in KDE_WORKLOADS:
        wl = KDE_WORKLOADS[arch_id]
        fn, abstract, donate = make_kde_step(wl, mesh)
        mf = sdkde_flops(wl.n_train, wl.dim, n_test=wl.n_test)
        shape_name = f"{wl.n_train}x{wl.n_test}xd{wl.dim}"
    else:
        arch = get_arch(arch_id)
        shape = SHAPES[shape_name]
        skip = arch.shape_applicable(shape)
        if skip:
            return {
                "arch": arch_id, "shape": shape_name,
                "mesh": mesh_desc(mesh), "status": "skip", "reason": skip,
            }
        fn, abstract, donate = build_cell(arch, shape, mesh)
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops(arch.model, tokens, training=True)
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops(arch.model, tokens, training=False)
        else:  # decode: one token per sequence
            mf = model_flops(arch.model, shape.global_batch, training=False)

    lowered = jax.jit(fn, donate_argnums=donate).lower(*abstract)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]

    terms = roofline_from_compiled(
        compiled,
        arch=arch_id,
        shape=shape_name,
        mesh_desc=mesh_desc(mesh),
        chips=chips,
        model_flops=mf,
    )
    rec = terms.row()
    rec["status"] = "ok"
    rec["compile_s"] = time.time() - t0
    rec["memory_analysis"] = str(mem)
    rec["collectives"] = terms.collective_detail

    if verbose:
        print(f"== {arch_id} / {shape_name} @ {mesh_desc(mesh)} ==")
        print(f"   memory_analysis: {mem}")
        print(
            "   cost_analysis: flops/device=%.3e bytes/device=%.3e"
            % (rec["hlo_flops"], rec["hlo_bytes"])
        )
        print(
            "   roofline: t_comp=%.2fms t_mem=%.2fms t_coll=%.2fms"
            " bound=%s MFU@roofline=%.1f%% useful=%.2f"
            % (
                rec["t_compute_s"] * 1e3,
                rec["t_memory_s"] * 1e3,
                rec["t_collective_s"] * 1e3,
                rec["bound"],
                rec["mfu"] * 100,
                rec["useful_ratio"],
            )
        )
        print(f"   compile took {rec['compile_s']:.1f}s")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, kde workload id, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    if args.arch == "all":
        arch_ids = list(list_archs()) + list(KDE_WORKLOADS)
    else:
        arch_ids = [args.arch]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        records = []
        for arch_id in arch_ids:
            if arch_id in KDE_WORKLOADS:
                shape_names = ["paper"]
            elif args.shape == "all":
                shape_names = [s.name for s in LM_SHAPES]
            else:
                shape_names = [args.shape]
            for shape_name in shape_names:
                try:
                    rec = run_cell(arch_id, shape_name, mesh)
                    records.append(rec)
                    if rec["status"] == "ok":
                        n_ok += 1
                    else:
                        n_skip += 1
                        print(f"-- skip {arch_id}/{shape_name}: {rec['reason']}")
                except Exception as e:  # a failure here is a bug in the system
                    n_fail += 1
                    traceback.print_exc()
                    records.append({
                        "arch": arch_id, "shape": shape_name,
                        "mesh": mesh_desc(mesh), "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                    })
        path = os.path.join(args.out, f"dryrun_{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {path} ({len(records)} cells)")
    print(f"DONE: {n_ok} ok, {n_skip} skips, {n_fail} FAILURES")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
