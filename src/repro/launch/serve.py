"""Serving driver: batched prefill + decode with the serve_step program.

Demonstrates the inference path end-to-end on CPU (reduced configs):
prefill a batch of prompts (building the KV/SSM cache), then greedy-decode
N tokens per sequence with the single-token serve_step, reporting decode
throughput.  The decode program is the same one the decode_32k / long_500k
dry-run cells lower at production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon_mamba_7b \\
      --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.synthetic import lm_batch
from repro.models.common import init_params, param_count
from repro.models.transformer import decode_step, init_cache, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--monitor", action="store_true",
                    help="SD-KDE activation-density OOD monitor (§4.3)")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    arch = dataclasses.replace(arch, model=arch.model.reduced(dtype=jnp.float32))
    cfg = arch.model
    print(f"arch={arch.arch_id} params={param_count(cfg)/1e6:.2f}M")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    batch = lm_batch(cfg, args.seed, 0, args.batch, args.prompt_len)
    max_len = args.prompt_len + args.gen + (
        cfg.n_patches if cfg.family == "vlm" else 0
    )

    # Prefill: build a max_len cache, copy the prompt K/V in.
    t0 = time.time()
    logits, pcache = jax.jit(
        lambda p, b: prefill(p, b["tokens"], cfg,
                             patches=b.get("patches"),
                             frames=b.get("frames"))
    )(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms")

    cache = init_cache(cfg, args.batch, max_len)
    for k in pcache:
        if k in ("pos",):
            continue
        if k in ("conv", "ssm"):
            cache[k] = pcache[k]
        else:  # kv-like: (L, B, S, H, hd) -> left-aligned into max_len
            s = pcache[k].shape[2]
            cache[k] = jax.lax.dynamic_update_slice(
                cache[k], pcache[k].astype(cache[k].dtype), (0, 0, 0, 0, 0)
            )
    cache["pos"] = pcache["pos"]

    step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg),
                   donate_argnums=(1,))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"decode: {args.gen} steps x batch {args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")

    if args.monitor:
        # SD-KDE over pooled hidden states: flag OOD requests at serve time.
        from repro.core.monitor import ActivationMonitor, pool_activations
        from repro.models.transformer import forward_hidden

        def acts(tokens):
            h, _ = forward_hidden(params, tokens, cfg)
            return pool_activations(h)

        ref = jnp.concatenate([
            acts(lm_batch(cfg, args.seed, s, 16, args.prompt_len)["tokens"])
            for s in range(8)
        ])
        mon = ActivationMonitor(proj_dim=8, quantile=0.02).fit(ref)
        flags = np.asarray(mon.flag(acts(batch["tokens"])))
        print(f"monitor: {int(flags.sum())}/{args.batch} requests flagged "
              f"OOD (in-distribution traffic)")
    print(f"sample generations (token ids):")
    for row in gen[: min(2, args.batch)]:
        print("  ", row[:16].tolist(), "...")
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"


if __name__ == "__main__":
    main()
