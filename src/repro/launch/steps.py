"""Step-program builders: the compiled units behind train / serve / dry-run.

For every (architecture × shape) cell this module produces
``(step_fn, abstract_inputs, donate_argnums)`` where ``abstract_inputs`` are
ShapeDtypeStructs carrying NamedShardings — ``jax.jit(fn).lower(*abstract)``
is exactly the multi-pod dry-run, and the same builders feed the real
train/serve drivers with concrete arrays.

Sharding policy (see DESIGN.md §6):
  * params        — Megatron TP over ``model`` (models/common.param_shapes);
                    kimi additionally 2-D-shards experts over ``data``.
  * optimizer     — ZeRO-1: master/moments extend the param spec over
                    (pod, data) where a dim divides.
  * train batch   — (microbatches, global/mb, S) with the batch dim over
                    (pod, data); accumulation scans the leading axis.
  * prefill batch — (B, S) batch over (pod, data).
  * decode cache  — batch over (pod, data) when divisible (decode_32k),
                    else the 524k cache SEQUENCE is sharded over
                    (pod, data) and heads over ``model`` (long_500k, B=1).
  * SD-KDE        — 2-D ring decomposition (distributed/ring2d.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, KdeWorkload, ShapeCfg
from repro.data.synthetic import batch_pspecs
from repro.launch.mesh import batch_axes
from repro.models.common import ModelConfig, param_shapes
from repro.models.transformer import (
    cache_spec,
    decode_step,
    loss_fn,
    prefill,
)
from repro.optim.adafactor import adafactor_state_pspecs, adafactor_update
from repro.optim.adamw import AdamWConfig, adamw_update, opt_state_pspecs
from repro.optim.clipping import clip_by_global_norm
from repro.optim.schedules import cosine_schedule


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return batch_axes(mesh)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def abstract_from_pspecs(shapes_dtypes, pspecs, mesh: Mesh):
    """pytree of (shape, dtype) + pytree of P -> pytree of ShapeDtypeStruct."""
    return jax.tree.map(
        lambda sd, spec: jax.ShapeDtypeStruct(
            sd[0], sd[1], sharding=_named(mesh, spec)
        ),
        shapes_dtypes,
        pspecs,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple),
    )


# ---------------------------------------------------------------------------
# Parameter / optimizer abstract state.
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, mesh: Mesh) -> Dict[str, jax.ShapeDtypeStruct]:
    return {
        name: jax.ShapeDtypeStruct(shape, dt, sharding=_named(mesh, spec))
        for name, (shape, dt, spec) in param_shapes(cfg).items()
    }


def abstract_opt_state(arch: ArchSpec, mesh: Mesh):
    cfg = arch.model
    shapes = param_shapes(cfg)
    dp_ax = _dp_axes(mesh)
    axis = dp_ax if len(dp_ax) > 1 else dp_ax[0]
    dp = _dp_size(mesh)

    if arch.optimizer == "adafactor":
        specs = adafactor_state_pspecs(shapes, dp, axis=axis)
        out: Dict[str, Any] = {
            "step": jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=_named(mesh, P())),
            "master": {}, "v": {},
        }
        for name, (shape, _, _) in shapes.items():
            out["master"][name] = jax.ShapeDtypeStruct(
                shape, jnp.float32,
                sharding=_named(mesh, specs["master"][name]),
            )
            vspec = specs["v"][name]
            if "vr" in vspec:
                out["v"][name] = {
                    "vr": jax.ShapeDtypeStruct(
                        shape[:-1], jnp.float32,
                        sharding=_named(mesh, vspec["vr"])),
                    "vc": jax.ShapeDtypeStruct(
                        shape[:-2] + shape[-1:], jnp.float32,
                        sharding=_named(mesh, vspec["vc"])),
                }
            else:
                out["v"][name] = {
                    "v": jax.ShapeDtypeStruct(
                        shape, jnp.float32,
                        sharding=_named(mesh, vspec["v"])),
                }
        return out

    specs = opt_state_pspecs(shapes, dp, axis=axis)
    out = {
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=_named(mesh, P())),
        "master": {}, "mu": {}, "nu": {},
    }
    for name, (shape, _, _) in shapes.items():
        for part in ("master", "mu", "nu"):
            out[part][name] = jax.ShapeDtypeStruct(
                shape, jnp.float32,
                sharding=_named(mesh, specs[part][name]),
            )
    return out


# ---------------------------------------------------------------------------
# Train step.
# ---------------------------------------------------------------------------


def make_train_step(arch: ArchSpec, mesh: Mesh, shape: ShapeCfg, *,
                    peak_lr: float = 3e-4, warmup: int = 2000,
                    total_steps: int = 100_000):
    """Returns (train_step, abstract_inputs, donate_argnums).

    train_step(params, opt_state, batch) -> (params', opt_state', metrics).
    Gradient accumulation scans the leading microbatch axis; the optimizer
    update happens once per global step (grads are reduced by GSPMD across
    (pod, data) automatically through the loss mean).
    """
    cfg = arch.model
    accum_dtype = jnp.dtype(arch.accum_dtype)
    use_adafactor = arch.optimizer == "adafactor"

    def train_step(params, opt_state, batch):
        def micro_loss(p, mb):
            return loss_fn(p, mb, cfg)

        def accum_body(acc, mb):
            g_acc, loss_acc = acc
            loss, g = jax.value_and_grad(micro_loss)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), g_acc, g
            )
            return (g_acc, loss_acc + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params
        )
        (grads, loss_sum), _ = jax.lax.scan(
            accum_body, (zeros, jnp.float32(0.0)), batch
        )
        nmb = shape.microbatches
        grads = jax.tree.map(lambda g: g / nmb, grads)
        loss = loss_sum / nmb

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = cosine_schedule(opt_state["step"], peak_lr, warmup, total_steps)
        if use_adafactor:
            new_params, new_state = adafactor_update(
                grads, opt_state, params, lr
            )
        else:
            new_params, new_state = adamw_update(
                grads, opt_state, params, lr, AdamWConfig()
            )
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    abstract = (
        abstract_params(cfg, mesh),
        abstract_opt_state(arch, mesh),
        abstract_train_batch(cfg, mesh, shape),
    )
    return train_step, abstract, (0, 1)


def abstract_train_batch(cfg: ModelConfig, mesh: Mesh, shape: ShapeCfg):
    """(microbatches, global/mb, ...) inputs, batch dim over (pod, data).

    Generated pre-split by the loader so no resharding is needed between
    accumulation steps (data/synthetic.py produces the same layout).
    """
    dp_ax = _dp_axes(mesh)
    nmb = shape.microbatches
    assert shape.global_batch % nmb == 0
    mb = shape.global_batch // nmb
    assert mb % _dp_size(mesh) == 0, (
        f"microbatch {mb} not divisible by dp={_dp_size(mesh)}"
    )
    out = {
        "tokens": jax.ShapeDtypeStruct(
            (nmb, mb, shape.seq_len), jnp.int32,
            sharding=_named(mesh, P(None, dp_ax, None)),
        )
    }
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (nmb, mb, cfg.n_patches, cfg.d_model), cfg.dtype,
            sharding=_named(mesh, P(None, dp_ax, None, None)),
        )
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (nmb, mb, cfg.enc_frames, cfg.d_model), cfg.dtype,
            sharding=_named(mesh, P(None, dp_ax, None, None)),
        )
    return out


# ---------------------------------------------------------------------------
# Prefill step.
# ---------------------------------------------------------------------------


def make_prefill_step(arch: ArchSpec, mesh: Mesh, shape: ShapeCfg):
    cfg = arch.model

    def prefill_step(params, batch):
        return prefill(
            params, batch["tokens"], cfg,
            patches=batch.get("patches"), frames=batch.get("frames"),
        )

    dp_ax = _dp_axes(mesh)
    assert shape.global_batch % _dp_size(mesh) == 0
    batch = {
        "tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32,
            sharding=_named(mesh, P(dp_ax, None)),
        )
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.n_patches, cfg.d_model), cfg.dtype,
            sharding=_named(mesh, P(dp_ax, None, None)),
        )
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.enc_frames, cfg.d_model), cfg.dtype,
            sharding=_named(mesh, P(dp_ax, None, None)),
        )
    abstract = (abstract_params(cfg, mesh), batch)
    return prefill_step, abstract, ()


# ---------------------------------------------------------------------------
# Decode step.
# ---------------------------------------------------------------------------


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch: int,
                 seq_len: int) -> Dict[str, P]:
    """Decode-cache shardings (explicit NamedShardings must divide evenly).

    decode_32k (batch ≥ dp): batch over (pod, data); KV heads over ``model``
    when n_kv_heads divides it, otherwise the cache SEQUENCE is split over
    ``model`` (flash-decoding-style split-KV — GQA configs with 2–8 KV
    heads can't use 16-way head parallelism).
    long_500k (batch=1): the sequence axis carries ALL the parallelism —
    KV seq over every mesh axis; SSM states shard d_inner over ``model``.
    """
    mp = mesh.shape["model"]
    dp_ax = _dp_axes(mesh)
    all_ax = tuple(mesh.axis_names)
    batch_sharded = batch % _dp_size(mesh) == 0
    kv_heads_ok = cfg.n_kv_heads % mp == 0

    if batch_sharded:
        b = dp_ax
        if kv_heads_ok:
            kv = P(None, b, None, "model", None)
        elif seq_len % mp == 0:
            kv = P(None, b, "model", None, None)
        else:
            kv = P(None, b, None, None, None)
    else:
        b = None
        seq_ax = all_ax if seq_len % mesh.devices.size == 0 else dp_ax
        kv = P(None, None, seq_ax, None, None)

    specs: Dict[str, P] = {}
    if not cfg.attn_free:
        specs["k"] = specs["v"] = kv
        if cfg.kv_quant:
            # int8 scales: (L, B, S, Hkv) — the kv spec minus the head-dim
            specs["k_scale"] = specs["v_scale"] = P(*list(kv)[:-1])
    if cfg.family in ("ssm", "hybrid"):
        specs["conv"] = P(None, b, None, "model")
        specs["ssm"] = P(None, b, "model", None)
    if cfg.family == "audio":
        # cross-attn cache: enc_frames (1500) and 20 heads don't divide the
        # model axis — batch sharding only.
        specs["xk"] = specs["xv"] = P(None, b, None, None, None)
    specs["pos"] = P()
    return specs


def make_decode_step(arch: ArchSpec, mesh: Mesh, shape: ShapeCfg):
    """serve_step: ONE new token against a seq_len cache (decode_* cells)."""
    cfg = arch.model

    def serve_step(params, cache, tokens):
        return decode_step(params, cache, tokens, cfg)

    b = shape.global_batch
    specs = cache_pspecs(cfg, mesh, b, shape.seq_len)
    cache_abstract: Dict[str, Any] = {}
    for name, (shp, dt) in cache_spec(cfg, b, shape.seq_len).items():
        cache_abstract[name] = jax.ShapeDtypeStruct(
            shp, dt, sharding=_named(mesh, specs[name])
        )
    cache_abstract["pos"] = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=_named(mesh, P())
    )
    dp_ax = _dp_axes(mesh)
    tok_spec = P(dp_ax, None) if b % _dp_size(mesh) == 0 else P(None, None)
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=_named(mesh, tok_spec)
    )
    abstract = (abstract_params(cfg, mesh), cache_abstract, tokens)
    return serve_step, abstract, (1,)


# ---------------------------------------------------------------------------
# SD-KDE cells (the paper's own workloads on the production mesh).
# ---------------------------------------------------------------------------


def make_kde_step(workload: KdeWorkload, mesh: Mesh, *, chunk: int = 2048):
    from repro.distributed.ring2d import kde_input_specs, ring2d_sdkde

    h = 0.2  # bandwidth enters as a traced constant; value is irrelevant
    # to lowering/roofline (same program for any h > 0)

    def kde_step(x, y):
        return ring2d_sdkde(x, y, h, mesh=mesh, chunk=chunk)

    x_spec, y_spec = kde_input_specs(
        workload.n_train, workload.n_test, workload.dim, mesh
    )
    return kde_step, (x_spec, y_spec), ()


# ---------------------------------------------------------------------------
# Cell dispatch (the dry-run's entry point).
# ---------------------------------------------------------------------------


def build_cell(arch: ArchSpec, shape: ShapeCfg, mesh: Mesh):
    # Register the mesh for the MoE shard-local dispatch, the attention
    # sharding hints (train/prefill), and the weights-stationary MoE decode
    # path (trace-time global; see models/parallel.py, models/moe.py).
    from repro.models.parallel import set_mesh

    set_mesh(mesh)
    if shape.kind == "train":
        if arch.train_microbatches:
            import dataclasses

            shape = dataclasses.replace(
                shape, microbatches=arch.train_microbatches
            )
        return make_train_step(arch, mesh, shape)
    if shape.kind == "prefill":
        return make_prefill_step(arch, mesh, shape)
    if shape.kind == "decode":
        return make_decode_step(arch, mesh, shape)
    raise ValueError(shape.kind)


def input_specs(arch_or_kde, shape: Optional[ShapeCfg], mesh: Mesh):
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, sharded, no device allocation (brief §dry-run.2)."""
    if isinstance(arch_or_kde, KdeWorkload):
        return make_kde_step(arch_or_kde, mesh)[1]
    return build_cell(arch_or_kde, shape, mesh)[1]
