"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init and
smoke tests must keep seeing one device.

Single pod:  (16, 16)      axes (data, model)          — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes (pod, data, model)     — 512 chips

Batch (and SD-KDE point rows) shard over (pod, data); tensor-parallel
weights over model.  All cross-pod traffic rides the slower inter-pod links
→ the ring schedules in distributed/ring.py and the gradient all-reduce are
laid out so per-pod reductions happen first (GSPMD emits hierarchical
all-reduces for the nested (pod, data) spec).
"""

from __future__ import annotations

from repro.distributed.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """The axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_desc(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names) + (
        f" ({','.join(mesh.axis_names)})"
    )
