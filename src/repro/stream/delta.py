"""The delta score pass: incremental (S0, S1) maintenance.

SD-KDE's debias shift of point i is a function of the score statistics

    S0_i = Σ_j φ(x_i, x_j)        S1_i = Σ_j φ(x_i, x_j) · x_j

over the *whole* live set — so appending or evicting points perturbs every
other point's statistics, and a naive refresh is the full O(n²·d) pass the
streaming layer exists to avoid.  But the perturbation is a *sum of the
changed points' contributions*: an append adds ``Σ_{b∈batch} φ(x_i, b)``
to S0_i (one O(n·b·d) cross GEMM), an eviction subtracts the same terms.

Two numeric choices make the incremental stats track a from-scratch pass:

  * **φ in f32, exactly as the dense pass computes it** — GEMM-form
    distances with the norm trick, clamped at 0 — so each individual term
    matches the refit's to f32 rounding.
  * **accumulation in float64** — the running S0/S1 live in f64, so a long
    interleaving of ``+=`` / ``-=`` cancels to f64 rounding instead of
    compounding f32 error, and an append-then-evict round trip restores
    the statistics to ~1e-16 relative.

Everything here is also the basis of ``core.estimator.SDKDE.append`` — the
offline face of the same math.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _phi_cross(a: jnp.ndarray, b: jnp.ndarray, inv2h2) -> jnp.ndarray:
    """f32 kernel weights φ(a_i, b_j), GEMM-form (matches the dense pass)."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    an = jnp.sum(a * a, axis=-1)[:, None]
    bn = jnp.sum(b * b, axis=-1)[None, :]
    sq = jnp.maximum(an + bn - 2.0 * (a @ b.T), 0.0)
    return jnp.exp(-sq * inv2h2)


def cross_stats(
    a: np.ndarray,
    b: np.ndarray,
    sh: float,
    *,
    block: int = 4096,
) -> Tuple[np.ndarray, np.ndarray]:
    """(ΔS0, ΔS1): the contributions of point set ``b`` to ``a``'s stats.

    Returns float64 ``(len(a),)`` and ``(len(a), d)`` arrays, f64-summed
    from f32 kernel weights.  Blocked on both axes so the φ working set
    stays ≤ block² regardless of how large either side is.
    """
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    na, d = a.shape
    inv2h2 = jnp.float32(1.0 / (2.0 * float(sh) ** 2))
    s0 = np.zeros(na, np.float64)
    s1 = np.zeros((na, d), np.float64)
    for i in range(0, na, block):
        ai = a[i:i + block]
        for j in range(0, b.shape[0], block):
            bj = b[j:j + block]
            phi = np.asarray(_phi_cross(ai, bj, inv2h2), np.float64)
            s0[i:i + block] += phi.sum(axis=1)
            s1[i:i + block] += phi @ bj.astype(np.float64)
    return s0, s1


def initial_stats(
    x: np.ndarray, sh: float, *, block: int = 4096
) -> Tuple[np.ndarray, np.ndarray]:
    """Full (S0, S1) of a point set against itself (the stream's one full
    pass, at fit time — every later update is a delta)."""
    return cross_stats(x, x, sh, block=block)


def append_delta(
    x_live: np.ndarray,
    x_new: np.ndarray,
    sh: float,
    *,
    block: int = 4096,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stat updates for appending ``x_new`` to a live set ``x_live``.

    Returns ``(ds0_live, ds1_live, s0_new, s1_new)``: the deltas to *add*
    to the existing points' statistics, and the new points' own full
    statistics over the post-append set (existing + batch, including the
    within-batch and self terms φ=1 — exactly the terms a from-scratch
    pass over the grown set would include).
    """
    ds0, ds1 = cross_stats(x_live, x_new, sh, block=block)
    s0_new_a, s1_new_a = cross_stats(x_new, x_live, sh, block=block)
    s0_new_b, s1_new_b = cross_stats(x_new, x_new, sh, block=block)
    return ds0, ds1, s0_new_a + s0_new_b, s1_new_a + s1_new_b


def evict_delta(
    x_keep: np.ndarray,
    x_out: np.ndarray,
    sh: float,
    *,
    block: int = 4096,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stat updates for evicting ``x_out``: the deltas to *subtract* from
    the kept points' statistics (the evicted rows' stats are dropped)."""
    return cross_stats(x_keep, x_out, sh, block=block)


def apply_shift(
    x: np.ndarray,
    s0: np.ndarray,
    s1: np.ndarray,
    h: float,
    sh: float,
) -> np.ndarray:
    """f64 debiased positions x^SD = x + (h²/2)·(S1 − x·S0)/(sh²·S0).

    Same formula as ``kernels.ops._apply_score_shift``; f64 end to end so
    a point whose statistics did not change reproduces its previous
    position bit-for-bit (the streaming layer's clean-tile invariant).
    """
    x64 = np.asarray(x, np.float64)
    s0c = s0[:, None]
    score = (s1 - x64 * s0c) / (float(sh) ** 2 * s0c)
    return x64 + 0.5 * float(h) ** 2 * score


__all__ = [
    "cross_stats", "initial_stats", "append_delta", "evict_delta",
    "apply_shift",
]
