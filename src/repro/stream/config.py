"""Streaming configuration and the rebuild policy.

A streaming estimator degrades as it drifts from its last full build:
appends pile into nearest-centroid clusters (inflating covering radii and
with them every certified pruning bound), evictions hollow tiles out, and
eventually some cluster's slack slots run dry.  ``StreamConfig`` sets the
budgets; ``RebuildPolicy`` turns the drift counters into a single
"re-cluster now" decision with a human-readable reason (surfaced in
telemetry and tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static knobs of a streaming estimator (hashable, like ServeConfig).

    ``slack`` is the per-cluster append headroom fraction reserved at every
    (re)build — ``ceil(cluster_size · slack)`` extra sentinel slots before
    block rounding (``kernels.spatial.cluster_capacities``).  ``staleness_
    budget`` is how many applied-but-unpublished update generations a query
    may be served across before the engine must publish a fresh snapshot;
    0 = always fresh.  ``background=True`` publishes snapshots on a worker
    thread so queries keep serving generation ``g`` while ``g+1`` builds.
    """

    slack: float = 0.5              # per-cluster append headroom fraction
    staleness_budget: int = 0       # generations a query may lag (0 = fresh)
    background: bool = False        # build snapshots on a worker thread
    delta_block: int = 4096         # GEMM chunk of the delta score pass
    # rebuild policy budgets (fractions of the live-set size at last build)
    max_append_frac: float = 0.5
    max_evict_frac: float = 0.5
    #: Rebuild when the mean covering radius of non-empty tiles exceeds
    #: this multiple of its value at the last build — radius inflation is
    #: exactly what loosens every certified pruning bound, so this is the
    #: "certified error drifted past the epsilon budget" trigger.
    max_radius_inflation: float = 2.0

    def __post_init__(self):
        if self.slack < 0:
            raise ValueError(f"slack must be >= 0, got {self.slack}")
        if self.staleness_budget < 0:
            raise ValueError("staleness_budget must be >= 0")
        if self.delta_block < 1:
            raise ValueError("delta_block must be >= 1")
        for f in ("max_append_frac", "max_evict_frac"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be > 0")
        if self.max_radius_inflation <= 1.0:
            raise ValueError("max_radius_inflation must be > 1")


class RebuildPolicy:
    """Decides when incremental maintenance must give way to a full build.

    Tracks drift since the last re-cluster; ``reason()`` returns why a
    rebuild is due (``None`` = keep streaming).  Slack overflow is sticky:
    once an append found no free slot the layout *cannot* represent the
    live set and the next snapshot must rebuild regardless of budgets.
    """

    def __init__(self, config: StreamConfig):
        self.config = config
        self.reset(0)

    def reset(self, base_size: int) -> None:
        self.base_size = max(int(base_size), 1)
        self.appends = 0
        self.evicts = 0
        self.base_mean_radius: Optional[float] = None
        self.overflowed = False

    def note_append(self, count: int) -> None:
        self.appends += int(count)

    def note_evict(self, count: int) -> None:
        self.evicts += int(count)

    def note_overflow(self) -> None:
        self.overflowed = True

    def note_mean_radius(self, mean_radius: float) -> Optional[str]:
        """Feed the post-refresh tile geometry; returns a drift reason."""
        if self.base_mean_radius is None:
            self.base_mean_radius = float(mean_radius)
            return None
        if (self.base_mean_radius > 0.0
                and mean_radius > self.config.max_radius_inflation
                * self.base_mean_radius):
            return "radius-drift"
        return None

    def reason(self) -> Optional[str]:
        cfg = self.config
        if self.overflowed:
            return "slack-overflow"
        if self.appends > cfg.max_append_frac * self.base_size:
            return "append-budget"
        if self.evicts > cfg.max_evict_frac * self.base_size:
            return "evict-budget"
        return None


__all__ = ["StreamConfig", "RebuildPolicy"]
