"""Incremental SD-KDE: append / evict / sliding-window without a refit.

A ``StreamingSDKDE`` holds a *live set* of train points whose score
statistics (S0, S1) are maintained incrementally (``stream.delta``): an
append or eviction costs one O(n·b·d) cross GEMM instead of the O(n²·d)
debias pass a from-scratch refit pays, and the debiased positions of every
live point are recomputed from the maintained statistics — so after any
interleaving of updates the served densities match a full refit to float
tolerance (tested at 1e-5 relative).

The Pallas serving layout is maintained in place between *rebuilds*:

  * appends are assigned to the existing clusters (``spatial.assign``) and
    claim per-cluster **slack slots** reserved inside the sentinel-padded
    layout (``spatial.cluster_capacities(slack=…)``) — the layout's shape,
    and with it every compiled bucket executable, survives the update;
  * evictions turn their slots back into sentinels;
  * only the **dirty tiles** — tiles holding appended/evicted slots or
    points whose statistics actually changed (a far-away append changes
    nothing: its kernel weight underflows to exactly 0.0) — have their
    operand columns re-cast and their metadata recomputed
    (``ops.update_train_columns``); clean tiles carry over bit-for-bit,
    so certified pruning bounds stay exactly as valid as at the last
    full build.

Updates are folded into serving via **generations**: every ``append`` /
``evict`` bumps ``gen``; ``flush`` publishes an immutable
``StreamSnapshot`` of the current generation (optionally on a worker
thread, so queries keep serving generation ``g`` while ``g+1`` builds);
``ensure(budget)`` is the serving engine's staleness gate.  A
``RebuildPolicy`` (``stream.config``) triggers a full re-cluster when
slack overflows or the tile geometry drifts past its budgets.
"""

from __future__ import annotations

import threading
from typing import Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro import fault_injection, obs
from repro.core.bandwidth import gaussian_norm_const
from repro.kernels import ops, spatial
from repro.stream import delta
from repro.stream.config import RebuildPolicy, StreamConfig

PAD_VALUE = ops.PAD_VALUE


class StreamSnapshot(NamedTuple):
    """An immutable published generation: everything a query dispatch
    reads.  Snapshots are replaced wholesale (never mutated), so a query
    holding one is race-free against concurrent appends/evictions — the
    in-flight dispatch finishes against the generation it started with.
    ``columns`` is lazily extended per precision tier under the stream's
    lock; existing entries are never rewritten."""

    gen: int
    layout_epoch: int
    n_live: int
    norm: float                       # n_live · (2π)^{d/2} · h^d
    points: jnp.ndarray               # (n_live, d) f32 debiased live points
    xp: Optional[jnp.ndarray]         # padded layout points (pallas)
    real: Optional[jnp.ndarray]       # (total,) bool (pallas)
    index: Optional[spatial.SpatialIndex]
    columns: Dict[str, ops.TrainColumns]
    affected_tiles: int               # tiles refreshed by this flush
    total_tiles: int
    # live ids aligned with ``points`` rows (monotone; the RFF tier's
    # incremental refit diffs consecutive snapshots by id to fold
    # appends/evictions into its feature sums without a full refit)
    ids: Optional[np.ndarray] = None


class StreamingSDKDE:
    """Incrementally maintained KDE / SD-KDE / Laplace-KDE train state.

    ``method="sdkde"`` pays one full O(n²·d) score pass at construction
    (the same pass a static fit pays) and never again; ``"kde"`` /
    ``"laplace"`` need no statistics, so only the layout machinery runs.
    ``backend="pallas"`` maintains the cluster-aligned serving layout;
    ``"jnp"`` maintains just the live debiased points.
    """

    def __init__(
        self,
        x0,
        h: float,
        *,
        method: str = "sdkde",
        score_h: Optional[float] = None,
        backend: str = "pallas",
        block_n: int = 512,
        precision: str = "f32",
        config: StreamConfig | None = None,
        seed: int = 0,
    ):
        if backend not in ("pallas", "jnp"):
            raise ValueError(
                f"streaming supports the pallas/jnp backends, not {backend!r}"
            )
        if method not in ("kde", "sdkde", "laplace"):
            raise ValueError(f"unknown method {method!r}")
        x0 = np.atleast_2d(np.asarray(x0, np.float32))
        if x0.shape[0] < 1:
            raise ValueError("streaming estimator needs >= 1 initial point")
        self.config = config or StreamConfig()
        self.method = method
        self.backend = backend
        self.block_n = int(block_n)
        self.precision = precision
        self.h = float(h)
        self.sh = float(score_h) if score_h is not None else float(h)
        self.seed = int(seed)
        self.d = x0.shape[1]

        self.x = x0.copy()                       # original (pre-shift) coords
        self.ids = np.arange(x0.shape[0], dtype=np.int64)
        self.next_id = x0.shape[0]
        if method == "sdkde":
            self.s0, self.s1 = delta.initial_stats(
                self.x, self.sh, block=self.config.delta_block
            )
        else:
            self.s0 = self.s1 = None

        self.gen = 0
        self.layout_epoch = 0
        self.rebuilds = 0
        self.last_rebuild_reason: Optional[str] = None
        self.policy = RebuildPolicy(self.config)
        self.policy.reset(x0.shape[0])
        self._tiers = {precision}
        self._dirty = np.zeros(self.x.shape[0], bool)   # rows to re-scatter
        self._dirty_tiles: set = set()                  # evicted slots' tiles
        self._lock = threading.RLock()
        self._worker: Optional[threading.Thread] = None

        # pallas layout state (None on the jnp backend)
        self._index: Optional[spatial.SpatialIndex] = None
        self._labels = self._slots = None
        self._starts = self._caps = None
        self._xp = self._real = None

        self._snapshot: Optional[StreamSnapshot] = None
        self._flush_sync()                       # publish generation 0

    # -- properties ------------------------------------------------------

    @property
    def n_live(self) -> int:
        return self.x.shape[0]

    @property
    def staleness(self) -> int:
        """Applied-but-unpublished update generations."""
        snap = self._snapshot
        return self.gen - (snap.gen if snap is not None else -1)

    def snapshot(self) -> StreamSnapshot:
        """The currently published generation (possibly stale)."""
        return self._snapshot

    # -- updates ---------------------------------------------------------

    def append(self, xs) -> np.ndarray:
        """Fold new points into the live set; returns their assigned ids.

        O(n·b·d): one delta score pass (sdkde), a nearest-centroid cluster
        assignment, and slack-slot placement.  The published snapshot is
        untouched — call ``flush()`` (or let the engine's staleness gate
        do it) to serve the new generation.
        """
        xs = np.atleast_2d(np.asarray(xs, np.float32))
        if xs.shape[1] != self.d:
            raise ValueError(f"append dim {xs.shape[1]} != {self.d}")
        b = xs.shape[0]
        obs.counter("stream.appends", "append calls").inc()
        obs.counter("stream.append_points", "points appended").inc(b)
        with obs.span("stream.append", points=b, n_live=self.n_live), \
                self._lock:
            if self.method == "sdkde":
                ds0, ds1, s0n, s1n = delta.append_delta(
                    self.x, xs, self.sh, block=self.config.delta_block
                )
                changed = ds0 != 0.0
                self.s0 = np.concatenate([self.s0 + ds0, s0n])
                self.s1 = np.concatenate([self.s1 + ds1, s1n])
                new_sd = delta.apply_shift(
                    xs, s0n, s1n, self.h, self.sh
                ).astype(np.float32)
            else:
                changed = np.zeros(self.n_live, bool)
                new_sd = xs
            new_ids = np.arange(self.next_id, self.next_id + b,
                                dtype=np.int64)
            self.next_id += b
            self.x = np.concatenate([self.x, xs])
            self.ids = np.concatenate([self.ids, new_ids])
            self._dirty = np.concatenate(
                [self._dirty | changed, np.ones(b, bool)]
            )
            if self.backend == "pallas":
                labels_new = np.asarray(
                    spatial.assign(jnp.asarray(new_sd), self._index)
                ).astype(np.int64)
                self._labels = np.concatenate([self._labels, labels_new])
                slots_new = None
                if not self.policy.overflowed:
                    slots_new = spatial.place_points(
                        self._real, labels_new, self._starts, self._caps
                    )
                if slots_new is None:
                    # slack overflow: the layout can no longer hold the
                    # live set; park the rows and force a rebuild at the
                    # next flush
                    self.policy.note_overflow()
                    self._slots = np.concatenate(
                        [self._slots, np.full(b, -1, np.int64)]
                    )
                else:
                    self._real[slots_new] = True
                    self._slots = np.concatenate(
                        [self._slots, slots_new.astype(np.int64)]
                    )
            self.gen += 1
            self.policy.note_append(b)
        self._maybe_background()
        return new_ids

    def evict(self, ids) -> int:
        """Remove points by id; returns the number evicted.

        O(n·e·d): one delta pass subtracts the evicted points'
        contributions from every kept statistic; their slots revert to
        sentinels in place (the layout shape is untouched).
        """
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        obs.counter("stream.evictions", "evict calls").inc()
        obs.counter("stream.evict_points", "points evicted").inc(
            int(ids.shape[0])
        )
        with obs.span("stream.evict", points=int(ids.shape[0]),
                      n_live=self.n_live), self._lock:
            out = np.isin(self.ids, ids)
            if out.sum() != ids.shape[0]:
                missing = np.setdiff1d(ids, self.ids)
                raise KeyError(f"ids not live: {missing[:8].tolist()}")
            if out.all():
                raise ValueError("cannot evict every live point")
            keep = ~out
            if self.method == "sdkde":
                ds0, ds1 = delta.evict_delta(
                    self.x[keep], self.x[out], self.sh,
                    block=self.config.delta_block,
                )
                changed = ds0 != 0.0
                self.s0 = self.s0[keep] - ds0
                self.s1 = self.s1[keep] - ds1
            else:
                changed = np.zeros(int(keep.sum()), bool)
            if self.backend == "pallas":
                slots_out = self._slots[out]
                placed = slots_out >= 0
                self._real[slots_out[placed]] = False
                self._xp[slots_out[placed]] = PAD_VALUE
                self._dirty_tiles.update(
                    (slots_out[placed] // self.block_n).tolist()
                )
                self._slots = self._slots[keep]
                self._labels = self._labels[keep]
            self.x = self.x[keep]
            self.ids = self.ids[keep]
            self._dirty = self._dirty[keep] | changed
            self.gen += 1
            self.policy.note_evict(int(out.sum()))
        self._maybe_background()
        return int(out.sum())

    def slide(self, xs) -> np.ndarray:
        """Sliding-window update: append ``xs``, evict the oldest as many.

        Live ids are monotone, so the oldest points are the smallest ids.
        """
        xs = np.atleast_2d(np.asarray(xs, np.float32))
        with self._lock:
            new_ids = self.append(xs)
            self.evict(self.ids[: xs.shape[0]])
        return new_ids

    # -- publishing ------------------------------------------------------

    def flush(self, wait: bool = True) -> StreamSnapshot:
        """Publish a snapshot of the current generation.

        ``wait=False`` with ``config.background`` starts the build on a
        worker thread and returns the (stale) published snapshot — the
        "serve g while g+1 prepares" mode.
        """
        if not wait and self.config.background:
            with self._lock:
                snap = self._snapshot
                if snap.gen == self.gen:
                    return snap
                if self._worker is None or not self._worker.is_alive():
                    self._worker = threading.Thread(
                        target=self._flush_sync, daemon=True
                    )
                    self._worker.start()
                return snap
        return self._flush_sync()

    def ensure(self, budget: Optional[int] = None) -> StreamSnapshot:
        """The serving gate: a snapshot no more than ``budget`` generations
        stale (default: ``config.staleness_budget``), waiting for or
        performing a flush only when the budget is exceeded."""
        budget = self.config.staleness_budget if budget is None else budget
        snap = self._snapshot
        if self.gen - snap.gen <= budget:
            return snap
        worker = self._worker
        if worker is not None and worker.is_alive():
            worker.join()
            snap = self._snapshot
            if self.gen - snap.gen <= budget:
                return snap
        return self._flush_sync()

    def columns_for(self, tier: str,
                    snap: Optional[StreamSnapshot] = None
                    ) -> ops.TrainColumns:
        """Prepared train columns of a snapshot at one tier (built lazily
        on first use, then refreshed incrementally at every flush).

        Pass the ``snap`` an in-flight dispatch is pinned to so a
        concurrent flush/evict can never swap train tensors mid-query;
        default is the currently published snapshot."""
        if snap is None:
            snap = self._snapshot
        cols = snap.columns.get(tier)
        if cols is not None:
            return cols
        with self._lock:
            if tier not in snap.columns:
                self._tiers.add(tier)
                snap.columns[tier] = ops.columns_from_layout(
                    snap.xp, snap.real, snap.index,
                    block_n=self.block_n, precision=tier,
                )
            return snap.columns[tier]

    # -- internals -------------------------------------------------------

    def _maybe_background(self) -> None:
        if self.config.background:
            self.flush(wait=False)

    def _flush_sync(self) -> StreamSnapshot:
        with self._lock:
            snap = self._snapshot
            if snap is not None and snap.gen == self.gen:
                return snap
            with obs.span("stream.flush", gen=self.gen,
                          n_live=self.n_live):
                # chaos hook: a staleness blowout is a flush that stalls,
                # so queries queue behind the staleness gate
                fault_injection.fire("stream.flush", gen=self.gen)
                snap = self._build_snapshot()
            obs.counter("stream.publishes",
                        "snapshot generations published").inc()
            obs.gauge("stream.dirty_tiles",
                      "tiles refreshed by the last flush").set(
                snap.affected_tiles)
            if snap.xp is not None:
                # live rows / layout slots: how full the slack-padded
                # serving layout is (1.0 = the next append overflows)
                obs.gauge("stream.slack_occupancy").set(
                    snap.n_live / snap.xp.shape[0])
            self._snapshot = snap
            return snap

    def _shifted(self) -> np.ndarray:
        if self.method == "sdkde":
            return delta.apply_shift(
                self.x, self.s0, self.s1, self.h, self.sh
            ).astype(np.float32)
        return self.x

    def _build_snapshot(self) -> StreamSnapshot:
        x_sd = self._shifted()
        n = x_sd.shape[0]
        norm = n * gaussian_norm_const(self.d, 1.0) * self.h ** self.d
        if self.backend != "pallas":
            # jnp path: publish the live points sentinel-padded to a pow2
            # row bucket (``xp``), so the engine's jitted executable sees
            # a bounded set of shapes across generations instead of one
            # retrace per net append/evict
            total = max(256, 1 << int(n - 1).bit_length())
            xp = np.full((total, self.d), PAD_VALUE, np.float32)
            xp[:n] = x_sd
            return StreamSnapshot(
                self.gen, self.layout_epoch, n, norm, jnp.asarray(x_sd),
                jnp.asarray(xp), None, None, {}, 0, 0, ids=self.ids,
            )

        reason = (self.policy.reason()
                  if self._index is not None else "initial")
        if reason is not None:
            return self._publish_rebuilt(x_sd, norm, reason)

        # incremental path: re-scatter only the dirty rows, refresh only
        # the affected tiles' columns/metadata
        dirty_slots = self._slots[self._dirty]
        self._xp[dirty_slots] = x_sd[self._dirty]
        tiles = set((dirty_slots // self.block_n).tolist())
        tiles |= self._dirty_tiles
        total_tiles = self._xp.shape[0] // self.block_n
        prev = self._snapshot.columns
        xp_j = jnp.asarray(self._xp)
        real_j = jnp.asarray(self._real)
        if len(tiles) >= max(1, total_tiles // 2):
            cols = {t: ops.columns_from_layout(
                xp_j, real_j, self._index,
                block_n=self.block_n, precision=t,
            ) for t in self._tiers}
        else:
            tidx = _pow2_pad(np.fromiter(sorted(tiles), np.int64,
                                         len(tiles)))
            cols = {
                t: (ops.update_train_columns(
                        prev[t], xp_j, real_j, tidx, precision=t)
                    if t in prev else
                    ops.columns_from_layout(
                        xp_j, real_j, self._index,
                        block_n=self.block_n, precision=t))
                for t in self._tiers
            }
        drift = self.policy.note_mean_radius(
            _mean_tile_radius(cols[self.precision].meta)
        )
        if drift is not None:
            return self._publish_rebuilt(x_sd, norm, drift)
        self._dirty[:] = False
        self._dirty_tiles = set()
        return StreamSnapshot(
            self.gen, self.layout_epoch, n, norm, jnp.asarray(x_sd),
            xp_j, real_j, self._index, cols, len(tiles), total_tiles,
            ids=self.ids,
        )

    def _publish_rebuilt(self, x_sd: np.ndarray, norm: float,
                         reason: str) -> StreamSnapshot:
        with obs.span("stream.rebuild", reason=reason,
                      n_live=x_sd.shape[0]):
            self._rebuild_layout(x_sd)
        if reason != "initial":
            self.rebuilds += 1
            obs.counter("stream.rebuilds",
                        "full layout re-clusters",
                        labels={"reason": reason}).inc()
            self.last_rebuild_reason = reason
        xp_j = jnp.asarray(self._xp)
        real_j = jnp.asarray(self._real)
        cols = {t: ops.columns_from_layout(
            xp_j, real_j, self._index, block_n=self.block_n, precision=t,
        ) for t in self._tiers}
        self.policy.note_mean_radius(
            _mean_tile_radius(cols[self.precision].meta)
        )
        total_tiles = self._xp.shape[0] // self.block_n
        return StreamSnapshot(
            self.gen, self.layout_epoch, x_sd.shape[0], norm,
            jnp.asarray(x_sd), xp_j, real_j, self._index, cols,
            total_tiles, total_tiles, ids=self.ids,
        )

    def _rebuild_layout(self, x_sd: np.ndarray) -> None:
        """Full re-cluster + re-scatter: the one non-incremental step.

        The scatter is kept in mutable numpy (appends/evictions write rows
        in place between rebuilds) but shares the slab geometry helpers —
        ``cluster_capacities``/``cluster_slots`` — with the static
        ``spatial.cluster_layout`` path, so the cluster-alignment
        invariant has one owner.  Slabs are sized for EVERY centroid of
        the index, not just the labels the train points happen to use:
        k-means can leave a trailing cluster empty, and a later append
        assigned to it still needs a slab to land in.
        """
        self._index = spatial.build_index(
            jnp.asarray(x_sd), seed=self.seed + self.layout_epoch
        )
        labels = np.asarray(self._index.labels).astype(np.int64)
        self._labels = labels
        k_full = (int(self._index.centroids.shape[0])
                  if self._index.centroids is not None
                  else int(labels.max()) + 1)
        self._starts, self._caps = spatial.cluster_capacities(
            labels, self.block_n, slack=self.config.slack,
            n_clusters=k_full,
        )
        # slots only cover observed labels; their slab starts agree with
        # the full-k geometry because empty-cluster slabs append after
        slots = spatial.cluster_slots(
            labels, self.block_n, slack=self.config.slack
        ).astype(np.int64)
        total = max(int(self._caps.sum()), self.block_n)
        xp = np.full((total, self.d), PAD_VALUE, np.float32)
        xp[slots] = x_sd
        real = np.zeros(total, bool)
        real[slots] = True
        self._slots, self._xp, self._real = slots, xp, real
        self.layout_epoch += 1
        self.policy.reset(x_sd.shape[0])
        self._dirty[:] = False
        self._dirty_tiles = set()


def _pow2_pad(idx: np.ndarray) -> np.ndarray:
    """Pad a tile-index list to the next power of two with repeats of its
    first entry — repeated writes are idempotent, and the bounded shape
    set keeps XLA retraces of the update path bounded."""
    if idx.size == 0:
        return idx
    k = 1 << int(idx.size - 1).bit_length()
    return np.concatenate([idx, np.full(k - idx.size, idx[0], idx.dtype)])


def _mean_tile_radius(meta: Optional[spatial.TileMeta]) -> float:
    if meta is None:
        return 0.0
    radii = np.asarray(meta.radii)
    counts = np.asarray(meta.counts)
    live = counts > 0
    return float(radii[live].mean()) if live.any() else 0.0


__all__ = ["StreamSnapshot", "StreamingSDKDE"]
