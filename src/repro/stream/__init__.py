"""Streaming (online-update) SD-KDE: the repo's incremental-fit layer.

Static Flash-SD-KDE amortizes the O(n²·d) debias pass across queries; this
package amortizes it across *dataset updates* too.  A ``StreamingSDKDE``
maintains the score statistics, debiased positions, and the cluster-aligned
Pallas serving layout incrementally under ``append`` / ``evict`` /
``slide``, publishing immutable generational ``StreamSnapshot``s that the
serving engine consumes under a staleness budget.

    from repro.stream import StreamConfig, StreamingSDKDE

    s = StreamingSDKDE(x0, h=0.5, method="sdkde", backend="pallas")
    ids = s.append(x_new)          # O(n·b·d) delta pass, no refit
    s.evict(ids[:4])
    snap = s.ensure(budget=0)      # freshest published generation
"""

from repro.stream import delta
from repro.stream.config import RebuildPolicy, StreamConfig
from repro.stream.estimator import StreamingSDKDE, StreamSnapshot

__all__ = [
    "delta",
    "RebuildPolicy", "StreamConfig",
    "StreamingSDKDE", "StreamSnapshot",
]
