"""Seeded, deterministic chaos harness for the resilient serving stack.

Fault tolerance that is never exercised is fault tolerance that does not
exist.  This module supplies the faults: a frozen :class:`ChaosConfig`
describes *which* failure modes fire and *how often*, a
:class:`FaultInjector` turns that description into concrete injected
failures at well-known **injection points** hooked into the serving stack,
and everything is deterministic under the config seed so a failing chaos
soak replays exactly.

Failure modes and where they strike:

  ===================  =========================  ==========================
  mode                 what it simulates          injection point (hook site)
  ===================  =========================  ==========================
  ``shard_kill``       dead shard replica: every  ``serve.dispatch``
                       dispatch raises            (serve/engine.py)
  ``slow_shard``       degraded device: dispatch  ``serve.dispatch``
                       sleeps ``slow_ms``
  ``compile_fail``     broken bucket executable:  ``serve.compile``
                       the build raises           (serve/engine.py),
                                                  ``registry.fit``
  ``nan_poison``       numerically-poisoned       ``serve.result``
                       result: densities → NaN    (serve/engine.py)
  ``staleness_blowout``  slow snapshot rebuild:   ``stream.flush``
                       the flush sleeps, queries  (stream/estimator.py)
                       pile up behind staleness
  ``client_burst``     traffic surge: the admit   ``serve.admit``
                       hook reports a burst of    (serve/frontend.py)
                       ``burst_factor`` synthetic
                       admissions (``burst()``)
  ``admit_stall``      stalled admission thread:  ``serve.admit``
                       the admit path sleeps
                       ``slow_ms``, arrivals
                       back up behind it
  ===================  =========================  ==========================

Each mode is a probability in [0, 1] drawn per *injection opportunity*
(deterministically: the k-th draw for a given (mode, point, shard,
replica) is a pure function of the seed, never of wall clock or thread
scheduling), plus an optional list of :class:`ChaosEvent` windows for
sustained, scheduled faults ("kill shard 0 replica 1 for requests
20..60") — the shape a soak's kill + recovery story needs.

The hooks are module-level (``fire`` / ``poison``) and cost one global
read + branch when no injector is installed, so production paths carry
them for free.  The resilience layer installs its injector and brackets
every dispatch in a ``scope(shard, replica)`` (thread-local, so hedged
duplicates running on worker threads are attributed to the replica they
actually target).

``InjectedFailure`` is the one exception type every injected fault
raises; the fault-tolerant layers (``serve/resilience.py``,
``distributed/fault.py``'s RestartLoop) catch exactly it and re-raise
everything else — a real bug must never be absorbed as chaos.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

MODES = ("shard_kill", "slow_shard", "compile_fail", "nan_poison",
         "staleness_blowout", "client_burst", "admit_stall")

#: Which failure modes each injection point consults.
POINT_MODES: Dict[str, Tuple[str, ...]] = {
    "serve.dispatch": ("shard_kill", "slow_shard"),
    "serve.compile": ("compile_fail",),
    "serve.result": ("nan_poison",),
    "registry.fit": ("compile_fail",),
    "stream.flush": ("staleness_blowout",),
    "serve.admit": ("client_burst", "admit_stall"),
}

_MODE_ID = {m: i for i, m in enumerate(MODES)}
_POINT_ID = {p: i for i, p in enumerate(POINT_MODES)}


class InjectedFailure(RuntimeError):
    """A deliberately injected fault — and ONLY that.

    Resilient layers catch this type exactly (retry, reroute, restart) and
    let every other exception propagate: absorbing a real bug as chaos is
    the classic way fault-injection harnesses hide regressions.
    """

    def __init__(self, kind: str, *, shard=None, replica=None, point=None):
        super().__init__(
            f"injected {kind} (point={point} shard={shard} replica={replica})"
        )
        self.kind = kind
        self.shard = shard
        self.replica = replica
        self.point = point


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """A sustained, scheduled fault window.

    Active while ``start <= request_index < stop`` for dispatches hitting
    the targeted ``(shard, replica)`` (-1 = every shard / every replica).
    """

    kind: str
    shard: int = -1
    replica: int = -1
    start: int = 0
    stop: int = 1 << 30

    def __post_init__(self):
        if self.kind not in MODES:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(choose from {MODES})")
        if self.stop < self.start:
            raise ValueError(f"empty chaos window [{self.start}, {self.stop})")

    def hits(self, request: int, shard, replica) -> bool:
        if not (self.start <= request < self.stop):
            return False
        if self.shard != -1 and shard != self.shard:
            return False
        if self.replica != -1 and replica != self.replica:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """What to break, how often, and on which schedule.

    Mode fields are per-opportunity probabilities; ``events`` adds
    deterministic sustained windows on top.  ``slow_ms`` is the injected
    delay of ``slow_shard`` / ``staleness_blowout`` faults.
    """

    seed: int = 0
    shard_kill: float = 0.0
    slow_shard: float = 0.0
    compile_fail: float = 0.0
    nan_poison: float = 0.0
    staleness_blowout: float = 0.0
    client_burst: float = 0.0
    admit_stall: float = 0.0
    slow_ms: float = 50.0
    #: Synthetic admissions injected per fired ``client_burst`` opportunity.
    burst_factor: int = 4
    events: Tuple[ChaosEvent, ...] = ()

    def __post_init__(self):
        for m in MODES:
            p = getattr(self, m)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"chaos probability {m}={p} outside [0, 1]")
        if self.slow_ms < 0:
            raise ValueError(f"slow_ms must be >= 0, got {self.slow_ms}")
        if self.burst_factor < 1:
            raise ValueError(
                f"burst_factor must be >= 1, got {self.burst_factor}")
        object.__setattr__(self, "events", tuple(self.events))

    @classmethod
    def from_modes(cls, modes: Union[str, Sequence[str]], *,
                   requests: int = 0, seed: int = 0,
                   slow_ms: float = 40.0) -> "ChaosConfig":
        """CLI shorthand: comma-separated mode names with stock rates.

        ``shard_kill`` additionally schedules one sustained kill of shard
        0 / replica 0 across the middle third of ``requests`` — the soak's
        kill + recovery arc — when a request count is known.
        """
        if isinstance(modes, str):
            modes = [m.strip() for m in modes.split(",") if m.strip()]
        rates = {"shard_kill": 0.1, "slow_shard": 0.2, "compile_fail": 0.3,
                 "nan_poison": 0.1, "staleness_blowout": 0.5,
                 "client_burst": 0.15, "admit_stall": 0.1}
        kw: dict = {"seed": seed, "slow_ms": slow_ms}
        events = []
        for m in modes:
            if m not in MODES:
                raise ValueError(f"unknown chaos mode {m!r} "
                                 f"(choose from {MODES})")
            kw[m] = rates[m]
            if m == "shard_kill" and requests >= 6:
                events.append(ChaosEvent("shard_kill", shard=0, replica=0,
                                         start=requests // 3,
                                         stop=2 * requests // 3))
        return cls(events=tuple(events), **kw)


class _Scope(threading.local):
    shard: Optional[int] = None
    replica: Optional[int] = None


class FaultInjector:
    """Deterministic fault source for one chaos run.

    The k-th probability draw for a (mode, point, shard, replica) target
    is seeded by exactly those coordinates plus k, so thread scheduling
    (hedged duplicates race on a pool) can never change which dispatch a
    fault lands on — only the *order* faults are observed in.
    ``counts`` records every injected fault by mode for telemetry and
    replay assertions.
    """

    def __init__(self, config: ChaosConfig):
        self.config = config
        self.request_index = -1        # no request admitted yet
        self.counts: Dict[str, int] = {m: 0 for m in MODES}
        self._draws: Dict[tuple, int] = {}
        self._scope = _Scope()
        self._lock = threading.Lock()

    # -- request lifecycle -----------------------------------------------

    def begin_request(self) -> int:
        """Advance the request clock (schedules index off this)."""
        with self._lock:
            self.request_index += 1
            return self.request_index

    @contextlib.contextmanager
    def scope(self, shard: Optional[int], replica: Optional[int]):
        """Attribute nested injection points to one (shard, replica)."""
        prev = (self._scope.shard, self._scope.replica)
        self._scope.shard, self._scope.replica = shard, replica
        try:
            yield self
        finally:
            self._scope.shard, self._scope.replica = prev

    # -- decisions --------------------------------------------------------

    def _draw(self, mode: str, point: str, shard, replica) -> float:
        key = (mode, point, shard, replica)
        with self._lock:
            k = self._draws.get(key, 0)
            self._draws[key] = k + 1
        seed = (int(self.config.seed) & 0x7FFFFFFF, _MODE_ID[mode],
                _POINT_ID[point], (shard if shard is not None else -1) + 2,
                (replica if replica is not None else -1) + 2, k)
        return float(np.random.default_rng(seed).random())

    def _active(self, mode: str, point: str, shard, replica) -> bool:
        req = self.request_index
        for ev in self.config.events:
            if ev.kind == mode and ev.hits(req, shard, replica):
                return True
        p = getattr(self.config, mode)
        return p > 0.0 and self._draw(mode, point, shard, replica) < p

    def _count(self, mode: str) -> None:
        with self._lock:
            self.counts[mode] += 1

    # -- the injection API the hooks call ---------------------------------

    def fire(self, point: str, **ctx) -> None:
        """Raise / delay according to the modes wired to this point."""
        shard = ctx.get("shard", self._scope.shard)
        replica = ctx.get("replica", self._scope.replica)
        for mode in POINT_MODES.get(point, ()):
            # value-shaped modes have dedicated hooks (poison / burst);
            # fire() only raises or delays
            if mode in ("nan_poison", "client_burst") or not self._active(
                    mode, point, shard, replica):
                continue
            self._count(mode)
            if mode in ("slow_shard", "staleness_blowout", "admit_stall"):
                time.sleep(self.config.slow_ms / 1e3)
            else:
                raise InjectedFailure(mode, shard=shard, replica=replica,
                                      point=point)

    def poison(self, point: str, value):
        """Return ``value``, NaN-poisoned when the mode fires."""
        shard, replica = self._scope.shard, self._scope.replica
        if "nan_poison" in POINT_MODES.get(point, ()) and self._active(
                "nan_poison", point, shard, replica):
            self._count("nan_poison")
            return value * float("nan")
        return value

    def burst(self, point: str) -> int:
        """Synthetic admissions to inject at ``point`` (0 = none).

        ``client_burst`` simulates a traffic surge rather than a broken
        component, so instead of raising it *reports load*: the admission
        front end asks this hook per real arrival and enqueues the
        returned number of synthetic duplicate requests — genuine queue
        pressure that exercises backpressure/shedding deterministically.
        """
        shard, replica = self._scope.shard, self._scope.replica
        if "client_burst" in POINT_MODES.get(point, ()) and self._active(
                "client_burst", point, shard, replica):
            self._count("client_burst")
            return int(self.config.burst_factor)
        return 0

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counts)


# ---------------------------------------------------------------------------
# Module-level hook surface (one global read + branch when quiet).
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None


def install(injector: FaultInjector) -> FaultInjector:
    """Make ``injector`` the process-wide fault source (None-safe hooks)."""
    global _ACTIVE
    _ACTIVE = injector
    return injector


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


@contextlib.contextmanager
def installed(injector: FaultInjector):
    prev = _ACTIVE
    install(injector)
    try:
        yield injector
    finally:
        install(prev) if prev is not None else uninstall()


def fire(point: str, **ctx) -> None:
    """Hook: inject at ``point`` if a chaos run is active (else free)."""
    inj = _ACTIVE
    if inj is not None:
        inj.fire(point, **ctx)


def poison(point: str, value):
    """Hook: possibly NaN-poison a result if a chaos run is active."""
    inj = _ACTIVE
    return value if inj is None else inj.poison(point, value)


def burst(point: str) -> int:
    """Hook: synthetic admissions to inject at ``point`` (0 when quiet)."""
    inj = _ACTIVE
    return 0 if inj is None else inj.burst(point)


__all__ = [
    "MODES", "POINT_MODES", "InjectedFailure", "ChaosEvent", "ChaosConfig",
    "FaultInjector", "install", "uninstall", "installed", "active",
    "fire", "poison", "burst",
]
