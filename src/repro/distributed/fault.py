"""Fault-tolerance supervisor: heartbeats, failure detection, restart.

The production posture (1000+ nodes) is checkpoint-restart with elastic
reshard: every host runs the same SPMD program; a coordinator-side
``Supervisor`` tracks per-host heartbeats, declares a host dead after
``timeout`` missed beats, and drives the restart decision:

  * dead host AND spare capacity   -> restart same-size from checkpoint
  * dead host AND no spares        -> shrink the mesh (elastic.plan_mesh),
                                      restore with resharding
                                      (checkpoint.restore with new shardings)
  * flapping host (slow heartbeat) -> straggler path, not a failure

On this single-process container the supervisor is exercised by unit tests
that drive simulated clocks/heartbeats (tests/test_fault.py) and by the
``launch.train`` driver, which runs a single-host instance of the same
loop: periodic async checkpoint + automatic restore-on-restart, and a
simulated failure-injection mode (--inject-failure) that kills and resumes
the step loop to prove end-to-end restart works.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional

from repro.fault_injection import InjectedFailure


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    step: int = 0
    alive: bool = True
    # Fencing: once a restart decision committed a host as dead, late
    # heartbeats from its zombie process must not revive it.  ``epoch``
    # bumps on every fence; only a beat carrying the current epoch (i.e.
    # from a process that was re-admitted by the coordinator, not the
    # fenced zombie) is accepted again.
    fenced: bool = False
    epoch: int = 0


class Supervisor:
    """Heartbeat registry + failure/straggler classification."""

    def __init__(
        self,
        n_hosts: int,
        *,
        timeout: float = 60.0,
        straggler_factor: float = 2.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.clock = clock
        now = clock()
        self.hosts: Dict[int, HostState] = {
            i: HostState(i, now) for i in range(n_hosts)
        }
        # EWMA of per-step wall time per host — straggler detection signal.
        self._step_time: Dict[int, float] = {}
        self._last_step_at: Dict[int, float] = {}
        #: Beats rejected by fencing — zombie liveness signal for telemetry.
        self.rejected_beats = 0

    # -- heartbeat ingestion ----------------------------------------------

    def beat(self, host_id: int, step: int,
             epoch: Optional[int] = None) -> bool:
        """Ingest a heartbeat; returns False if it was rejected.

        A fenced host's beats are rejected unless they carry the host's
        current fencing epoch — a zombie process that survived the
        restart decision keeps beating with no (or a stale) epoch and can
        no longer flip itself back to alive.
        """
        now = self.clock()
        h = self.hosts[host_id]
        if h.fenced:
            if epoch != h.epoch:
                self.rejected_beats += 1
                return False
            h.fenced = False   # re-admitted under the new epoch
        if step > h.step:
            prev = self._last_step_at.get(host_id)
            if prev is not None:
                dt = (now - prev) / max(step - h.step, 1)
                ewma = self._step_time.get(host_id, dt)
                self._step_time[host_id] = 0.8 * ewma + 0.2 * dt
            self._last_step_at[host_id] = now
        h.last_beat, h.step, h.alive = now, step, True
        return True

    # -- fencing ------------------------------------------------------------

    def fence(self, host_ids: Iterable[int]) -> None:
        """Commit hosts as dead: bump their epoch and reject stale beats."""
        for hid in host_ids:
            h = self.hosts[hid]
            if not h.fenced:
                h.fenced = True
                h.alive = False
                h.epoch += 1

    def fenced(self) -> List[int]:
        return sorted(h.host_id for h in self.hosts.values() if h.fenced)

    def readmit(self, host_id: int) -> int:
        """Coordinator-side re-admission of a fenced host (e.g. after a
        successful health probe); returns the epoch its beats must carry."""
        h = self.hosts[host_id]
        h.fenced = False
        h.alive = True
        h.last_beat = self.clock()
        return h.epoch

    # -- classification -----------------------------------------------------

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        dead = []
        for h in self.hosts.values():
            if now - h.last_beat > self.timeout:
                h.alive = False
                dead.append(h.host_id)
        return dead

    def stragglers(self) -> List[int]:
        """Hosts whose EWMA step time exceeds factor × fleet median."""
        times = sorted(self._step_time.values())
        if len(times) < 2:
            return []
        median = times[len(times) // 2]
        return [
            hid for hid, t in self._step_time.items()
            if t > self.straggler_factor * median and self.hosts[hid].alive
        ]

    def fleet_step(self) -> int:
        """The globally-committed step = min over live hosts."""
        live = [h.step for h in self.hosts.values() if h.alive]
        return min(live) if live else 0

    # -- restart decision ----------------------------------------------------

    def restart_plan(self, spare_hosts: int = 0, *,
                     fence: bool = False) -> Optional[dict]:
        """None if healthy; else a restart decision dict.

        With ``fence=True`` the decision is also *committed*: the dead
        hosts are fenced atomically with the plan, so a zombie's late
        beat cannot revive a host the plan already removed.
        """
        dead = self.dead_hosts()
        if not dead:
            return None
        if fence:
            self.fence(dead)
        live = len(self.hosts) - len(dead)
        if len(dead) <= spare_hosts:
            return {
                "action": "replace",
                "dead": dead,
                "new_size": len(self.hosts),
            }
        return {"action": "shrink", "dead": dead, "new_size": live}


@dataclasses.dataclass
class RestartLoop:
    """Single-host skeleton of the restart-from-checkpoint loop used by
    launch/train.py: run step_fn until done, checkpointing every
    ``ckpt_every``; on (simulated or real) failure, restore and continue.
    """

    step_fn: Callable[[int], None]          # executes step i
    save_fn: Callable[[int], None]          # checkpoint at step i
    restore_fn: Callable[[], int]           # -> step to resume from
    ckpt_every: int = 50

    def run(self, total_steps: int, *, fail_at: Optional[int] = None) -> int:
        """Returns the number of (re)starts it took."""
        starts = 0
        done = 0
        while done < total_steps:
            starts += 1
            start = self.restore_fn()
            try:
                for i in range(start, total_steps):
                    if fail_at is not None and i == fail_at and starts == 1:
                        raise InjectedFailure("node_failure",
                                              point="restart_loop")
                    self.step_fn(i)
                    done = i + 1
                    if (i + 1) % self.ckpt_every == 0:
                        self.save_fn(i + 1)
            except InjectedFailure:
                continue   # supervisor restarts us; restore_fn resumes
            # any other exception — a real bug in step_fn — propagates:
            # absorbing it here would turn regressions into silent retries
        return starts
