"""Ring-sharded SD-KDE: the paper's streaming accumulation, at mesh scale.

The single-chip Flash kernels stream column tiles HBM→VMEM; this module
applies the same idea one level up the hierarchy: point-set *shards* are
streamed device→device around a ring with ``lax.ppermute`` while each device
consumes the block it currently holds.  Per-device collective traffic is
O(n·d / R) per step — linear in n, never quadratic — and the permute of the
next block is independent of the GEMMs on the current block, so XLA's
latency-hiding scheduler overlaps communication with compute.

Multi-pod meshes use a *hierarchical* two-level ring: an inner ring over the
``data`` axis (fast intra-pod ICI) and an outer rotation over the ``pod``
axis (slow inter-pod links).  Cross-pod transfers happen once per full inner
ring, so each inter-pod permute has an entire pod's worth of compute to hide
behind — the key to scaling past one pod.

All functions are shard_map'd over a mesh and agree with the single-device
reference path to float tolerance (tested in tests/test_distributed_kde.py).
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bandwidth import gaussian_norm_const
from repro.core.kde import PAD_VALUE, sqdist
from repro.distributed import compat


def default_mesh(data_axis: str = "data") -> Mesh:
    """One-axis ring over every local device (1 device → a trivial ring).

    Serving and the estimator's ``ring`` backend use this when no mesh is
    passed, so the same code path runs unchanged from a CPU laptop to a pod.
    """
    import numpy as np

    return Mesh(np.asarray(jax.devices()), (data_axis,))


def _ring_perm(size: int):
    return [(i, (i + 1) % size) for i in range(size)]


def _pvary(tree, axes: tuple):
    """Mark zero-init carries as varying over the ring axes (shard_map vma)."""
    return jax.tree.map(lambda a: compat.pvary(a, axes), tree)


def _ring_scan(
    cols0: jnp.ndarray,
    init_acc,
    consume: Callable,
    mesh: Mesh,
    data_axis: str,
    pod_axis: str | None,
):
    """Hierarchical ring fold: acc = consume(acc, block) over all blocks.

    ``cols0`` is this device's resident column block.  Inner ring rotates
    over ``data_axis``; if ``pod_axis`` is given, an outer rotation over pods
    runs a full inner ring per pod step.
    """
    n_data = mesh.shape[data_axis]
    n_pod = mesh.shape[pod_axis] if pod_axis else 1
    vary_axes = (data_axis,) + ((pod_axis,) if pod_axis else ())
    init_acc = _pvary(init_acc, vary_axes)

    def inner_ring(carry_cols, acc):
        def body(i, state):
            acc, cols = state
            # The permute is independent of the consume — XLA overlaps them.
            nxt = (
                lax.ppermute(cols, data_axis, _ring_perm(n_data))
                if n_data > 1
                else cols
            )
            acc = consume(acc, cols)
            return acc, nxt

        acc, cols = lax.fori_loop(0, n_data, body, (acc, carry_cols))
        return cols, acc

    def outer_body(p, state):
        acc, cols = state
        cols, acc = inner_ring(cols, acc)
        if pod_axis and n_pod > 1:
            cols = lax.ppermute(cols, pod_axis, _ring_perm(n_pod))
        return acc, cols

    acc, _ = lax.fori_loop(0, n_pod, outer_body, (init_acc, cols0))
    return acc


def _row_axes(mesh: Mesh, data_axis: str, pod_axis: str | None):
    return (pod_axis, data_axis) if pod_axis else (data_axis,)


def _phi(sq, h):
    return jnp.exp(-sq / (2.0 * h * h))


# ---------------------------------------------------------------------------
# Ring score statistics (train × train).
# ---------------------------------------------------------------------------


def ring_score_stats(
    x: jnp.ndarray,
    h,
    *,
    mesh: Mesh | None = None,
    data_axis: str = "data",
    pod_axis: str | None = None,
):
    """(S0, S1) with rows and streamed columns sharded over the ring.

    ``x`` must be evenly shardable over the ring axes (pad with
    ``repro.core.kde.pad_rows`` first — sentinel rows contribute exactly 0).
    """
    mesh = default_mesh(data_axis) if mesh is None else mesh
    axes = _row_axes(mesh, data_axis, pod_axis)
    spec = P(axes, None)

    def local(x_rows):
        def consume(acc, cols):
            s0, s1 = acc
            sq = sqdist(x_rows, cols)
            phi = _phi(sq, h)
            return s0 + jnp.sum(phi, axis=1), s1 + phi @ cols

        init = (
            jnp.zeros(x_rows.shape[0], jnp.float32),
            jnp.zeros(x_rows.shape, jnp.float32),
        )
        return _ring_scan(x_rows, init, consume, mesh, data_axis, pod_axis)

    return compat.shard_map(
        local, mesh=mesh, in_specs=(spec,), out_specs=(P(axes), spec)
    )(x)


def ring_sdkde_shift(
    x: jnp.ndarray,
    h,
    *,
    score_h=None,
    mesh: Mesh | None = None,
    data_axis: str = "data",
    pod_axis: str | None = None,
    eps: float = 1e-30,
) -> jnp.ndarray:
    """Debiased samples, rows staying sharded over the ring axes."""
    mesh = default_mesh(data_axis) if mesh is None else mesh
    sh = h if score_h is None else score_h
    s0, s1 = ring_score_stats(
        x, sh, mesh=mesh, data_axis=data_axis, pod_axis=pod_axis
    )
    score = (s1 - x * s0[:, None]) / (sh * sh * s0[:, None] + eps)
    return x + 0.5 * h * h * score


# ---------------------------------------------------------------------------
# Ring KDE / Laplace evaluation (train × query).
# ---------------------------------------------------------------------------


def _ring_eval(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    weight_fn,
    *,
    n_true: int,
    mesh: Mesh | None,
    data_axis: str,
    pod_axis: str | None,
):
    mesh = default_mesh(data_axis) if mesh is None else mesh
    axes = _row_axes(mesh, data_axis, pod_axis)
    spec = P(axes, None)
    d = x.shape[-1]

    def local(y_rows, x_cols):
        def consume(acc, cols):
            sq = sqdist(y_rows, cols)
            return acc + jnp.sum(weight_fn(sq, h, d), axis=1)

        init = jnp.zeros(y_rows.shape[0], jnp.float32)
        return _ring_scan(x_cols, init, consume, mesh, data_axis, pod_axis)

    sums = compat.shard_map(
        local, mesh=mesh, in_specs=(spec, spec), out_specs=P(axes)
    )(y, x)
    h = jnp.asarray(h, jnp.float32)
    return sums / (n_true * gaussian_norm_const(d, 1.0) * h**d)


def ring_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    n_true: int | None = None,
    mesh: Mesh | None = None,
    data_axis: str = "data",
    pod_axis: str | None = None,
) -> jnp.ndarray:
    """Gaussian KDE at sharded queries; train shards rotate around the ring."""
    n_true = int(x.shape[0]) if n_true is None else n_true
    return _ring_eval(
        x, y, h, lambda sq, h_, d_: _phi(sq, h_),
        n_true=n_true, mesh=mesh, data_axis=data_axis, pod_axis=pod_axis,
    )


def ring_laplace_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    n_true: int | None = None,
    mesh: Mesh | None = None,
    data_axis: str = "data",
    pod_axis: str | None = None,
) -> jnp.ndarray:
    """Fused Laplace-corrected KDE on the ring."""
    n_true = int(x.shape[0]) if n_true is None else n_true

    def w(sq, h_, d_):
        scaled = sq / (2.0 * h_ * h_)
        return _phi(sq, h_) * (1.0 + d_ / 2.0 - scaled)

    return _ring_eval(
        x, y, h, w,
        n_true=n_true, mesh=mesh, data_axis=data_axis, pod_axis=pod_axis,
    )


def ring_sdkde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    score_h=None,
    n_true: int | None = None,
    mesh: Mesh | None = None,
    data_axis: str = "data",
    pod_axis: str | None = None,
) -> jnp.ndarray:
    """Full distributed SD-KDE: ring score pass → local shift → ring KDE.

    This is the compiled program behind the ``flash_sdkde_*`` dry-run cells:
    the paper's 1M-point workload sharded over a (pod, data, model) mesh.
    """
    n_true = int(x.shape[0]) if n_true is None else n_true
    x_sd = ring_sdkde_shift(
        x, h, score_h=score_h, mesh=mesh,
        data_axis=data_axis, pod_axis=pod_axis,
    )
    return ring_kde(
        x_sd, y, h, n_true=n_true, mesh=mesh,
        data_axis=data_axis, pod_axis=pod_axis,
    )


# ---------------------------------------------------------------------------
# Host-level helpers.
# ---------------------------------------------------------------------------


def shard_points(
    x: jnp.ndarray, mesh: Mesh, axes: Sequence[str]
) -> jnp.ndarray:
    """Pad rows to the ring size and place with a row sharding."""
    ring = 1
    for a in axes:
        ring *= mesh.shape[a]
    n = x.shape[0]
    rem = (-n) % ring
    if rem:
        x = jnp.pad(x, [(0, rem), (0, 0)], constant_values=PAD_VALUE)
    return jax.device_put(x, NamedSharding(mesh, P(tuple(axes), None)))
