"""Elastic scaling: mesh planning + state resharding on shrink/grow.

When the fleet loses (or gains) hosts, the job restarts on a different
device count.  This module picks the new mesh shape and re-computes every
sharding for it; checkpoint.restore(shardings=...) then re-places the saved
state — params, optimizer, data cursor — onto the new mesh.  The TRAINING
SEMANTICS are preserved by keeping the global batch size fixed and scaling
the per-device batch (grad-accumulation count absorbs non-divisibility).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    note: str = ""

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(
    n_devices: int,
    *,
    model_parallel: int = 16,
    want_pods: Optional[int] = None,
) -> MeshPlan:
    """Largest (pod, data, model) mesh that fits ``n_devices``.

    Keeps the model axis fixed (TP degree is architecture-determined) and
    gives the rest to data; a pod axis is split out when the count divides.
    Drops devices that don't fit the grid (reported in ``note``) — the
    shrink path after failures.
    """
    mp = model_parallel
    while mp > 1 and n_devices % mp != 0:
        mp //= 2
    rest = n_devices // mp
    if want_pods and rest % want_pods == 0 and want_pods > 1:
        plan = MeshPlan((want_pods, rest // want_pods, mp),
                        ("pod", "data", "model"))
    else:
        plan = MeshPlan((rest, mp), ("data", "model"))
    used = plan.n_devices
    note = "" if used == n_devices else f"dropping {n_devices - used} devices"
    return dataclasses.replace(plan, note=note)


def make_mesh(plan: MeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = plan.n_devices
    import numpy as np

    grid = np.asarray(devices[:n]).reshape(plan.shape)
    return Mesh(grid, plan.axes)


def reshard_specs(
    pspecs: Dict[str, P], old_mesh_axes: Tuple[str, ...], new_mesh: Mesh
) -> Dict[str, NamedSharding]:
    """Map logical PartitionSpecs onto a (possibly smaller) new mesh.

    Axes that disappeared from the mesh (e.g. ``pod`` after a shrink to one
    pod) are dropped from every spec — those dims become replicated.
    """
    live = set(new_mesh.axis_names)

    def fix_entry(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in live)
            return kept if kept else None
        return e if e in live else None

    out = {}
    for name, spec in pspecs.items():
        out[name] = NamedSharding(new_mesh, P(*(fix_entry(e) for e in spec)))
    return out


def rebatch(global_batch: int, old_dp: int, new_dp: int,
            microbatches: int) -> Tuple[int, int, int]:
    """(per_device_batch, microbatches, new_global) after a dp resize.

    Prefers keeping the global batch exactly (growing the accumulation count
    until the new dp degree divides); when no exact tiling exists (e.g. 256
    over 15 hosts), the global batch moves to the NEAREST achievable
    multiple — training semantics change minimally and deterministically.
    """
    for mb in range(microbatches, global_batch + 1):
        if global_batch % (new_dp * mb) == 0:
            return global_batch // (new_dp * mb), mb, global_batch
    mb = microbatches
    per_dev = max(1, round(global_batch / (new_dp * mb)))
    return per_dev, mb, per_dev * new_dp * mb
