"""Version compatibility for the distributed layer.

The ring schedules are written against the modern JAX surface
(``jax.shard_map``, ``lax.pvary``).  Older jax releases (<= 0.4.x, like the
0.4.37 in the CPU validation image) ship ``shard_map`` under
``jax.experimental`` and have no ``pvary`` (varying-manual-axes tracking
didn't exist yet, so marking a carry as axis-varying is a no-op there).
Everything in ``repro.distributed`` goes through these two shims so the same
ring code runs on both.
"""

from __future__ import annotations

import jax
from jax import lax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        # check_rep=False: the ring carries are device-varying by
        # construction; the old replication checker can't see that.
        del check_vma  # the old tracer has no vma concept
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


if hasattr(lax, "pvary"):
    pvary = lax.pvary
else:  # jax <= 0.4.x: no varying-axes tracking, nothing to mark
    def pvary(x, axes):  # noqa: ARG001 - signature parity with lax.pvary
        return x


__all__ = ["shard_map", "pvary"]
