"""Version compatibility for the distributed layer.

The ring schedules are written against the modern JAX surface
(``jax.shard_map``, ``lax.pvary``).  Older jax releases (<= 0.4.x, like the
0.4.37 in the CPU validation image) ship ``shard_map`` under
``jax.experimental`` and have no ``pvary`` (varying-manual-axes tracking
didn't exist yet, so marking a carry as axis-varying is a no-op there).
Everything in ``repro.distributed`` goes through these two shims so the same
ring code runs on both.
"""

from __future__ import annotations

import jax
from jax import lax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  axis_names=None):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  axis_names=None):
        # check_rep=False: the ring carries are device-varying by
        # construction; the old replication checker can't see that.
        del check_vma  # the old tracer has no vma concept
        kw = {}
        if axis_names is not None:
            # the old API spells partial-manual as the complement: ``auto``
            # = the axes NOT listed as manual.
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False, **kw,
        )


if hasattr(lax, "pvary"):
    pvary = lax.pvary
else:  # jax <= 0.4.x: no varying-axes tracking, nothing to mark
    def pvary(x, axes):  # noqa: ARG001 - signature parity with lax.pvary
        return x


if hasattr(lax, "pvary"):  # modern jax: barrier has a differentiation rule
    optimization_barrier = lax.optimization_barrier
else:
    # 0.4.x lacks the JVP rule for optimization_barrier, which breaks any
    # grad through it.  The barrier is a scheduling hint (it pins a gather
    # below a convert on TPU), not semantics — dropping it on the old-jax
    # CPU validation path changes nothing numerically.
    def optimization_barrier(x):
        return x


if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:  # jax <= 0.4.x: psum of 1 constant-folds to the (static) axis size
    def axis_size(name):
        return lax.psum(1, name)


try:  # jax >= 0.5: explicit axis types on meshes
    from jax.sharding import AxisType
except ImportError:  # jax <= 0.4.x: every axis is implicitly Auto
    AxisType = None


def make_auto_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` with every axis marked Auto, on any jax version.

    Newer jax wants ``axis_types=(AxisType.Auto, ...)`` spelled out (and
    hidden-sharding APIs check it); 0.4.x has no axis-type concept — its
    meshes already behave as Auto — and rejects the kwarg.
    """
    kw = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


__all__ = ["shard_map", "pvary", "axis_size", "optimization_barrier",
           "AxisType", "make_auto_mesh"]
