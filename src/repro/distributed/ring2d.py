"""2-D block-partitioned SD-KDE: the production-mesh decomposition.

``ring.py`` parallelizes over point rows only — on a (data, model) mesh the
model axis would sit idle for the KDE workload.  This module partitions the
PAIR space over the full mesh:

  * EVAL/QUERY rows shard over the ``model`` axis (16-way),
  * TRAIN columns shard over (pod, data) (16/32-way),
  * device (i, j) accumulates the partial statistics of
    (row-shard j × column-shard i) — n²/chips pairs, no redundancy —
  * one small ``lax.psum`` over (pod, data) completes the column reduction
    (payload = the (rows_loc × (d+1)) accumulator, NOT anything quadratic).

Within a device, column blocks stream through a ``lax.scan`` in ``chunk``-
sized sub-blocks so the (rows × cols) φ tile never materializes at full
width (the paper's streaming accumulation; the Pallas kernels push the same
idea into VMEM on real TPU).

History (EXPERIMENTS.md §Perf, flash_sdkde_1m iteration 2): the first
version of this module rotated the column shards around a (pod, data)
ppermute ring — correct, but every ring member consumed EVERY column shard,
duplicating all work ``data×pod``-fold.  The roofline table's
MODEL_FLOPS/HLO_FLOPs column sat at 0.07 ≈ 1/16 for the SD-KDE cells, which
is exactly how the bug was found.  A ppermute ring is the right tool when
rows and columns shard over the SAME axis (ring.py); with distinct axes the
block partition + psum is strictly better.

``check_vma=False``: the accumulators are psum'd to replicated across the
column axes, which the variance tracker cannot prove through the scan.
Agreement with the single-device reference path: tests/test_ring2d.py.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bandwidth import gaussian_norm_const
from repro.core.kde import PAD_VALUE, sqdist
from repro.distributed import compat


def _phi(sq, h):
    return jnp.exp(-sq / (2.0 * h * h))


def _chunked_consume(rows, cols, chunk: int, body, acc):
    """Stream ``cols`` in ``chunk`` blocks: acc = body(acc, rows, col_blk)."""
    n = cols.shape[0]
    if n <= chunk:
        return body(acc, rows, cols)
    nb = n // chunk
    main, tail = cols[: nb * chunk], cols[nb * chunk:]
    blocks = main.reshape(nb, chunk, cols.shape[-1])

    def step(a, blk):
        return body(a, rows, blk), None

    acc, _ = lax.scan(step, acc, blocks)
    if tail.shape[0]:
        acc = body(acc, rows, tail)
    return acc


def _axes(mesh: Mesh):
    pod = "pod" if "pod" in mesh.axis_names else None
    ring = (("pod", "data") if pod else ("data",))
    return pod, ring


def ring2d_score_stats(
    x_rows: jnp.ndarray,       # row-sharded view (model axis)
    x_cols: jnp.ndarray,       # column-sharded view (pod, data)
    h,
    *,
    mesh: Mesh,
    chunk: int = 2048,
):
    """(S0, S1) over the train set; rows over ``model``, cols over the rest."""
    pod, col_axes = _axes(mesh)

    def local(rows, cols):
        def body(acc, r, blk):
            s0, s1 = acc
            phi = _phi(sqdist(r, blk), h)
            return s0 + jnp.sum(phi, axis=1), s1 + phi @ blk

        init = (
            jnp.zeros(rows.shape[0], jnp.float32),
            jnp.zeros(rows.shape, jnp.float32),
        )
        s0, s1 = _chunked_consume(rows, cols, chunk, body, init)
        return lax.psum(s0, col_axes), lax.psum(s1, col_axes)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P(col_axes, None)),
        out_specs=(P("model"), P("model", None)),
        check_vma=False,
    )(x_rows, x_cols)


def ring2d_kde_sums(
    y_rows: jnp.ndarray,
    x_cols: jnp.ndarray,
    h,
    *,
    mesh: Mesh,
    chunk: int = 2048,
    laplace: bool = False,
):
    """Unnormalized (Laplace-)KDE sums at model-sharded queries."""
    pod, col_axes = _axes(mesh)
    d = x_cols.shape[-1]

    def local(rows, cols):
        def body(acc, r, blk):
            sq = sqdist(r, blk)
            phi = _phi(sq, h)
            if laplace:
                phi = phi * (1.0 + d / 2.0 - sq / (2.0 * h * h))
            return acc + jnp.sum(phi, axis=1)

        init = jnp.zeros(rows.shape[0], jnp.float32)
        acc = _chunked_consume(rows, cols, chunk, body, init)
        return lax.psum(acc, col_axes)

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P(col_axes, None)),
        out_specs=P("model"),
        check_vma=False,
    )(y_rows, x_cols)


def ring2d_sdkde(
    x: jnp.ndarray,            # (n, d) train points
    y: jnp.ndarray,            # (m, d) queries
    h,
    *,
    score_h=None,
    n_true: int | None = None,
    mesh: Mesh,
    chunk: int = 2048,
    laplace_final: bool = False,
    eps: float = 1e-30,
) -> jnp.ndarray:
    """Full SD-KDE on the production mesh; returns densities at ``y``.

    Program structure (the flash_sdkde_* dry-run cells):
      1. score pass: rows of X over ``model``, X columns over (pod, data)
      2. shift (elementwise, stays model-row-sharded)
      3. KDE pass: rows of Y over ``model``, shifted X columns over
         (pod, data)
    GSPMD inserts the reshard between (2) and (3) — an all-to-all moving the
    debiased samples from row sharding to column sharding, O(n·d) bytes.
    """
    n, d = x.shape
    n_true = n if n_true is None else n_true
    sh = h if score_h is None else score_h

    s0, s1 = ring2d_score_stats(x, x, sh, mesh=mesh, chunk=chunk)
    score = (s1 - x * s0[:, None]) / (sh * sh * s0[:, None] + eps)
    x_sd = x + 0.5 * h * h * score

    sums = ring2d_kde_sums(
        y, x_sd, h, mesh=mesh, chunk=chunk, laplace=laplace_final
    )
    h = jnp.asarray(h, jnp.float32)
    return sums / (n_true * gaussian_norm_const(d, 1.0) * h**d)


def kde_input_specs(n: int, m: int, d: int, mesh: Mesh):
    """ShapeDtypeStructs for the dry-run: x column-sharded, y row-sharded."""
    pod, col_axes = _axes(mesh)
    return (
        jax.ShapeDtypeStruct(
            (n, d), jnp.float32,
            sharding=NamedSharding(mesh, P(col_axes, None)),
        ),
        jax.ShapeDtypeStruct(
            (m, d), jnp.float32,
            sharding=NamedSharding(mesh, P("model", None)),
        ),
    )


def pad_for_mesh(x: jnp.ndarray, mesh: Mesh) -> jnp.ndarray:
    """Pad rows so both the column shards and the model-row shards divide."""
    import math

    pod, col_axes = _axes(mesh)
    cols = 1
    for a in col_axes:
        cols *= mesh.shape[a]
    mult = math.lcm(cols, mesh.shape["model"])
    rem = (-x.shape[0]) % mult
    if rem:
        x = jnp.pad(x, [(0, rem), (0, 0)], constant_values=PAD_VALUE)
    return x
