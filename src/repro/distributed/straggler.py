"""Straggler mitigation: deadline-based duplicate dispatch.

Policy (data-parallel): the fleet advances in lockstep, so one slow host
gates every step.  When the Supervisor's EWMA flags a straggler, its NEXT
microbatch is duplicately dispatched to the fastest healthy host; whichever
copy lands first wins, the loser is cancelled.  Because synthetic batches
are pure functions of (seed, step) (data/synthetic.py), the duplicate is
bit-identical — re-dispatch never perturbs the training stream.

``DuplicateDispatcher`` is runtime-agnostic (callables in, result out) so it
is unit-testable on one host; launch/train.py wires it to per-step work.
"""

from __future__ import annotations

import threading
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Optional, Sequence, Tuple


class DuplicateDispatcher:
    """Run ``work(host)`` with an optional racing duplicate on a backup."""

    def __init__(self, *, deadline: float):
        self.deadline = deadline
        self._pool = ThreadPoolExecutor(max_workers=4)

    def run(
        self,
        work: Callable[[int], object],
        primary: int,
        backup: Optional[int] = None,
    ) -> Tuple[object, int]:
        """Returns (result, winning_host).

        Dispatches to ``primary``; if it misses ``deadline`` and a backup is
        given, races a duplicate and takes the first completion.
        """
        f_primary = self._pool.submit(work, primary)
        done, _ = wait([f_primary], timeout=self.deadline)
        if f_primary in done:
            return f_primary.result(), primary
        if backup is None:
            return f_primary.result(), primary  # no spare: block it out
        f_backup = self._pool.submit(work, backup)
        done, _ = wait([f_primary, f_backup], return_when=FIRST_COMPLETED)
        winner = f_primary if f_primary in done else f_backup
        host = primary if winner is f_primary else backup
        return winner.result(), host

    def close(self):
        self._pool.shutdown(wait=False, cancel_futures=True)


def pick_backup(step_times: dict, straggler: int) -> Optional[int]:
    """Fastest healthy host ≠ straggler (lowest EWMA step time)."""
    candidates = [(t, h) for h, t in step_times.items() if h != straggler]
    return min(candidates)[1] if candidates else None
