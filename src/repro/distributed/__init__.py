"""Distributed runtime: ring-sharded SD-KDE, fault tolerance, elasticity."""

from repro.distributed import ring  # noqa: F401
from repro.distributed.compression import (  # noqa: F401
    compress,
    compressed_psum,
    decompress,
    init_residual,
)
from repro.distributed.elastic import (  # noqa: F401
    MeshPlan,
    make_mesh,
    plan_mesh,
    rebatch,
    reshard_specs,
)
from repro.distributed.fault import RestartLoop, Supervisor  # noqa: F401
from repro.distributed.straggler import (  # noqa: F401
    DuplicateDispatcher,
    pick_backup,
)
