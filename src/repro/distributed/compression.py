"""Int8 gradient compression with error feedback (cross-pod all-reduce).

At 2+ pods the gradient all-reduce crosses the slow inter-pod links; int8
quantization cuts those bytes 4× (vs f32) / 2× (vs bf16).  Plain
quantization biases the update, so we keep the classic error-feedback
residual: the de-quantization error of step t is added back into the
gradient at step t+1, making the scheme unbiased in the long run
(Seide et al. 2014; Karimireddy et al. 2019).

Layout: per-tensor symmetric scaling (max-abs / 127).  ``compress`` /
``decompress`` are pure and shard-transparent — they run INSIDE the pjit'd
train step, so GSPMD reduces the int8 tensors and the f32 scales instead of
the full-precision gradients.

The quantize→all-reduce→dequantize pattern here reduces QUANTIZED gradients
(sum of int8 payloads in f32 accumulation); with R ring participants the
wire format is int8 while the accumulator stays exact.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def compress(
    grads: Dict[str, jnp.ndarray],
    residual: Dict[str, jnp.ndarray] | None,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """Quantize grads+residual to int8; returns (q, scales, new_residual)."""
    q, scales, new_res = {}, {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32)
        if residual is not None:
            g32 = g32 + residual[k]
        s = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        qk = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        q[k], scales[k] = qk, s
        new_res[k] = g32 - qk.astype(jnp.float32) * s   # error feedback
    return q, scales, new_res


def decompress(
    q: Dict[str, jnp.ndarray], scales: Dict[str, jnp.ndarray]
) -> Dict[str, jnp.ndarray]:
    return {k: q[k].astype(jnp.float32) * scales[k] for k in q}


def init_residual(params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.zeros(p.shape, jnp.float32) for k, p in params.items()}


def compressed_psum(
    grads: Dict[str, jnp.ndarray],
    residual: Dict[str, jnp.ndarray],
    axis_name: str,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """All-reduce mean of int8-compressed grads over ``axis_name``
    (shard_map context).  Returns (mean_grads, new_residual)."""
    q, s, new_res = compress(grads, residual)
    n = jax.lax.psum(1, axis_name)
    out = {}
    for k in q:
        # int8 payload summed in f32 (wire bytes: 1/axis member/element).
        acc = jax.lax.psum(q[k].astype(jnp.float32) * s[k], axis_name)
        out[k] = acc / n
    return out, new_res
