"""Sharded async checkpointing with rotation and elastic restore.

Layout per step::

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step metadata
        shard_<host>.npz     # this host's addressable shards
        _COMMITTED           # written last — torn checkpoints are ignored

Properties a 1000-node deployment needs, scaled to this container:

  * **Sharded writes** — every host writes only its addressable shards
    (``addressable_shards``); no host gathers the full state.  (On one host
    this degenerates to a single npz, same code path.)
  * **Async** — ``save`` returns immediately; the serialization runs on a
    background thread against host copies snapshot'd at call time, so the
    train loop never blocks on disk.
  * **Atomicity** — the ``_COMMITTED`` marker commits a checkpoint;
    ``latest_step`` skips torn directories, so a node failure mid-save
    never corrupts restart.
  * **Rotation** — keep the newest ``keep`` committed checkpoints.
  * **Elastic restore** — ``restore`` takes the *target* shardings; arrays
    are re-assembled host-side and ``device_put`` with the new sharding, so
    a job restarted on a different mesh (shrunk/regrown) resharding is
    automatic.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

_COMMIT = "_COMMITTED"


def _flatten(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}\x1f"))
        return out
    out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("\x1f")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_pytree(tree: Any, directory: str, *, host_id: int = 0) -> None:
    """Synchronous sharded save of one pytree into ``directory``."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    manifest = {}
    arrays = {}
    for i, (path, arr) in enumerate(flat.items()):
        arr = jnp.asarray(arr)
        manifest[path] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "key": f"a{i}",
        }
        # Host-local view: for fully-addressable arrays this is the whole
        # array; for multi-host arrays, only our shards (index recorded).
        if isinstance(arr, jax.Array) and not arr.is_fully_addressable:
            shards = [
                {"index": [[s.start, s.stop] for s in sh.index],
                 "data": np.asarray(sh.data)}
                for sh in arr.addressable_shards
            ]
            manifest[path]["sharded"] = True
            for j, sh in enumerate(shards):
                arrays[f"a{i}_s{j}"] = sh["data"]
            manifest[path]["shard_index"] = [s["index"] for s in shards]
        else:
            arrays[f"a{i}"] = np.asarray(arr)
    np.savez(os.path.join(directory, f"shard_{host_id}.npz"), **arrays)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(directory, _COMMIT), "w") as f:
        f.write("ok")


def restore_pytree(
    directory: str,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore a pytree; ``shardings`` (same structure, NamedSharding leaves)
    re-places arrays on the *current* mesh — the elastic-restart path."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(directory)):
        if fname.startswith("shard_") and fname.endswith(".npz"):
            with np.load(os.path.join(directory, fname)) as z:
                data.update({k: z[k] for k in z.files})

    flat_sh = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for path, meta in manifest.items():
        if meta.get("sharded"):
            full = np.zeros(meta["shape"], meta["dtype"])
            for j, idx in enumerate(meta["shard_index"]):
                sl = tuple(slice(a, b) for a, b in idx)
                full[sl] = data[f"{meta['key']}_s{j}"]
            arr = full
        else:
            arr = data[meta["key"]]
        # jnp handles extension dtypes (bfloat16) that raw numpy can't name
        arr = np.asarray(jnp.asarray(arr).astype(meta["dtype"]))
        sh = flat_sh.get(path)
        flat[path] = jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
    return _unflatten(flat)


class CheckpointManager:
    """Async save / rotate / restore driver for the train loop."""

    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def committed_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.directory, name, _COMMIT)
            ):
                steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    # -- save / restore ----------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot to host memory now; write on the background thread."""
        self.wait()  # one in-flight save at a time (bounded host memory)
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            d = self._step_dir(step)
            save_pytree(host_tree, d, host_id=self.host_id)
            self._rotate()

        self._pending = self._pool.submit(work)
        if blocking:
            self.wait()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def restore(self, shardings: Optional[Any] = None,
                step: Optional[int] = None) -> Any:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.directory}")
        return restore_pytree(self._step_dir(step), shardings)

    def _rotate(self):
        with self._lock:
            steps = self.committed_steps()
            for s in steps[: -self.keep]:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
