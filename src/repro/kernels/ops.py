"""Public wrappers around the Flash-SD-KDE Pallas kernels.

Responsibilities: pad point sets to tile multiples (with far-away sentinel
points whose kernel weight underflows to exactly 0.0, so padding never
changes a result), precompute squared norms and transposed layouts (lane
axis = the streamed column dimension, which is what the TPU wants), budget
VMEM, launch the kernels, slice off padding and normalize.

Three launch knobs thread through every wrapper here:

  * ``precision`` — the GEMM-operand tier (``"f32"`` / ``"bf16"`` /
    ``"bf16x2"``, kernels/precision.py).  Norms, distances, exponentials and
    accumulators stay f32 at every tier; only the MXU operands shrink.
  * ``block_m`` / ``block_n`` — the launch tile, either explicit ints or
    ``"auto"`` (the default), which consults the model-guided autotuner
    (kernels/autotune.py): cost-model shortlist on the padded problem,
    optional on-device timing, memoized winners.
  * ``prune`` — cluster pruning (kernels/spatial.py): ``"off"`` streams
    every tile pair (dense), a float ``epsilon ≥ 0`` reorders the train set
    spatially and skips column tiles whose certified per-point contribution
    is ≤ epsilon (``0.0`` = only tiles whose every term underflows to
    exactly 0.0 in f32 — the dense result, cheaper), and ``"auto"`` (the
    default) applies exact (epsilon=0) pruning once the streamed set is
    large enough to pay for the bounds prepass.

Every function here has a pure-jnp oracle in ``ref.py`` and an allclose
sweep in ``tests/``.
"""

from __future__ import annotations

import functools
import math
import threading
import weakref
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.bandwidth import gaussian_norm_const
from repro.kernels import autotune, flash_pruned, spatial
from repro.kernels import precision as prec
from repro.obs import state as obs_state
from repro.kernels.flash_kde import flash_kde_pallas
from repro.kernels.flash_laplace import flash_laplace_pallas, sq_moment_pallas
from repro.kernels.flash_score import flash_score_pallas

PAD_VALUE = 1.0e6
# VMEM is ~16 MiB/core on v5e; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_STATIC = ("precision", "block_m", "block_n", "interpret")

PruneArg = Union[str, float]  # "auto" | "off" | epsilon ≥ 0

#: ``prune="auto"`` enables exact pruning only past these sizes — below
#: them the bounds prepass and host-side visit-list compaction cost more
#: than the skipped tiles were worth.
PRUNE_AUTO_MIN_COLS = 16384
PRUNE_AUTO_MIN_TILES = 4


def resolve_prune(prune: PruneArg, cols: int, block_n: int) -> Optional[float]:
    """The per-point epsilon a prune argument means; None = dense."""
    if prune is None or prune is False or prune == "off":
        return None
    if prune == "auto":
        if (cols >= PRUNE_AUTO_MIN_COLS
                and cols >= PRUNE_AUTO_MIN_TILES * block_n):
            return 0.0
        return None
    if isinstance(prune, str):
        raise ValueError(
            f"bad prune argument {prune!r} (choose 'auto', 'off', or a "
            "float epsilon >= 0)"
        )
    eps = float(prune)
    if not eps >= 0.0:
        raise ValueError(f"prune epsilon must be >= 0, got {eps}")
    return eps


def _apply_plan(plan, n: int, m: int, d: int, *,
                precision, block_m, block_n, prune):
    """Fill wrapper knobs still at their defaults from an execution plan.

    ``plan`` is None (no-op), ``"auto"`` (resolve one via
    ``repro.plan.plan_for`` for this call's shape), or a resolved
    ``repro.plan.ExecutionPlan``.  Override precedence matches the serve
    layer: a knob passed away from its wrapper default always wins; a knob
    left at its default ("f32" / "auto") is filled from the plan.
    """
    if plan is None:
        return precision, block_m, block_n, prune
    if plan == "auto":
        from repro.plan import plan_for

        plan = plan_for(n, d, q=m, backend="pallas")
    if precision == "f32":
        precision = plan.precision
    if block_m == "auto" and plan.block_m is not None:
        block_m = plan.block_m
    if block_n == "auto" and plan.block_n is not None:
        block_n = plan.block_n
    if prune == "auto":
        prune = plan.prune
    return precision, block_m, block_n, prune


def _traced(*arrays) -> bool:
    """True when any argument is an abstract tracer (jit/vmap/grad).

    The pruned path host-syncs (visit-list compaction, layout shapes), so
    under tracing the public wrappers silently fall back to dense — the
    pre-pruning behavior, and the only one that can stay a single jaxpr.
    """
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


# One-shot wrappers amortize the spatial prep across repeated calls on the
# SAME train array (e.g. core.estimator evaluate loops): keyed by array
# identity, guarded by a weakref so a recycled id can never alias, holding
# at most a handful of live entries.
_COLUMNS_CACHE: dict = {}
_COLUMNS_LOCK = threading.Lock()


def _cached_columns(x, *, block_n: int, precision: str,
                    seed: int) -> "TrainColumns":
    key = (id(x), int(block_n), precision, seed)
    with _COLUMNS_LOCK:
        hit = _COLUMNS_CACHE.get(key)
        if hit is not None and hit[0]() is x:
            return hit[1]
    cols = prepare_train_columns(x, block_n=block_n, precision=precision,
                                 clustered=True, seed=seed)
    try:
        ref = weakref.ref(x)
    except TypeError:            # not weakref-able: skip caching
        return cols
    with _COLUMNS_LOCK:
        for k in [k for k, (r, _) in _COLUMNS_CACHE.items() if r() is None]:
            del _COLUMNS_CACHE[k]
        _COLUMNS_CACHE[key] = (ref, cols)
    return cols


def _pad_to(x: jnp.ndarray, mult: int, value: float = PAD_VALUE) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x
    return jnp.pad(x, [(0, rem)] + [(0, 0)] * (x.ndim - 1),
                   constant_values=value)


def _norms(x: jnp.ndarray) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    return jnp.sum(x32 * x32, axis=-1, keepdims=True)


def _tier_norms(hi: jnp.ndarray, lo: Optional[jnp.ndarray]) -> jnp.ndarray:
    """f32 squared norms of the points the tier-cast operands represent.

    Computing norms from the *cast* operands (not the f32 originals) keeps
    ``sq = ‖ŷ‖² + ‖x̂‖² − 2·ŷ·x̂`` an exact nonnegative squared distance of
    slightly perturbed points, so reduced precision acts as a data
    perturbation rather than cancellation noise in the exponent (see
    kernels/precision.py).
    """
    return _norms(prec.reconstruct(hi, lo))


def _inv2h2(h) -> jnp.ndarray:
    h = jnp.asarray(h, jnp.float32)
    return (1.0 / (2.0 * h * h)).reshape(1, 1)


def vmem_tile_bytes(block_m: int, block_n: int, d: int,
                    itemsize: int = 4, out_width: Optional[int] = None) -> int:
    """Per-step VMEM working set (inputs + φ tile + output accumulator).

    ``itemsize`` is the GEMM-operand byte width (4 f32, 2 bf16, 4 for the
    two-plane bf16x2 split — ``precision.operand_bytes``); norms, the φ
    tile, and the accumulator are always f32.  ``out_width`` is the
    accumulator width: the (block_n, d+1) xaug operand tile exists only on
    the score path (out_width = d+1); the KDE/Laplace paths (out_width = 1)
    carry neither it nor a (d+1)-wide accumulator.  None keeps the legacy
    conservative budget (score-shaped).
    """
    ow = out_width if out_width is not None else d + 1
    operand_elems = (
        block_m * d            # row tile
        + d * block_n          # xt column tile
        + (block_n * (d + 1) if ow > 1 else 0)   # xaug column tile (score)
    )
    f32_elems = (
        block_m                # row norms
        + block_n              # column norms
        + block_m * block_n    # φ tile (registers/VMEM intermediate)
        + block_m * ow         # accumulator
    )
    return operand_elems * itemsize + f32_elems * 4


def _check_vmem(block_m: int, block_n: int, d: int,
                itemsize: int = 4, out_width: Optional[int] = None) -> None:
    b = vmem_tile_bytes(block_m, block_n, d, itemsize, out_width)
    if b > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"tile working set {b/2**20:.1f} MiB exceeds VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB): block_m={block_m} "
            f"block_n={block_n} d={d} itemsize={itemsize}"
        )


def _resolve(block_m, block_n, rows, cols, d, *, out_width, precision,
             interpret, row_multiple=None, col_multiple=None, pruned=False):
    """Shared "auto"-tile resolution + dtype-aware VMEM gate."""
    block_m, block_n = autotune.resolve_blocks(
        block_m, block_n, rows, cols, d, out_width=out_width,
        precision=precision, row_multiple=row_multiple,
        col_multiple=col_multiple,
        measure=False if interpret else None,
        pruned=pruned,
    )
    _check_vmem(block_m, block_n, d, prec.operand_bytes(precision),
                out_width=out_width)
    return block_m, block_n


# ---------------------------------------------------------------------------
# Score statistics / SD-KDE shift.
# ---------------------------------------------------------------------------


def _score_operands(xp: jnp.ndarray, precision: str):
    """(x_ops, xt_ops, xaug_ops, nrm, xrec) for a padded train set."""
    npad = xp.shape[0]
    xaug = jnp.concatenate([xp, jnp.ones((npad, 1), xp.dtype)], axis=1)
    if precision == "f32":
        x_ops = (xp, None)
        xt_ops = (xp.astype(jnp.float32).T.astype(xp.dtype), None)
        xaug_ops = (xaug, None)
        xrec = xp.astype(jnp.float32)
    else:
        x_ops = prec.cast_operand(xp.astype(jnp.float32), precision)
        xt_ops = (x_ops[0].T, None if x_ops[1] is None else x_ops[1].T)
        xaug_ops = prec.cast_operand(xaug.astype(jnp.float32), precision)
        xrec = prec.reconstruct(*x_ops)
    return x_ops, xt_ops, xaug_ops, _norms(xrec), xrec


@functools.partial(jax.jit, static_argnames=_STATIC)
def _flash_score_stats_dense(
    x: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m=128,
    block_n=512,
    interpret: bool = False,
):
    n, d = x.shape
    mult = math.lcm(block_m, block_n)
    xp = _pad_to(x, mult)
    x_ops, xt_ops, xaug_ops, nrm, _ = _score_operands(xp, precision)
    s1aug = flash_score_pallas(
        x_ops[0], nrm, xt_ops[0], xaug_ops[0], _inv2h2(h),
        x_ops[1], xt_ops[1], xaug_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    s0 = s1aug[:n, d]
    s1 = s1aug[:n, :d]
    return s0, s1


def _record_occupancy_profile(rows, col_counts, d, launch_occ, block_n,
                              yrec, meta_fine, inv2h2, epsilon, block_m,
                              kind):
    """Feed the tuner's occupancy profile after one bounds prepass.

    The launch-width occupancy is recorded under every column-count key a
    later resolve may use (true train count and padded layout length).
    The fine-width probe — a second bounds pass at FINE_PROBE_BLOCK,
    ~block_n/128× the prepass cost — runs only until the profile has a
    fine record for this regime: after that the EMA has nothing new to
    learn and the hot query path skips it.
    """
    fine = autotune.FINE_PROBE_BLOCK
    for n_key in col_counts:
        autotune.record_occupancy(rows, n_key, d, launch_occ,
                                  block_n=block_n)
    if meta_fine is None or all(
        autotune.has_occupancy(rows, k, d, fine) for k in col_counts
    ):
        return
    fine_tm = spatial.tile_map(yrec, meta_fine, inv2h2, epsilon,
                               block_m=block_m, kind=kind)
    fine_occ = float(jnp.mean(fine_tm.keep))
    for n_key in col_counts:
        autotune.record_occupancy(rows, n_key, d, fine_occ, block_n=fine)


def _score_stats_pruned(
    x: jnp.ndarray,
    h,
    epsilon: float,
    index: spatial.SpatialIndex,
    *,
    precision: str,
    block_m: int,
    block_n: int,
    interpret: bool,
):
    """Pruned score pass; returns (S0, S1) in ``x``'s original row order.

    The score pass is train×train, so the cluster-aligned layout serves
    both axes: row tiles and column tiles of the same padded scatter, and
    the output rows come straight back through the layout's slot map.  The
    certificate uses the score kind — per-point bound exp(-arg)·max(1,
    max|x|) — because the accumulator weights are the [X | 1] columns.
    """
    n, d = x.shape
    layout = spatial.cluster_layout(
        jnp.asarray(x, jnp.float32), index.labels, block_n,
        total_multiple=math.lcm(block_m, block_n),
    )
    xp = layout.points
    x_ops, xt_ops, xaug_ops, nrm, xrec = _score_operands(xp, precision)
    col_meta = spatial.tile_metadata(xrec, layout.real, block=block_n)
    tm = spatial.tile_map(xrec, col_meta, _inv2h2(h), epsilon,
                          block_m=block_m, kind="score")
    vl = spatial.visit_lists(tm.keep)
    fine_meta = None
    if block_n > autotune.FINE_PROBE_BLOCK \
            and xp.shape[0] % autotune.FINE_PROBE_BLOCK == 0 \
            and not autotune.has_occupancy(n, n, d,
                                           autotune.FINE_PROBE_BLOCK):
        fine_meta = spatial.tile_metadata(xrec, layout.real,
                                          block=autotune.FINE_PROBE_BLOCK)
    _record_occupancy_profile(n, {n}, d, vl.occupancy, block_n, xrec,
                              fine_meta, _inv2h2(h), epsilon, block_m,
                              "score")
    _note_pruned_launch("score", vl, tm, epsilon)
    with obs.span("kernels.pruned_score", rows=n,
                  occupancy=round(vl.occupancy, 4)), \
            obs.annotate("flash_score_pruned"):
        s1aug = flash_pruned.flash_score_pallas_pruned(
            vl.counts, vl.tile_map, x_ops[0], nrm, xt_ops[0], xaug_ops[0],
            _inv2h2(h), x_ops[1], xt_ops[1], xaug_ops[1],
            block_m=block_m, block_n=block_n, max_visits=vl.max_visits,
            interpret=interpret,
        )
    rows = s1aug[layout.slots]
    return rows[:, d], rows[:, :d]


def _note_pruned_launch(kind: str, vl: spatial.VisitLists,
                        tm: spatial.TileMap, epsilon) -> None:
    """Record one pruned pass: visit fraction (= 1 − skip rate) and the
    certified error budget actually spent, so serving telemetry can show
    how sparse traffic really is and how close certificates run to their
    epsilon.  The max-reduction over the (tiny) per-row-tile err_bound
    vector host-syncs, so the whole helper is skipped when metrics are
    off — this already sits on the pruned path's host-sync boundary."""
    if not obs_state.metrics_on:
        return
    obs.counter("kernels.prune.launches", labels={"kind": kind}).inc()
    obs.histogram("kernels.prune.visit_fraction",
                  "column tiles visited / total per pruned pass",
                  lo=1e-3, hi=1.0).observe(vl.occupancy)
    err = float(jnp.max(tm.err_bound)) if tm.err_bound.size else 0.0
    obs.histogram("kernels.prune.cert_budget",
                  "max certified abs error of the unnormalized "
                  "accumulator per pruned pass",
                  lo=1e-30, hi=1.0, per_decade=1).observe(err)
    obs.gauge("kernels.prune.epsilon",
              "per-point contribution threshold of the last pruned "
              "pass").set(float(epsilon))


def flash_score_stats(
    x: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    prune: PruneArg = "auto",
    seed: int = 0,
    plan=None,
):
    """(S0, S1) score statistics over the train set via the fused kernel."""
    prec.validate(precision)
    n, d = x.shape
    precision, block_m, block_n, prune = _apply_plan(
        plan, n, n, d, precision=precision, block_m=block_m,
        block_n=block_n, prune=prune,
    )
    if _traced(x):
        prune = "off"            # pruning host-syncs; stay traceable
    block_m, block_n = _resolve(
        block_m, block_n, n, n, d, out_width=d + 1, precision=precision,
        interpret=interpret, pruned=prune != "off",
    )
    eps = resolve_prune(prune, n, block_n)
    if eps is None:
        return _flash_score_stats_dense(
            x, h, precision=precision, block_m=block_m, block_n=block_n,
            interpret=interpret,
        )
    index = spatial.build_index(x, seed=seed)
    return _score_stats_pruned(
        x, h, eps, index, precision=precision, block_m=block_m,
        block_n=block_n, interpret=interpret,
    )


def _apply_score_shift(x32: jnp.ndarray, s0, s1, h, sh) -> jnp.ndarray:
    """x^SD = x + (h²/2)·ŝ(x) from the fused statistics (rows aligned)."""
    sh = jnp.asarray(sh, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    score = (s1 - x32 * s0[:, None]) / (sh * sh * s0[:, None])
    return x32 + 0.5 * h * h * score


def flash_sdkde_shift(
    x: jnp.ndarray,
    h,
    *,
    score_h=None,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    prune: PruneArg = "auto",
    seed: int = 0,
    plan=None,
) -> jnp.ndarray:
    """Debiased samples x^SD = x + (h²/2)·ŝ(x), score via the flash kernel."""
    sh = h if score_h is None else score_h
    s0, s1 = flash_score_stats(
        x, sh, precision=precision,
        block_m=block_m, block_n=block_n, interpret=interpret,
        prune=prune, seed=seed, plan=plan,
    )
    return _apply_score_shift(x.astype(jnp.float32), s0, s1, h, sh)


# ---------------------------------------------------------------------------
# KDE / Laplace-KDE evaluation.
# ---------------------------------------------------------------------------


def _prep_eval(x, y, block_m, block_n, precision):
    """Pad, transpose, norm and tier-cast one (train, queries) pair."""
    yp = _pad_to(y, block_m)
    xp = _pad_to(x, block_n)
    if precision == "f32":
        y_ops = (yp, None)
        xt_ops = (xp.astype(jnp.float32).T.astype(xp.dtype), None)
        nrm_y, nrm_x = _norms(yp), _norms(xp).reshape(1, -1)
    else:
        y_ops = prec.cast_operand(yp.astype(jnp.float32), precision)
        x_ops = prec.cast_operand(xp.astype(jnp.float32), precision)
        # cast commutes with transpose: the lane-major column planes are
        # the row-layout planes transposed, and the column norms come from
        # the same cast values the kernel will stream.
        xt_ops = (x_ops[0].T, None if x_ops[1] is None else x_ops[1].T)
        nrm_y = _tier_norms(*y_ops)
        nrm_x = _tier_norms(*x_ops).reshape(1, -1)
    return y_ops, xt_ops, nrm_y, nrm_x


@functools.partial(jax.jit, static_argnames=_STATIC + ("laplace",))
def _flash_eval_dense(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m=128,
    block_n=512,
    interpret: bool = False,
    laplace: bool = False,
) -> jnp.ndarray:
    """Dense KDE / fused-Laplace evaluation (normalized densities)."""
    n, d = x.shape
    m = y.shape[0]
    y_ops, xt_ops, nrm_y, nrm_x = _prep_eval(x, y, block_m, block_n,
                                             precision)
    kernel = flash_laplace_pallas if laplace else flash_kde_pallas
    sums = kernel(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


def flash_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    prune: PruneArg = "auto",
    seed: int = 0,
    plan=None,
) -> jnp.ndarray:
    """Normalized Gaussian KDE densities at ``y`` (train set ``x``)."""
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    precision, block_m, block_n, prune = _apply_plan(
        plan, n, m, d, precision=precision, block_m=block_m,
        block_n=block_n, prune=prune,
    )
    if _traced(x, y):
        prune = "off"            # pruning host-syncs; stay traceable
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret, pruned=prune != "off",
    )
    eps = resolve_prune(prune, n, block_n)
    if eps is None:
        return _flash_eval_dense(
            x, y, h, precision=precision, block_m=block_m, block_n=block_n,
            interpret=interpret, laplace=False,
        )
    cols = _cached_columns(x, block_n=block_n, precision=precision,
                           seed=seed)
    sums = _pruned_eval_sums(
        y, cols, h, eps, precision=precision, block_m=block_m,
        block_n=block_n, interpret=interpret, laplace=False,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums / (n * gaussian_norm_const(d, 1.0) * h**d)


def flash_laplace_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    prune: PruneArg = "auto",
    seed: int = 0,
    plan=None,
) -> jnp.ndarray:
    """Fused Flash-Laplace-KDE densities at ``y`` — single quadratic pass."""
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    precision, block_m, block_n, prune = _apply_plan(
        plan, n, m, d, precision=precision, block_m=block_m,
        block_n=block_n, prune=prune,
    )
    if _traced(x, y):
        prune = "off"            # pruning host-syncs; stay traceable
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret, pruned=prune != "off",
    )
    eps = resolve_prune(prune, n, block_n)
    if eps is None:
        return _flash_eval_dense(
            x, y, h, precision=precision, block_m=block_m, block_n=block_n,
            interpret=interpret, laplace=True,
        )
    cols = _cached_columns(x, block_n=block_n, precision=precision,
                           seed=seed)
    sums = _pruned_eval_sums(
        y, cols, h, eps, precision=precision, block_m=block_m,
        block_n=block_n, interpret=interpret, laplace=True,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums / (n * gaussian_norm_const(d, 1.0) * h**d)


@functools.partial(jax.jit, static_argnames=_STATIC)
def laplace_kde_nonfused(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Non-fused Laplace baseline: two quadratic kernel launches (Fig. 4).

    Stays dense on purpose — it exists as the measured baseline for the
    fusion (and now pruning) speedups.
    """
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret,
    )
    y_ops, xt_ops, nrm_y, nrm_x = _prep_eval(x, y, block_m, block_n,
                                             precision)
    kde_sums = flash_kde_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    sq_mom = sq_moment_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    combined = (1.0 + d / 2.0) * kde_sums - sq_mom / (2.0 * h * h)
    return combined[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


# ---------------------------------------------------------------------------
# Prepared fast path (serving).
# ---------------------------------------------------------------------------


class TrainColumns(NamedTuple):
    """Fit-time prepared train tensors for one precision tier."""

    xt: jnp.ndarray                 # (d, n_padded) tier-cast hi plane
    xt_lo: Optional[jnp.ndarray]    # (d, n_padded) bf16 lo plane (bf16x2)
    nrm_x: jnp.ndarray              # (1, n_padded) f32 column norms
    # Cluster-pruning state (None on non-spatial prepares): per-column-tile
    # geometry certified against the tier-cast points, and the spatial
    # index whose centroids order incoming query batches.  ``meta_fine``
    # is the same geometry at the tuner's fine probe width — the pruned
    # wrappers measure occupancy there too, so the autotuner can
    # extrapolate skip rates to tile widths it has never launched.
    meta: Optional[spatial.TileMeta] = None
    index: Optional[spatial.SpatialIndex] = None
    meta_fine: Optional[spatial.TileMeta] = None
    block_n: int = 0                # prepare-time column-tile width


def prepare_train_columns(
    x: jnp.ndarray,
    *,
    block_n: "int | str" = 512,
    precision: str = "f32",
    clustered: bool = False,
    index: Optional[spatial.SpatialIndex] = None,
    seed: int = 0,
) -> TrainColumns:
    """One-time train-side prep for repeated evaluation against the same set.

    Pads the (debiased) train set to a ``block_n`` multiple with sentinel
    points, builds the transposed (d, n) layout the kernels stream as lane-
    major column tiles (cast to the requested precision tier — for bf16x2
    both hi and lo planes), and precomputes the f32 column squared norms.
    ``block_n`` may be ``"auto"`` (autotuned for a serving-scale row count).

    ``clustered=True`` instead scatters the points into the cluster-aligned
    sentinel-padded layout (k-means by default; pass ``index`` to reuse an
    existing clustering — its per-row labels apply directly when fitted on
    a row-aligned set, e.g. the pre-shift points) and attaches the per-tile
    metadata the pruned kernels' bounds prepass consumes.  The serving
    registry caches the result per tier so none of this work is repeated
    per query batch.
    """
    prec.validate(precision)
    if block_n == "auto":
        _, block_n = autotune.resolve_blocks(
            128, "auto", rows=4096, cols=x.shape[0], d=x.shape[-1],
            precision=precision, measure=False,
        )
    real = None
    if clustered:
        if index is None:
            index = spatial.build_index(x, seed=seed)
        labels = index.labels if (
            index.labels is not None
            and index.labels.shape[0] == x.shape[0]
        ) else spatial.assign(x, index)
        layout = spatial.cluster_layout(jnp.asarray(x), labels, block_n)
        xp, real = layout.points, layout.real
    else:
        xp = _pad_to(x, block_n)
    return columns_from_layout(
        xp, real, index if clustered else None,
        block_n=block_n, precision=precision,
    )


def columns_from_layout(
    xp: jnp.ndarray,
    real: Optional[jnp.ndarray],
    index: Optional[spatial.SpatialIndex],
    *,
    block_n: int,
    precision: str = "f32",
) -> TrainColumns:
    """TrainColumns from an already-scattered padded layout.

    The streaming layer owns its layout (slack slots, in-place refreshes)
    and calls this to (re)build the per-tier cast planes + norms + tile
    metadata; ``prepare_train_columns`` routes through here too, so both
    paths share one casting/metadata recipe.  ``real=None`` means a plain
    tail-padded (non-clustered) layout: no metadata is attached.
    """
    prec.validate(precision)
    if precision == "f32":
        xt, xt_lo = xp.astype(jnp.float32).T.astype(xp.dtype), None
        xrec = xp.astype(jnp.float32)
        nrm_x = _norms(xp).reshape(1, -1)
    else:
        x_hi, x_lo = prec.cast_operand(xp.astype(jnp.float32), precision)
        xt, xt_lo = x_hi.T, None if x_lo is None else x_lo.T
        xrec = prec.reconstruct(x_hi, x_lo)
        nrm_x = _norms(xrec).reshape(1, -1)
    meta = meta_fine = None
    if real is not None:
        meta = spatial.tile_metadata(xrec, real, block=block_n)
        fine = autotune.FINE_PROBE_BLOCK
        if block_n > fine and xp.shape[0] % fine == 0:
            meta_fine = spatial.tile_metadata(xrec, real, block=fine)
    return TrainColumns(xt, xt_lo, nrm_x, meta, index, meta_fine, block_n)


def update_train_columns(
    cols: TrainColumns,
    xp: jnp.ndarray,
    real: jnp.ndarray,
    tiles,
    *,
    precision: str = "f32",
) -> TrainColumns:
    """Refresh prepared columns for only the listed column tiles.

    The streaming delta path: after appends/evictions/shift drift touch a
    subset of tiles, re-cast those tiles' operand columns, recompute their
    norms and tile metadata, and carry every untouched column over
    bit-for-bit.  The *compute* saved is the per-tile cast/split, norm
    and metadata reductions — the functional ``.at[].set`` updates still
    copy the full (d, n) planes, so a flush remains Θ(n·d) in memory
    traffic; what this buys is skipping the reduction work and keeping
    clean tiles' certificates byte-identical.  ``tiles`` may contain
    repeats (pow2-padded index buffers keep retraces bounded); each write
    is recomputed from the current layout, so repeated writes are
    idempotent.
    """
    prec.validate(precision)
    block = cols.block_n
    tiles_np = np.asarray(tiles, np.int64).reshape(-1)
    if tiles_np.size == 0:
        return cols
    rows_np = tiles_np[:, None] * block + np.arange(block)[None, :]
    rows = jnp.asarray(rows_np.reshape(-1), jnp.int32)
    sub = jnp.asarray(xp, jnp.float32)[rows]             # (k·block, d)
    if precision == "f32":
        hi, lo = sub.astype(cols.xt.dtype), None
        rec = sub
    else:
        hi, lo = prec.cast_operand(sub, precision)
        rec = prec.reconstruct(hi, lo)
    xt = cols.xt.at[:, rows].set(hi.T)
    xt_lo = cols.xt_lo if cols.xt_lo is None else (
        cols.xt_lo.at[:, rows].set(lo.T)
    )
    nrm_x = cols.nrm_x.at[0, rows].set(_norms(rec)[:, 0])
    meta, meta_fine = cols.meta, cols.meta_fine
    if meta is not None:
        mask = jnp.asarray(real)[rows]
        meta = spatial.merge_tile_meta(
            meta, tiles_np,
            spatial.tile_meta_from_rows(
                rec.reshape(tiles_np.size, block, -1),
                mask.reshape(tiles_np.size, block),
            ),
        )
        if meta_fine is not None:
            fine = autotune.FINE_PROBE_BLOCK
            ratio = block // fine
            ftiles = (tiles_np[:, None] * ratio
                      + np.arange(ratio)[None, :]).reshape(-1)
            meta_fine = spatial.merge_tile_meta(
                meta_fine, ftiles,
                spatial.tile_meta_from_rows(
                    rec.reshape(ftiles.size, fine, -1),
                    mask.reshape(ftiles.size, fine),
                ),
            )
    return cols._replace(xt=xt, xt_lo=xt_lo, nrm_x=nrm_x, meta=meta,
                         meta_fine=meta_fine)


def _cast_queries(yp: jnp.ndarray, precision: str):
    """(y_hi, y_lo, nrm_y, yrec) for a padded query block at one tier."""
    if precision == "f32":
        y_hi, y_lo = yp, None
        yrec = yp.astype(jnp.float32)
    else:
        y_hi, y_lo = prec.cast_operand(yp.astype(jnp.float32), precision)
        yrec = prec.reconstruct(y_hi, y_lo)
    return y_hi, y_lo, _norms(yrec), yrec


def _pruned_eval_sums(
    y: jnp.ndarray,
    cols: TrainColumns,
    h,
    epsilon: float,
    *,
    precision: str,
    block_m: int,
    block_n: int,
    interpret: bool,
    laplace: bool,
    n_real: Optional[int] = None,
) -> jnp.ndarray:
    """Pruned kernel sums (len(y),) for queries against prepared columns.

    ``y`` may carry sentinel padding rows past ``n_real`` (the serving
    path); only real rows enter the query layout.  This is the pruned
    path's one host-sync orchestration: assign queries to the train
    clusters → scatter into a cluster-aligned layout → bounds prepass →
    compact visit lists (host) → launch → gather back to request order.
    """
    if cols.meta is None or cols.index is None:
        raise ValueError(
            "pruned evaluation needs spatially prepared train columns "
            "(prepare_train_columns(..., clustered=True))"
        )
    if cols.block_n != block_n:
        raise ValueError(
            "pruned launch block_n must match the width the columns were "
            f"prepared at: launch {block_n} vs prepared {cols.block_n} — "
            "the tile metadata and visit lists address tiles of that width"
        )
    y = jnp.asarray(y)
    m_in, d = y.shape
    nr = m_in if n_real is None else min(n_real, m_in)
    # scatter the real queries into their own cluster-aligned layout
    # (assigned against the train centroids) so row tiles stay coherent
    labels = spatial.assign(y[:nr], cols.index)
    qlayout = spatial.cluster_layout(
        jnp.asarray(y[:nr], jnp.float32), labels, block_m, bucket_rows=True
    )
    yp = qlayout.points
    y_hi, y_lo, nrm_y, yrec = _cast_queries(yp, precision)
    kind = "laplace" if laplace else "kde"
    tm = spatial.tile_map(yrec, cols.meta, _inv2h2(h), epsilon,
                          block_m=block_m, kind=kind)
    vl = spatial.visit_lists(tm.keep)
    # record under BOTH column counts a later resolve may key on: the
    # true train count (flash_kde / flash_sdkde resolve pre-padding) and
    # the padded layout length (the prepared serving path)
    n_true = int(cols.meta.counts.sum())
    _record_occupancy_profile(m_in, {n_true, cols.xt.shape[1]}, d,
                              vl.occupancy, block_n, yrec, cols.meta_fine,
                              _inv2h2(h), epsilon, block_m, kind)
    _note_pruned_launch(kind, vl, tm, epsilon)
    with obs.span("kernels.pruned_eval", rows=nr, kind=kind,
                  occupancy=round(vl.occupancy, 4),
                  max_visits=vl.max_visits), \
            obs.annotate("flash_kde_pruned"):
        sums = flash_pruned.flash_kde_pallas_pruned(
            vl.counts, vl.tile_map, y_hi, nrm_y, cols.xt, cols.nrm_x,
            _inv2h2(h), y_lo, cols.xt_lo,
            block_m=block_m, block_n=block_n, max_visits=vl.max_visits,
            interpret=interpret, laplace=laplace,
        )
    out = sums[qlayout.slots, 0]                 # back to request order
    if nr < m_in:                                # caller's sentinel tail
        out = jnp.concatenate([out, jnp.zeros((m_in - nr,), out.dtype)])
    return out


@functools.partial(jax.jit, static_argnames=_STATIC + ("laplace",))
def _flash_kde_prepared_dense(
    yp: jnp.ndarray,
    xt: jnp.ndarray,
    nrm_x: jnp.ndarray,
    h,
    xt_lo: jnp.ndarray | None = None,
    *,
    precision: str = "f32",
    block_m=128,
    block_n=512,
    interpret: bool = False,
    laplace: bool = False,
) -> jnp.ndarray:
    y_hi, y_lo, nrm_y, _ = _cast_queries(yp, precision)
    kernel = flash_laplace_pallas if laplace else flash_kde_pallas
    sums = kernel(
        y_hi, nrm_y, xt, nrm_x, _inv2h2(h), y_lo, xt_lo,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return sums[:, 0]


def flash_kde_prepared(
    yp: jnp.ndarray,       # (m, d) queries, ALREADY padded to block_m multiple
    xt: jnp.ndarray,       # (d, n) from prepare_train_columns (tier-cast)
    nrm_x: jnp.ndarray,    # (1, n) from prepare_train_columns
    h,
    xt_lo: jnp.ndarray | None = None,  # (d, n) lo plane (bf16x2 tier)
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    laplace: bool = False,
    prune: PruneArg = "off",
    columns: Optional[TrainColumns] = None,
    n_real: Optional[int] = None,
) -> jnp.ndarray:
    """No-reassert fast path: unnormalized kernel sums for pre-padded queries.

    Skips the per-call padding, transposition and norm precomputation that
    ``flash_kde`` does — the serving layer pads queries to shape-bucket
    multiples of ``block_m`` up front and reuses the prepared train tensors
    (cached per precision tier) across every batch.  Returns raw sums (m,);
    the caller divides by ``n_true · (2π)^{d/2} h^d`` (padding rows give ~0
    and are sliced off by the caller).

    ``prune`` ≠ "off" takes the cluster-pruned path: pass the full
    ``columns`` (prepared with ``clustered=True``, so the tile metadata and
    spatial index are fit-time state) and ``n_real`` = the true query count
    so sentinel padding rows stay out of the row-tile geometry.  The dense
    path stays jit-traceable; the pruned path host-syncs once per batch to
    compact its visit lists.
    """
    prec.validate(precision)
    if _traced(yp):
        prune = "off"            # pruning host-syncs; stay traceable
    if (precision == "bf16x2") != (xt_lo is not None):
        raise ValueError(
            "bf16x2 needs prepared lo planes (and other tiers must not "
            f"pass them): precision={precision} xt_lo={xt_lo is not None}"
        )
    m, d = yp.shape
    n = xt.shape[1]
    if prune != "off" and columns is not None and block_n == "auto":
        # the visit lists index tiles of the prepare-time width — an
        # autotuned width that differs would silently misaddress them
        block_n = columns.block_n
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret, row_multiple=m, col_multiple=n,
        pruned=prune != "off",
    )
    eps = resolve_prune(prune, n, block_n)
    if eps is None:
        with obs.annotate("flash_kde_prepared_dense"):
            return _flash_kde_prepared_dense(
                yp, xt, nrm_x, h, xt_lo, precision=precision,
                block_m=block_m, block_n=block_n, interpret=interpret,
                laplace=laplace,
            )
    if columns is None:
        raise ValueError(
            "flash_kde_prepared(prune=...) needs columns= (the clustered "
            "TrainColumns) for the tile metadata"
        )
    return _pruned_eval_sums(
        yp, columns, h, eps, precision=precision, block_m=block_m,
        block_n=block_n, interpret=interpret, laplace=laplace, n_real=n_real,
    )


# ---------------------------------------------------------------------------
# Full pipeline.
# ---------------------------------------------------------------------------


def flash_sdkde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    score_h=None,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    prune: PruneArg = "auto",
    seed: int = 0,
    plan=None,
) -> jnp.ndarray:
    """Full Flash-SD-KDE: score pass → shift → KDE at queries (normalized).

    The pipeline shares one train-side prep: the spatial clustering is
    computed once on ``x`` and its layout is reused for the score pass
    (train×train) *and* the KDE eval on the shifted set — the debias shift
    is O(h²), so the ordering stays tight — and the shifted set flows
    through ``prepare_train_columns`` (no second pad/transpose).
    """
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    precision, block_m, block_n, prune = _apply_plan(
        plan, n, m, d, precision=precision, block_m=block_m,
        block_n=block_n, prune=prune,
    )
    if _traced(x, y):
        prune = "off"            # pruning host-syncs; stay traceable
    sh = h if score_h is None else score_h
    s_bm, s_bn = _resolve(
        block_m, block_n, n, n, d, out_width=d + 1, precision=precision,
        interpret=interpret, pruned=prune != "off",
    )
    k_bm, k_bn = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret, pruned=prune != "off",
    )
    s_eps = resolve_prune(prune, n, s_bn)
    k_eps = resolve_prune(prune, n, k_bn)

    x32 = jnp.asarray(x, jnp.float32)
    index = None
    if s_eps is not None or k_eps is not None:
        index = spatial.build_index(x32, seed=seed)
    if s_eps is None:
        s0, s1 = _flash_score_stats_dense(
            x32, sh, precision=precision, block_m=s_bm, block_n=s_bn,
            interpret=interpret,
        )
    else:
        s0, s1 = _score_stats_pruned(
            x32, sh, s_eps, index, precision=precision, block_m=s_bm,
            block_n=s_bn, interpret=interpret,
        )
    x_sd = _apply_score_shift(x32, s0, s1, h, sh)

    # one shared eval-side prep, reusing the clustering: the labels fitted
    # on x stay valid row-for-row for the O(h²)-shifted x_sd
    cols = prepare_train_columns(
        x_sd, block_n=k_bn, precision=precision,
        clustered=k_eps is not None, index=index if k_eps is not None
        else None,
    )
    if k_eps is None:
        yp = _pad_to(jnp.asarray(y), k_bm)
        sums = _flash_kde_prepared_dense(
            yp, cols.xt, cols.nrm_x, h, cols.xt_lo, precision=precision,
            block_m=k_bm, block_n=k_bn, interpret=interpret, laplace=False,
        )[:m]
    else:
        sums = _pruned_eval_sums(
            y, cols, h, k_eps, precision=precision, block_m=k_bm,
            block_n=k_bn, interpret=interpret, laplace=False,
        )
    h = jnp.asarray(h, jnp.float32)
    return sums / (n * gaussian_norm_const(d, 1.0) * h**d)
