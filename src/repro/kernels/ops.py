"""Public jit'd wrappers around the Flash-SD-KDE Pallas kernels.

Responsibilities: pad point sets to tile multiples (with far-away sentinel
points whose kernel weight underflows to exactly 0.0, so padding never
changes a result), precompute squared norms and transposed layouts (lane
axis = the streamed column dimension, which is what the TPU wants), budget
VMEM, launch the kernels, slice off padding and normalize.

Every function here has a pure-jnp oracle in ``ref.py`` and an allclose
sweep in ``tests/``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.bandwidth import gaussian_norm_const
from repro.kernels.flash_kde import flash_kde_pallas
from repro.kernels.flash_laplace import flash_laplace_pallas, sq_moment_pallas
from repro.kernels.flash_score import flash_score_pallas

PAD_VALUE = 1.0e6
# VMEM is ~16 MiB/core on v5e; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _pad_to(x: jnp.ndarray, mult: int, value: float = PAD_VALUE) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x
    return jnp.pad(x, [(0, rem)] + [(0, 0)] * (x.ndim - 1),
                   constant_values=value)


def _norms(x: jnp.ndarray) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    return jnp.sum(x32 * x32, axis=-1, keepdims=True)


def _inv2h2(h) -> jnp.ndarray:
    h = jnp.asarray(h, jnp.float32)
    return (1.0 / (2.0 * h * h)).reshape(1, 1)


def vmem_tile_bytes(block_m: int, block_n: int, d: int,
                    itemsize: int = 4) -> int:
    """Per-step VMEM working set (inputs + φ tile + output accumulator)."""
    tiles = (
        block_m * d            # row tile
        + block_m              # row norms
        + d * block_n          # xt column tile
        + block_n * (d + 1)    # xaug column tile
        + block_n              # column norms
        + block_m * block_n    # φ tile (registers/VMEM intermediate)
        + block_m * (d + 1)    # accumulator
    )
    return tiles * itemsize


def _check_vmem(block_m: int, block_n: int, d: int) -> None:
    b = vmem_tile_bytes(block_m, block_n, d)
    if b > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"tile working set {b/2**20:.1f} MiB exceeds VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB): block_m={block_m} "
            f"block_n={block_n} d={d}"
        )


# ---------------------------------------------------------------------------
# Score statistics / SD-KDE shift.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def flash_score_stats(
    x: jnp.ndarray,
    h,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
):
    """(S0, S1) score statistics over the train set via the fused kernel."""
    n, d = x.shape
    _check_vmem(block_m, block_n, d)
    mult = math.lcm(block_m, block_n)
    xp = _pad_to(x, mult)
    npad = xp.shape[0]
    xaug = jnp.concatenate(
        [xp, jnp.ones((npad, 1), xp.dtype)], axis=1
    )
    s1aug = flash_score_pallas(
        xp, _norms(xp), xp.astype(jnp.float32).T.astype(xp.dtype), xaug,
        _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    s0 = s1aug[:n, d]
    s1 = s1aug[:n, :d]
    return s0, s1


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_sdkde_shift(
    x: jnp.ndarray,
    h,
    *,
    score_h=None,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Debiased samples x^SD = x + (h²/2)·ŝ(x), score via the flash kernel."""
    sh = h if score_h is None else score_h
    s0, s1 = flash_score_stats(
        x, sh, block_m=block_m, block_n=block_n, interpret=interpret
    )
    sh = jnp.asarray(sh, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    x32 = x.astype(jnp.float32)
    score = (s1 - x32 * s0[:, None]) / (sh * sh * s0[:, None])
    return x32 + 0.5 * h * h * score


# ---------------------------------------------------------------------------
# KDE / Laplace-KDE evaluation.
# ---------------------------------------------------------------------------


def _prep_eval(x, y, block_m, block_n):
    d = x.shape[-1]
    _check_vmem(block_m, block_n, d)
    yp = _pad_to(y, block_m)
    xp = _pad_to(x, block_n)
    xt = xp.astype(jnp.float32).T.astype(xp.dtype)
    return yp, xp, xt


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def flash_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Normalized Gaussian KDE densities at ``y`` (train set ``x``)."""
    n, d = x.shape
    m = y.shape[0]
    yp, xp, xt = _prep_eval(x, y, block_m, block_n)
    sums = flash_kde_pallas(
        yp, _norms(yp), xt, _norms(xp).reshape(1, -1), _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def flash_laplace_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused Flash-Laplace-KDE densities at ``y`` — single quadratic pass."""
    n, d = x.shape
    m = y.shape[0]
    yp, xp, xt = _prep_eval(x, y, block_m, block_n)
    sums = flash_laplace_pallas(
        yp, _norms(yp), xt, _norms(xp).reshape(1, -1), _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def laplace_kde_nonfused(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Non-fused Laplace baseline: two quadratic kernel launches (Fig. 4)."""
    n, d = x.shape
    m = y.shape[0]
    yp, xp, xt = _prep_eval(x, y, block_m, block_n)
    nrm_y, nrm_x = _norms(yp), _norms(xp).reshape(1, -1)
    kde_sums = flash_kde_pallas(
        yp, nrm_y, xt, nrm_x, _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    sq_mom = sq_moment_pallas(
        yp, nrm_y, xt, nrm_x, _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    combined = (1.0 + d / 2.0) * kde_sums - sq_mom / (2.0 * h * h)
    return combined[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


# ---------------------------------------------------------------------------
# Prepared fast path (serving).
# ---------------------------------------------------------------------------


def prepare_train_columns(x: jnp.ndarray, *, block_n: int = 512):
    """One-time train-side prep for repeated evaluation against the same set.

    Pads the (debiased) train set to a ``block_n`` multiple with sentinel
    points, builds the transposed (d, n) layout the kernels stream as lane-
    major column tiles, and precomputes the column squared norms.  The
    returned ``(xt, nrm_x)`` pair is what ``flash_kde_prepared`` consumes —
    the serving registry caches it so none of this work is repeated per
    query batch.
    """
    xp = _pad_to(x, block_n)
    xt = xp.astype(jnp.float32).T.astype(xp.dtype)
    return xt, _norms(xp).reshape(1, -1)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret", "laplace")
)
def flash_kde_prepared(
    yp: jnp.ndarray,       # (m, d) queries, ALREADY padded to block_m multiple
    xt: jnp.ndarray,       # (d, n) from prepare_train_columns
    nrm_x: jnp.ndarray,    # (1, n) from prepare_train_columns
    h,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
    laplace: bool = False,
) -> jnp.ndarray:
    """No-reassert fast path: unnormalized kernel sums for pre-padded queries.

    Skips the per-call padding, transposition and norm precomputation that
    ``flash_kde`` does — the serving layer pads queries to shape-bucket
    multiples of ``block_m`` up front and reuses the prepared train tensors
    across every batch.  Returns raw sums (m,); the caller divides by
    ``n_true · (2π)^{d/2} h^d`` (padding rows give ~0 and are sliced off by
    the caller).
    """
    d = yp.shape[-1]
    _check_vmem(block_m, block_n, d)
    kernel = flash_laplace_pallas if laplace else flash_kde_pallas
    sums = kernel(
        yp, _norms(yp), xt, nrm_x, _inv2h2(h),
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return sums[:, 0]


# ---------------------------------------------------------------------------
# Full pipeline.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def flash_sdkde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    score_h=None,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Full Flash-SD-KDE: score pass → shift → KDE at queries (normalized)."""
    x_sd = flash_sdkde_shift(
        x, h, score_h=score_h,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return flash_kde(
        x_sd, y, h, block_m=block_m, block_n=block_n, interpret=interpret
    )
