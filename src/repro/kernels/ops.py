"""Public jit'd wrappers around the Flash-SD-KDE Pallas kernels.

Responsibilities: pad point sets to tile multiples (with far-away sentinel
points whose kernel weight underflows to exactly 0.0, so padding never
changes a result), precompute squared norms and transposed layouts (lane
axis = the streamed column dimension, which is what the TPU wants), budget
VMEM, launch the kernels, slice off padding and normalize.

Two launch knobs thread through every wrapper here:

  * ``precision`` — the GEMM-operand tier (``"f32"`` / ``"bf16"`` /
    ``"bf16x2"``, kernels/precision.py).  Norms, distances, exponentials and
    accumulators stay f32 at every tier; only the MXU operands shrink.
  * ``block_m`` / ``block_n`` — the launch tile, either explicit ints or
    ``"auto"`` (the default), which consults the model-guided autotuner
    (kernels/autotune.py): cost-model shortlist on the padded problem,
    optional on-device timing, memoized winners.

Every function here has a pure-jnp oracle in ``ref.py`` and an allclose
sweep in ``tests/``.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.bandwidth import gaussian_norm_const
from repro.kernels import autotune
from repro.kernels import precision as prec
from repro.kernels.flash_kde import flash_kde_pallas
from repro.kernels.flash_laplace import flash_laplace_pallas, sq_moment_pallas
from repro.kernels.flash_score import flash_score_pallas

PAD_VALUE = 1.0e6
# VMEM is ~16 MiB/core on v5e; leave headroom for double buffering.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_STATIC = ("precision", "block_m", "block_n", "interpret")


def _pad_to(x: jnp.ndarray, mult: int, value: float = PAD_VALUE) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0:
        return x
    return jnp.pad(x, [(0, rem)] + [(0, 0)] * (x.ndim - 1),
                   constant_values=value)


def _norms(x: jnp.ndarray) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    return jnp.sum(x32 * x32, axis=-1, keepdims=True)


def _tier_norms(hi: jnp.ndarray, lo: Optional[jnp.ndarray]) -> jnp.ndarray:
    """f32 squared norms of the points the tier-cast operands represent.

    Computing norms from the *cast* operands (not the f32 originals) keeps
    ``sq = ‖ŷ‖² + ‖x̂‖² − 2·ŷ·x̂`` an exact nonnegative squared distance of
    slightly perturbed points, so reduced precision acts as a data
    perturbation rather than cancellation noise in the exponent (see
    kernels/precision.py).
    """
    return _norms(prec.reconstruct(hi, lo))


def _inv2h2(h) -> jnp.ndarray:
    h = jnp.asarray(h, jnp.float32)
    return (1.0 / (2.0 * h * h)).reshape(1, 1)


def vmem_tile_bytes(block_m: int, block_n: int, d: int,
                    itemsize: int = 4) -> int:
    """Per-step VMEM working set (inputs + φ tile + output accumulator).

    ``itemsize`` is the GEMM-operand byte width (4 f32, 2 bf16, 4 for the
    two-plane bf16x2 split — ``precision.operand_bytes``); norms, the φ
    tile, and the accumulator are always f32.
    """
    operand_elems = (
        block_m * d            # row tile
        + d * block_n          # xt column tile
        + block_n * (d + 1)    # xaug column tile
    )
    f32_elems = (
        block_m                # row norms
        + block_n              # column norms
        + block_m * block_n    # φ tile (registers/VMEM intermediate)
        + block_m * (d + 1)    # accumulator
    )
    return operand_elems * itemsize + f32_elems * 4


def _check_vmem(block_m: int, block_n: int, d: int,
                itemsize: int = 4) -> None:
    b = vmem_tile_bytes(block_m, block_n, d, itemsize)
    if b > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"tile working set {b/2**20:.1f} MiB exceeds VMEM budget "
            f"({VMEM_BUDGET_BYTES/2**20:.0f} MiB): block_m={block_m} "
            f"block_n={block_n} d={d} itemsize={itemsize}"
        )


def _resolve(block_m, block_n, rows, cols, d, *, out_width, precision,
             interpret, row_multiple=None, col_multiple=None):
    """Shared "auto"-tile resolution + dtype-aware VMEM gate."""
    block_m, block_n = autotune.resolve_blocks(
        block_m, block_n, rows, cols, d, out_width=out_width,
        precision=precision, row_multiple=row_multiple,
        col_multiple=col_multiple,
        measure=False if interpret else None,
    )
    _check_vmem(block_m, block_n, d, prec.operand_bytes(precision))
    return block_m, block_n


# ---------------------------------------------------------------------------
# Score statistics / SD-KDE shift.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=_STATIC)
def flash_score_stats(
    x: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
):
    """(S0, S1) score statistics over the train set via the fused kernel."""
    prec.validate(precision)
    n, d = x.shape
    block_m, block_n = _resolve(
        block_m, block_n, n, n, d, out_width=d + 1, precision=precision,
        interpret=interpret,
    )
    mult = math.lcm(block_m, block_n)
    xp = _pad_to(x, mult)
    npad = xp.shape[0]
    xaug = jnp.concatenate(
        [xp, jnp.ones((npad, 1), xp.dtype)], axis=1
    )
    if precision == "f32":
        x_ops = (xp, None)
        xt_ops = (xp.astype(jnp.float32).T.astype(xp.dtype), None)
        xaug_ops = (xaug, None)
        nrm = _norms(xp)
    else:
        x_ops = prec.cast_operand(xp.astype(jnp.float32), precision)
        xt_ops = (x_ops[0].T, None if x_ops[1] is None else x_ops[1].T)
        xaug_ops = prec.cast_operand(xaug.astype(jnp.float32), precision)
        nrm = _tier_norms(*x_ops)
    s1aug = flash_score_pallas(
        x_ops[0], nrm, xt_ops[0], xaug_ops[0], _inv2h2(h),
        x_ops[1], xt_ops[1], xaug_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    s0 = s1aug[:n, d]
    s1 = s1aug[:n, :d]
    return s0, s1


@functools.partial(jax.jit, static_argnames=_STATIC)
def flash_sdkde_shift(
    x: jnp.ndarray,
    h,
    *,
    score_h=None,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Debiased samples x^SD = x + (h²/2)·ŝ(x), score via the flash kernel."""
    sh = h if score_h is None else score_h
    s0, s1 = flash_score_stats(
        x, sh, precision=precision,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    sh = jnp.asarray(sh, jnp.float32)
    h = jnp.asarray(h, jnp.float32)
    x32 = x.astype(jnp.float32)
    score = (s1 - x32 * s0[:, None]) / (sh * sh * s0[:, None])
    return x32 + 0.5 * h * h * score


# ---------------------------------------------------------------------------
# KDE / Laplace-KDE evaluation.
# ---------------------------------------------------------------------------


def _prep_eval(x, y, block_m, block_n, precision):
    """Pad, transpose, norm and tier-cast one (train, queries) pair."""
    yp = _pad_to(y, block_m)
    xp = _pad_to(x, block_n)
    if precision == "f32":
        y_ops = (yp, None)
        xt_ops = (xp.astype(jnp.float32).T.astype(xp.dtype), None)
        nrm_y, nrm_x = _norms(yp), _norms(xp).reshape(1, -1)
    else:
        y_ops = prec.cast_operand(yp.astype(jnp.float32), precision)
        x_ops = prec.cast_operand(xp.astype(jnp.float32), precision)
        # cast commutes with transpose: the lane-major column planes are
        # the row-layout planes transposed, and the column norms come from
        # the same cast values the kernel will stream.
        xt_ops = (x_ops[0].T, None if x_ops[1] is None else x_ops[1].T)
        nrm_y = _tier_norms(*y_ops)
        nrm_x = _tier_norms(*x_ops).reshape(1, -1)
    return y_ops, xt_ops, nrm_y, nrm_x


@functools.partial(jax.jit, static_argnames=_STATIC)
def flash_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Normalized Gaussian KDE densities at ``y`` (train set ``x``)."""
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret,
    )
    y_ops, xt_ops, nrm_y, nrm_x = _prep_eval(x, y, block_m, block_n,
                                             precision)
    sums = flash_kde_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


@functools.partial(jax.jit, static_argnames=_STATIC)
def flash_laplace_kde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused Flash-Laplace-KDE densities at ``y`` — single quadratic pass."""
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret,
    )
    y_ops, xt_ops, nrm_y, nrm_x = _prep_eval(x, y, block_m, block_n,
                                             precision)
    sums = flash_laplace_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    return sums[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


@functools.partial(jax.jit, static_argnames=_STATIC)
def laplace_kde_nonfused(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Non-fused Laplace baseline: two quadratic kernel launches (Fig. 4)."""
    prec.validate(precision)
    n, d = x.shape
    m = y.shape[0]
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret,
    )
    y_ops, xt_ops, nrm_y, nrm_x = _prep_eval(x, y, block_m, block_n,
                                             precision)
    kde_sums = flash_kde_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    sq_mom = sq_moment_pallas(
        y_ops[0], nrm_y, xt_ops[0], nrm_x, _inv2h2(h), y_ops[1], xt_ops[1],
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    h = jnp.asarray(h, jnp.float32)
    combined = (1.0 + d / 2.0) * kde_sums - sq_mom / (2.0 * h * h)
    return combined[:m, 0] / (n * gaussian_norm_const(d, 1.0) * h**d)


# ---------------------------------------------------------------------------
# Prepared fast path (serving).
# ---------------------------------------------------------------------------


class TrainColumns(NamedTuple):
    """Fit-time prepared train tensors for one precision tier."""

    xt: jnp.ndarray                 # (d, n_padded) tier-cast hi plane
    xt_lo: Optional[jnp.ndarray]    # (d, n_padded) bf16 lo plane (bf16x2)
    nrm_x: jnp.ndarray              # (1, n_padded) f32 column norms


def prepare_train_columns(x: jnp.ndarray, *, block_n: int = 512,
                          precision: str = "f32") -> TrainColumns:
    """One-time train-side prep for repeated evaluation against the same set.

    Pads the (debiased) train set to a ``block_n`` multiple with sentinel
    points, builds the transposed (d, n) layout the kernels stream as lane-
    major column tiles (cast to the requested precision tier — for bf16x2
    both hi and lo planes), and precomputes the f32 column squared norms.
    The serving registry caches the result per tier so none of this work is
    repeated per query batch.
    """
    prec.validate(precision)
    if block_n == "auto":
        _, block_n = autotune.resolve_blocks(
            128, "auto", rows=4096, cols=x.shape[0], d=x.shape[-1],
            precision=precision, measure=False,
        )
    xp = _pad_to(x, block_n)
    if precision == "f32":
        xt, xt_lo = xp.astype(jnp.float32).T.astype(xp.dtype), None
        nrm_x = _norms(xp).reshape(1, -1)
    else:
        x_hi, x_lo = prec.cast_operand(xp.astype(jnp.float32), precision)
        xt, xt_lo = x_hi.T, None if x_lo is None else x_lo.T
        nrm_x = _tier_norms(x_hi, x_lo).reshape(1, -1)
    return TrainColumns(xt, xt_lo, nrm_x)


@functools.partial(jax.jit, static_argnames=_STATIC + ("laplace",))
def flash_kde_prepared(
    yp: jnp.ndarray,       # (m, d) queries, ALREADY padded to block_m multiple
    xt: jnp.ndarray,       # (d, n) from prepare_train_columns (tier-cast)
    nrm_x: jnp.ndarray,    # (1, n) from prepare_train_columns
    h,
    xt_lo: jnp.ndarray | None = None,  # (d, n) lo plane (bf16x2 tier)
    *,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
    laplace: bool = False,
) -> jnp.ndarray:
    """No-reassert fast path: unnormalized kernel sums for pre-padded queries.

    Skips the per-call padding, transposition and norm precomputation that
    ``flash_kde`` does — the serving layer pads queries to shape-bucket
    multiples of ``block_m`` up front and reuses the prepared train tensors
    (cached per precision tier) across every batch.  Returns raw sums (m,);
    the caller divides by ``n_true · (2π)^{d/2} h^d`` (padding rows give ~0
    and are sliced off by the caller).
    """
    prec.validate(precision)
    if (precision == "bf16x2") != (xt_lo is not None):
        raise ValueError(
            "bf16x2 needs prepared lo planes (and other tiers must not "
            f"pass them): precision={precision} xt_lo={xt_lo is not None}"
        )
    m, d = yp.shape
    n = xt.shape[1]
    block_m, block_n = _resolve(
        block_m, block_n, m, n, d, out_width=1, precision=precision,
        interpret=interpret, row_multiple=m, col_multiple=n,
    )
    if precision == "f32":
        y_hi, y_lo = yp, None
        nrm_y = _norms(yp)
    else:
        y_hi, y_lo = prec.cast_operand(yp.astype(jnp.float32), precision)
        nrm_y = _tier_norms(y_hi, y_lo)
    kernel = flash_laplace_pallas if laplace else flash_kde_pallas
    sums = kernel(
        y_hi, nrm_y, xt, nrm_x, _inv2h2(h), y_lo, xt_lo,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return sums[:, 0]


# ---------------------------------------------------------------------------
# Full pipeline.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=_STATIC)
def flash_sdkde(
    x: jnp.ndarray,
    y: jnp.ndarray,
    h,
    *,
    score_h=None,
    precision: str = "f32",
    block_m="auto",
    block_n="auto",
    interpret: bool = False,
) -> jnp.ndarray:
    """Full Flash-SD-KDE: score pass → shift → KDE at queries (normalized)."""
    x_sd = flash_sdkde_shift(
        x, h, score_h=score_h, precision=precision,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return flash_kde(
        x_sd, y, h, precision=precision,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )
