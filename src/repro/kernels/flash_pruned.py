"""Pruned flash kernels: scalar-prefetched visit lists over column tiles.

The dense kernels run a rectangular ``(m/block_m, n/block_n)`` grid; these
variants run ``(m/block_m, max_visits)`` and fetch, per grid step, the
column tile named by a prefetched per-row-tile visit list
(``kernels/spatial.py``).  BlockSpec index maps read the prefetched scalars
— the canonical TPU block-sparse pattern — so the skipped tiles are never
DMA'd at all: the win is HBM traffic *and* MXU/VPU work, proportional to
(1 − occupancy).

Layout per grid step (i = row tile, k = visit slot):

    counts   (mt,)            int32   visits of row tile i  (scalar prefetch)
    tile_map (mt, max_visits) int32   k-th column tile to stream  (prefetch)
    row/col tensors                   exactly the dense kernels' tiles, but
                                      the column index is tile_map[i, k]

Visit slots past ``counts[i]`` replay the row's first kept tile; the kernel
body masks their accumulation with ``pl.when(k < counts[i])``, so bucketed
(power-of-two) visit extents stay exact.  Accumulators initialize at
``k == 0`` — the visit axis is the innermost sequential grid dimension,
same revisiting-output-block scheme as the dense kernels.

Precision tiers compose unchanged: the ``*_lo`` planes ride along and the
bodies reuse the dense kernels' compensated-Gram helpers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_laplace import _sq_tile
from repro.kernels.precision import weighted_accum


def _make_eval_kernel(compensated: bool, laplace: bool):
    """KDE / fused-Laplace body with visit-count masking."""

    def kernel(cnt_ref, tmap_ref, *refs):
        del tmap_ref  # consumed by the BlockSpec index maps
        if compensated:
            (y_ref, y_lo_ref, nrm_m_ref, xt_ref, xt_lo_ref, nrm_n_ref,
             inv2h2_ref, out_ref) = refs
        else:
            y_ref, nrm_m_ref, xt_ref, nrm_n_ref, inv2h2_ref, out_ref = refs
            y_lo_ref = xt_lo_ref = None
        i, k = pl.program_id(0), pl.program_id(1)

        @pl.when(k == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(k < cnt_ref[i])
        def _accumulate():
            sq = _sq_tile(y_ref, nrm_m_ref, xt_ref, nrm_n_ref, y_lo_ref,
                          xt_lo_ref)
            scaled = sq * inv2h2_ref[0, 0]
            phi = jnp.exp(-scaled)
            if laplace:
                d = xt_ref.shape[0]
                phi = phi * (1.0 + d / 2.0 - scaled)
            out_ref[...] += jnp.sum(phi, axis=1, keepdims=True)

    return kernel


_EVAL = {(c, l): _make_eval_kernel(c, l)
         for c in (False, True) for l in (False, True)}


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "max_visits", "interpret",
                     "laplace"),
)
def flash_kde_pallas_pruned(
    counts: jnp.ndarray,     # (mt,) int32 visits per row tile
    tile_map: jnp.ndarray,   # (mt, max_visits) int32 column-tile indices
    y: jnp.ndarray,          # (m, d) queries, padded to block_m multiple
    nrm_y: jnp.ndarray,      # (m, 1) f32
    xt: jnp.ndarray,         # (d, n) train columns, padded to block_n
    nrm_x: jnp.ndarray,      # (1, n) f32
    inv2h2: jnp.ndarray,     # (1, 1) f32
    y_lo: jnp.ndarray | None = None,
    xt_lo: jnp.ndarray | None = None,
    *,
    block_m: int = 128,
    block_n: int = 512,
    max_visits: int = 1,
    interpret: bool = False,
    laplace: bool = False,
) -> jnp.ndarray:
    """Pruned KDE / fused-Laplace sums (m, 1) f32 (unnormalized)."""
    m, d = y.shape
    n = xt.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    assert (y_lo is None) == (xt_lo is None), "bf16x2 needs both lo planes"
    mt = m // block_m
    assert counts.shape == (mt,) and tile_map.shape == (mt, max_visits), (
        counts.shape, tile_map.shape, mt, max_visits)

    row = pl.BlockSpec((block_m, d), lambda i, k, cnt, tm: (i, 0))
    nrm_row = pl.BlockSpec((block_m, 1), lambda i, k, cnt, tm: (i, 0))
    col = pl.BlockSpec((d, block_n), lambda i, k, cnt, tm: (0, tm[i, k]))
    nrm_col = pl.BlockSpec((1, block_n), lambda i, k, cnt, tm: (0, tm[i, k]))
    scalar = pl.BlockSpec((1, 1), lambda i, k, cnt, tm: (0, 0))

    if y_lo is None:
        kernel = _EVAL[(False, laplace)]
        in_specs = [row, nrm_row, col, nrm_col, scalar]
        args = (y, nrm_y, xt, nrm_x, inv2h2)
    else:
        kernel = _EVAL[(True, laplace)]
        in_specs = [row, row, nrm_row, col, col, nrm_col, scalar]
        args = (y, y_lo, nrm_y, xt, xt_lo, nrm_x, inv2h2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mt, max_visits),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, 1), lambda i, k, cnt, tm: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(counts, tile_map, *args)


def _make_score_kernel(compensated: bool):
    def kernel(cnt_ref, tmap_ref, *refs):
        del tmap_ref
        if compensated:
            (x_hi_ref, x_lo_ref, nrm_m_ref, xt_hi_ref, xt_lo_ref,
             xaug_hi_ref, xaug_lo_ref, nrm_n_ref, inv2h2_ref,
             out_ref) = refs
        else:
            (x_hi_ref, nrm_m_ref, xt_hi_ref, xaug_hi_ref, nrm_n_ref,
             inv2h2_ref, out_ref) = refs
            x_lo_ref = xt_lo_ref = xaug_lo_ref = None
        i, k = pl.program_id(0), pl.program_id(1)

        @pl.when(k == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(k < cnt_ref[i])
        def _accumulate():
            sq = _sq_tile(x_hi_ref, nrm_m_ref, xt_hi_ref, nrm_n_ref,
                          x_lo_ref, xt_lo_ref)
            phi = jnp.exp(-sq * inv2h2_ref[0, 0])
            if compensated:
                out_ref[...] += weighted_accum(phi, xaug_hi_ref[...],
                                               xaug_lo_ref[...])
            else:
                out_ref[...] += weighted_accum(phi, xaug_hi_ref[...])

    return kernel


_SCORE = {c: _make_score_kernel(c) for c in (False, True)}


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "max_visits", "interpret"),
)
def flash_score_pallas_pruned(
    counts: jnp.ndarray,     # (nt_rows,) int32
    tile_map: jnp.ndarray,   # (nt_rows, max_visits) int32
    x: jnp.ndarray,          # (n, d) padded to block_m/block_n multiples
    nrm: jnp.ndarray,        # (n, 1) f32
    xt: jnp.ndarray,         # (d, n)
    xaug: jnp.ndarray,       # (n, d+1) [X | 1]
    inv2h2: jnp.ndarray,     # (1, 1) f32
    x_lo: jnp.ndarray | None = None,
    xt_lo: jnp.ndarray | None = None,
    xaug_lo: jnp.ndarray | None = None,
    *,
    block_m: int = 128,
    block_n: int = 512,
    max_visits: int = 1,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pruned score statistics S1aug (n, d+1) f32."""
    n, d = x.shape
    assert n % block_m == 0 and n % block_n == 0, (n, block_m, block_n)
    los = (x_lo, xt_lo, xaug_lo)
    assert all(v is None for v in los) or all(v is not None for v in los), \
        "bf16x2 needs all three lo planes"
    mt = n // block_m
    assert counts.shape == (mt,) and tile_map.shape == (mt, max_visits), (
        counts.shape, tile_map.shape, mt, max_visits)

    row = pl.BlockSpec((block_m, d), lambda i, k, cnt, tm: (i, 0))
    nrm_row = pl.BlockSpec((block_m, 1), lambda i, k, cnt, tm: (i, 0))
    col = pl.BlockSpec((d, block_n), lambda i, k, cnt, tm: (0, tm[i, k]))
    aug = pl.BlockSpec((block_n, d + 1), lambda i, k, cnt, tm: (tm[i, k], 0))
    nrm_col = pl.BlockSpec((1, block_n), lambda i, k, cnt, tm: (0, tm[i, k]))
    scalar = pl.BlockSpec((1, 1), lambda i, k, cnt, tm: (0, 0))

    nrm_bcast = jnp.broadcast_to(nrm.reshape(1, -1), (1, n))
    if x_lo is None:
        kernel = _SCORE[False]
        in_specs = [row, nrm_row, col, aug, nrm_col, scalar]
        args = (x, nrm, xt, xaug, nrm_bcast, inv2h2)
    else:
        kernel = _SCORE[True]
        in_specs = [row, row, nrm_row, col, col, aug, aug, nrm_col, scalar]
        args = (x, x_lo, nrm, xt, xt_lo, xaug, xaug_lo, nrm_bcast, inv2h2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mt, max_visits),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, d + 1),
                               lambda i, k, cnt, tm: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d + 1), jnp.float32),
        interpret=interpret,
    )(counts, tile_map, *args)


__all__ = ["flash_kde_pallas_pruned", "flash_score_pallas_pruned"]
