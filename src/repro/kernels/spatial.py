"""Spatial tile reordering + certified tile skipping for the flash kernels.

Every dense flash kernel streams all ``m/block_m × n/block_n`` tile pairs
even though ``exp(-‖y−x‖²/2h²)`` underflows to exactly 0.0 for the vast
majority of tiles at paper-scale problems.  This module supplies the three
pieces a *pruned* pass needs (DEANN-style distance-aware pruning, with the
error budgets certified per tile):

  1. **Clustered layout** — k-means (default) or Morton grouping of the
     (debiased) train set, laid out so every streamed ``block_n`` column
     tile holds points of ONE cluster: each cluster's points are
     contiguous and sentinel-padded up to a tile multiple.  Without the
     per-cluster padding, the tiles at cluster boundaries straddle two
     far-apart clusters, inherit a covering radius the size of their
     separation, and can never be skipped — with tile size comparable to
     cluster size that is *every* tile.  Queries go through the same
     layout per batch (assigned to the train centroids), which keeps row
     tiles spatially coherent so their visit lists stay short.
  2. **Tile metadata** — per column tile: centroid, covering radius, real
     (non-sentinel) point count, and max |coordinate| (the score kernel's
     accumulator weight bound).  Sentinel rows are masked out, so
     all-padding tiles carry ``count == 0`` and are skipped for free.
  3. **Tile maps** — the bounds prepass.  For every *query row* the
     distance to every column-tile centroid is one cheap
     ``(m × n/block_n)`` GEMM; min-reducing it over each ``block_m`` row
     tile gives

         dmin_ij = max(0, min_{r ∈ tile i} ‖y_r − c_j‖ − radius_j)
         arg_ij  = margin · dmin_ij² / (2h²)

     a certified lower bound on every pairwise exponent of the (i, j)
     tile (``margin < 1`` absorbs f32 round-off here and in the kernels'
     norm-trick ``sq``).  Using the per-row min — rather than a row-tile
     centroid+radius — keeps the bound tight even when a row tile spans
     several clusters.  The per-point contribution of tile ``j`` to any
     row of tile ``i`` is then at most

         kde:      exp(-arg)
         laplace:  exp(-arg) · (1 + d/2 + arg)      (decreasing in arg)
         score:    exp(-arg) · max(1, max|x| in j)  (the φ@[X|1] weights)

     A tile is skipped iff that bound is ≤ the caller's per-point
     ``epsilon``, or iff ``arg`` clears the f32 exp-underflow threshold —
     in which case the dense kernel would have accumulated *exactly 0.0*
     for every pair, so ``epsilon=0`` pruning reproduces the dense result
     bit-for-bit up to summation order.  The summed bound over skipped
     tiles is returned as a per-row-tile error certificate (tests assert
     the float64 dropped mass never exceeds it).

The kept tiles are compacted into per-row-tile visit lists
(``tile_map[i, k]`` = k-th column tile row block ``i`` must stream), which
the pruned kernels consume via scalar prefetch — the grid shrinks from
``m_tiles × n_tiles`` to ``m_tiles × max_visits``.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

PAD_VALUE = 1.0e6   # matches ops.PAD_VALUE — kernel weight underflows to 0

# f32 exp(-x) is exactly 0.0 for x > 150·ln2 ≈ 103.97 (subnormal rounding).
# 105 adds a hair of slack; MARGIN then demands ~11% more headroom before a
# tile may be skipped under the exact (epsilon=0) rule.
UNDERFLOW_ARG = 105.0
#: Conservative shrink on the certified exponent lower bound: covers f32
#: rounding in the bounds prepass and the kernels' norms-minus-Gram ``sq``.
MARGIN = 0.9

KINDS = ("kde", "laplace", "score")


class SpatialIndex(NamedTuple):
    """A clustering of one point set: assignment state for layouts."""

    labels: Optional[jnp.ndarray]      # (n,) int32 cluster of each point
    centroids: Optional[jnp.ndarray]   # (k, d) f32 k-means centroids
    method: str = "kmeans"


class ClusterLayout(NamedTuple):
    """A cluster-aligned padded layout of one point set.

    ``points[slots[i]] == x[i]``; every other row is a sentinel.  Cluster
    c occupies a contiguous, ``block``-aligned slab, so no ``block`` tile
    ever holds two clusters.  ``real`` marks non-sentinel rows.
    """

    points: jnp.ndarray   # (total, d) padded layout
    real: jnp.ndarray     # (total,) bool
    slots: jnp.ndarray    # (n,) int32 — row of original point i
    block: int


class TileMeta(NamedTuple):
    """Per-column-tile geometry of a cluster-aligned layout."""

    centroids: jnp.ndarray   # (t, d) f32 centroid of the tile's real points
    radii: jnp.ndarray       # (t,)   f32 max ‖x − centroid‖ over real points
    counts: jnp.ndarray      # (t,)   int32 real (non-sentinel) points
    max_abs: jnp.ndarray     # (t,)   f32 max |coordinate| over real points


class TileMap(NamedTuple):
    """Bounds-prepass output: which tiles each row block must visit."""

    keep: jnp.ndarray        # (mt, t) bool
    err_bound: jnp.ndarray   # (mt,)  f32 certified max abs error per row of
    #                        # the unnormalized accumulator (worst component)


class VisitLists(NamedTuple):
    """Host-compacted tile map in the layout the pruned kernels prefetch."""

    counts: jnp.ndarray      # (mt,) int32 visits per row tile
    tile_map: jnp.ndarray    # (mt, max_visits) int32 column-tile indices
    max_visits: int          # static grid extent (pow2-bucketed)
    occupancy: float         # mean(counts) / n_tiles — the skip-rate stat


# ---------------------------------------------------------------------------
# Clustering.
# ---------------------------------------------------------------------------


def _sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    an = jnp.sum(a * a, axis=-1)[:, None]
    bn = jnp.sum(b * b, axis=-1)[None, :]
    return jnp.maximum(an + bn - 2.0 * (a @ b.T), 0.0)


def default_n_clusters(n: int) -> int:
    """sqrt-law cluster count: ~128 at 256k points, floor 2, cap 1024.

    Erring toward MORE clusters than the data has is safe: pruning bounds
    only tighten as clusters shrink, while the assignment/bounds GEMMs
    stay O(n·k·d) — negligible next to the O(n·m·d) quadratic pass.
    """
    return max(2, min(1024, int(math.sqrt(max(n, 1) / 16.0))))


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def _kmeans_fit(x: jnp.ndarray, key: jnp.ndarray, *, k: int,
                iters: int) -> jnp.ndarray:
    """Lloyd iterations on (a subsample of) x; returns (k, d) centroids."""
    n = x.shape[0]
    c = x[jax.random.choice(key, n, (k,), replace=n < k)]
    for _ in range(iters):
        lab = jnp.argmin(_sqdist(x, c), axis=1)
        one = jax.nn.one_hot(lab, k, dtype=jnp.float32)     # (n, k)
        cnt = jnp.sum(one, axis=0)[:, None]                 # (k, 1)
        sums = one.T @ x                                    # (k, d)
        c = jnp.where(cnt > 0, sums / jnp.maximum(cnt, 1.0), c)
    return c


def _morton_codes(x: jnp.ndarray) -> jnp.ndarray:
    """Interleaved-bit codes; coords quantized to the data range."""
    n, d = x.shape
    bits = max(1, 31 // d)
    lo = jnp.min(x, axis=0, keepdims=True)
    hi = jnp.max(x, axis=0, keepdims=True)
    q = ((x - lo) / jnp.maximum(hi - lo, 1e-30) * (2**bits - 1)).astype(
        jnp.int32
    )
    code = jnp.zeros((n,), jnp.int32)
    for b in range(bits - 1, -1, -1):
        for j in range(d):
            code = (code << 1) | ((q[:, j] >> b) & 1)
    return code


def _morton_labels(x32: jnp.ndarray, group: int = 64) -> jnp.ndarray:
    """Bucketed morton-rank labels: ~``group`` spatial neighbors per label."""
    n = x32.shape[0]
    order = jnp.argsort(_morton_codes(x32))
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    return rank // group


def build_index(
    x: jnp.ndarray,
    *,
    method: str = "kmeans",
    n_clusters: Optional[int] = None,
    iters: int = 8,
    fit_sample: int = 16384,
    seed: int = 0,
) -> SpatialIndex:
    """Cluster a point set; O(n·k·d) — amortized at prep/fit time.

    k-means fits Lloyd on a ≤``fit_sample`` subsample then assigns every
    point in one pass.  Morton labels points by their interleaved-bit
    code bucketed into ~64-point groups (grouping, not exact clustering —
    a fallback for data k-means fits poorly).
    """
    x32 = jnp.asarray(x, jnp.float32)
    n = x32.shape[0]
    if method == "morton":
        return SpatialIndex(_morton_labels(x32), None, "morton")
    if method != "kmeans":
        raise ValueError(f"unknown spatial ordering {method!r}")
    k = n_clusters or default_n_clusters(n)
    key = jax.random.PRNGKey(seed)
    fit = x32 if n <= fit_sample else x32[
        jax.random.choice(key, n, (fit_sample,), replace=False)
    ]
    c = _kmeans_fit(fit, jax.random.fold_in(key, 1), k=k, iters=iters)
    labels = jnp.argmin(_sqdist(x32, c), axis=1).astype(jnp.int32)
    return SpatialIndex(labels, c, "kmeans")


def assign(y: jnp.ndarray, index: SpatialIndex) -> jnp.ndarray:
    """Cluster labels for a NEW point set (queries) under a train index."""
    y32 = jnp.asarray(y, jnp.float32)
    if index.centroids is not None:
        return jnp.argmin(_sqdist(y32, index.centroids), axis=1).astype(
            jnp.int32
        )
    # morton / centroid-free indexes: group by the queries' own codes
    return _morton_labels(y32)


# ---------------------------------------------------------------------------
# Cluster-aligned layouts.
# ---------------------------------------------------------------------------


def cluster_capacities(labels, block: int, *, slack: float = 0.0,
                       n_clusters: Optional[int] = None):
    """Per-cluster slab geometry ``(starts, caps)`` in padded-row units.

    ``slack > 0`` reserves headroom beyond what the points need —
    ``ceil(size · slack)`` extra rows per cluster, and at least one full
    block even for an empty cluster — before rounding each slab up to a
    ``block`` multiple.  The headroom rows are ordinary sentinel rows
    until a streaming append claims them, so the padded layout's *shape*
    survives appends: new points land in free slots instead of forcing a
    re-scatter.  ``slack == 0`` reproduces the static layout exactly
    (empty clusters get zero rows).
    """
    lab = np.asarray(labels)
    k = n_clusters if n_clusters is not None else (
        int(lab.max()) + 1 if lab.size else 1
    )
    sizes = np.bincount(lab, minlength=k)
    if slack > 0.0:
        want = sizes + np.ceil(sizes * slack).astype(np.int64)
        want = np.maximum(want, 1)                        # empty → 1 block
    else:
        want = sizes
    caps = ((want + block - 1) // block) * block
    starts = np.concatenate([[0], np.cumsum(caps)[:-1]])
    return starts.astype(np.int64), caps.astype(np.int64)


def cluster_slots(labels, block: int, *, slack: float = 0.0) -> np.ndarray:
    """Padded slot of each point: clusters contiguous, ``block``-multiples.

    Host-side (the layout shape must be static for the launch anyway).
    """
    lab = np.asarray(labels)
    n = lab.shape[0]
    k = int(lab.max()) + 1 if n else 1
    starts, _ = cluster_capacities(lab, block, slack=slack, n_clusters=k)
    sizes = np.bincount(lab, minlength=k)
    order = np.argsort(lab, kind="stable")
    within = np.empty(n, np.int64)
    within[order] = np.arange(n) - np.repeat(
        np.concatenate([[0], np.cumsum(sizes)[:-1]]), sizes
    )
    return (starts[lab] + within).astype(np.int32)


def place_points(real, labels_new, starts, caps) -> Optional[np.ndarray]:
    """Free slots for appended points, respecting the cluster slabs.

    ``real`` marks occupied rows of the existing layout; each new point
    (cluster ``labels_new[i]``) takes the first free sentinel slot inside
    its cluster's ``[starts[c], starts[c] + caps[c])`` slab, so the
    cluster-alignment invariant (no tile straddles clusters) is preserved
    without touching any existing row.  Returns the claimed slots, or
    ``None`` when some cluster's slab is full — slack overflow, the
    caller's signal to rebuild the layout.
    """
    occ = np.asarray(real).copy()
    lab = np.asarray(labels_new)
    slots = np.empty(lab.shape[0], np.int32)
    for i, c in enumerate(lab):
        s, e = int(starts[c]), int(starts[c] + caps[c])
        free = np.flatnonzero(~occ[s:e])
        if free.size == 0:
            return None
        slots[i] = s + free[0]
        occ[slots[i]] = True
    return slots


def cluster_layout(x: jnp.ndarray, labels, block: int, *,
                   total_multiple: Optional[int] = None,
                   bucket_rows: bool = False,
                   slack: float = 0.0) -> ClusterLayout:
    """Scatter a point set into its cluster-aligned sentinel-padded layout.

    ``total_multiple`` additionally pads the layout's total length up to a
    multiple (the score pass needs lcm(block_m, block_n); single-sided
    passes just need ``block``, which holds by construction).
    ``bucket_rows`` rounds the tile count up to a power of two — per-batch
    query layouts vary with the label mix, and bucketing keeps ragged
    traffic on a bounded set of compiled shapes (extra tiles are all
    sentinel: zero count, never visited).  ``slack`` reserves per-cluster
    append headroom (see ``cluster_capacities``).
    """
    x = jnp.asarray(x)
    n, d = x.shape
    lab = np.asarray(labels)
    slots = cluster_slots(lab, block, slack=slack)
    _, caps = cluster_capacities(lab, block, slack=slack)
    total = int(caps.sum())
    total = max(total, block)
    if bucket_rows:
        tiles = -(-total // block)
        total = block * (1 << max(0, math.ceil(math.log2(tiles))))
    if total_multiple is not None:
        total = -(-total // total_multiple) * total_multiple
    slots_j = jnp.asarray(slots)
    points = jnp.full((total, d), PAD_VALUE, x.dtype).at[slots_j].set(x)
    real = jnp.zeros((total,), bool).at[slots_j].set(True)
    return ClusterLayout(points, real, slots_j, block)


# ---------------------------------------------------------------------------
# Tile metadata.
# ---------------------------------------------------------------------------


@jax.jit
def tile_meta_from_rows(x3: jnp.ndarray, mask: jnp.ndarray) -> TileMeta:
    """TileMeta of pre-gathered tile rows: (t, block, d) points, (t, block)
    real-mask.  The shared reduction behind full and partial builds."""
    x3 = jnp.asarray(x3, jnp.float32)
    cnt = jnp.sum(mask, axis=1).astype(jnp.int32)
    denom = jnp.maximum(cnt, 1).astype(jnp.float32)[:, None]
    cen = jnp.sum(jnp.where(mask[..., None], x3, 0.0), axis=1) / denom
    sq = jnp.sum((x3 - cen[:, None, :]) ** 2, axis=-1)       # (t, block)
    radii = jnp.sqrt(jnp.max(jnp.where(mask, sq, 0.0), axis=1))
    max_abs = jnp.max(
        jnp.where(mask[..., None], jnp.abs(x3), 0.0), axis=(1, 2)
    )
    return TileMeta(cen, radii, cnt, max_abs)


@functools.partial(jax.jit, static_argnames=("block",))
def tile_metadata(xp: jnp.ndarray, real: jnp.ndarray, *,
                  block: int) -> TileMeta:
    """Geometry of each ``block``-row tile of a cluster-aligned layout.

    ``real`` masks sentinel rows out of every statistic.  ``xp`` must be
    the f32 points the kernel *actually* computes distances between — at
    reduced precision tiers, the tier-cast reconstruction — so the bounds
    certify the perturbed-operand distances, not the originals.
    """
    npad, d = xp.shape
    t = npad // block
    x3 = jnp.asarray(xp, jnp.float32).reshape(t, block, d)
    mask = jnp.asarray(real).reshape(t, block)
    return tile_meta_from_rows(x3, mask)


def merge_tile_meta(meta: TileMeta, tiles, sub: TileMeta) -> TileMeta:
    """Write ``sub``'s rows over ``meta`` at the listed tile indices.

    ``tiles`` may contain repeats (pow2-padded index buffers): each row of
    ``sub`` is the freshly recomputed geometry of its tile, so repeated
    writes are idempotent.
    """
    tiles = jnp.asarray(np.asarray(tiles, np.int32))
    if tiles.size == 0:
        return meta
    return TileMeta(
        meta.centroids.at[tiles].set(sub.centroids),
        meta.radii.at[tiles].set(sub.radii),
        meta.counts.at[tiles].set(sub.counts),
        meta.max_abs.at[tiles].set(sub.max_abs),
    )


def tile_metadata_update(meta: TileMeta, xp: jnp.ndarray, real: jnp.ndarray,
                         tiles, *, block: int) -> TileMeta:
    """Refresh the metadata of only the listed tiles, in place.

    The streaming layer calls this after an append/evict/delta-shift pass
    with the set of tiles whose points actually changed — every other
    tile's geometry is carried over bit-for-bit, so certificates derived
    from it stay exactly as valid as at the last full build.
    """
    tiles_np = np.asarray(tiles, np.int64)
    if tiles_np.size == 0:
        return meta
    rows = jnp.asarray(
        (tiles_np[:, None] * block + np.arange(block)[None, :]), jnp.int32
    )
    sub = tile_meta_from_rows(jnp.asarray(xp, jnp.float32)[rows],
                              jnp.asarray(real)[rows])
    return merge_tile_meta(meta, tiles_np, sub)


# ---------------------------------------------------------------------------
# The bounds prepass.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_m", "kind"))
def tile_map(
    yp: jnp.ndarray,          # (m_pad, d) f32 padded query rows
    col_meta: TileMeta,
    inv2h2: jnp.ndarray,
    epsilon,
    *,
    block_m: int,
    kind: str = "kde",
) -> TileMap:
    """Certified keep/skip decision for every (row tile, column tile) pair.

    The bound starts from each *query row's* exact distance to each column
    tile centroid (one (m × t) GEMM), min-reduced over the row tile —
    sentinel query rows sit at distance ~PAD_VALUE·√d and never win the
    min, so row tiles need no metadata of their own and stay tight even
    when they span clusters.

    ``epsilon`` is the per-train-point contribution threshold: a skipped
    tile's certified per-point bound (see module docstring) is ≤ epsilon,
    so the absolute error on any row of the unnormalized accumulator is at
    most ``Σ_skipped count_j · bound_ij`` — returned as ``err_bound`` (and,
    loosely, ≤ n·epsilon).  ``epsilon=0`` only skips tiles whose every
    pairwise term underflows to exactly 0.0 in f32.
    """
    assert kind in KINDS, kind
    eps = jnp.asarray(epsilon, jnp.float32)
    m_pad, d = yp.shape
    mt = m_pad // block_m

    def row_tile_min(y_tile):                    # (block_m, d) -> (t,)
        dist = jnp.sqrt(_sqdist(y_tile, col_meta.centroids))
        return jnp.min(dist, axis=0)

    dmin_c = jax.lax.map(
        row_tile_min, jnp.asarray(yp, jnp.float32).reshape(mt, block_m, d)
    )                                            # (mt, t) min row→centroid
    dmin = jnp.maximum(dmin_c - col_meta.radii[None, :], 0.0)
    arg = MARGIN * dmin * dmin * inv2h2.reshape(())
    if kind == "laplace":
        w = 1.0 + d / 2.0 + arg
    elif kind == "score":
        w = jnp.maximum(1.0, col_meta.max_abs)[None, :]
    else:
        w = 1.0
    bound = w * jnp.exp(-arg)                    # per-point, per (i, j)
    skip = (arg >= UNDERFLOW_ARG) | (col_meta.counts == 0)[None, :]
    skip = skip | ((eps > 0.0) & (bound <= eps))
    keep = ~skip
    err = jnp.sum(
        jnp.where(skip, col_meta.counts[None, :].astype(jnp.float32) * bound,
                  0.0),
        axis=1,
    )
    return TileMap(keep, err)


def visit_lists(keep, *, bucket_visits: bool = True) -> VisitLists:
    """Compact a keep matrix into the prefetched visit-list layout.

    This is the one host-sync point of the pruned path: the grid's static
    ``max_visits`` extent must be a Python int.  ``bucket_visits`` rounds it
    up to a power of two (capped at n_tiles) so ragged traffic reuses at
    most log2(n_tiles) compiled grid shapes per launch config; slots past a
    row's count are masked out in-kernel (they replay the row's first kept
    tile, keeping the DMA stream warm and valid).
    """
    k = np.asarray(keep)
    mt, t = k.shape
    counts = k.sum(axis=1).astype(np.int32)
    kmax = max(int(counts.max(initial=0)), 1)
    if bucket_visits and kmax < t:
        kmax = min(t, 1 << max(0, math.ceil(math.log2(kmax))))
    order = np.argsort(~k, axis=1, kind="stable")[:, :kmax].astype(np.int32)
    fill = np.where(counts > 0, order[:, 0], 0).astype(np.int32)
    pad = np.arange(kmax)[None, :] >= counts[:, None]
    tmap = np.where(pad, fill[:, None], order)
    occ = float(counts.mean() / t) if t else 1.0
    return VisitLists(jnp.asarray(counts), jnp.asarray(tmap), int(kmax), occ)


def partition_clusters(labels, n_shards: int) -> np.ndarray:
    """Balanced assignment of whole clusters to shards.

    Greedy longest-processing-time: clusters (by point count, descending)
    go to the currently-lightest shard, ties broken by lowest shard id so
    the partition is deterministic.  Keeping clusters whole means every
    shard is a self-contained cluster-aligned tile set — its own layout,
    its own ``TileMeta``, its own certified bounds — which is exactly what
    the resilience layer's per-shard error certificates need.

    Returns ``(k,)`` int32: the shard of each cluster.  Requires
    ``n_shards <= k`` so no shard ends up empty.
    """
    lab = np.asarray(labels)
    k = int(lab.max()) + 1 if lab.size else 1
    if not (1 <= n_shards <= k):
        raise ValueError(
            f"n_shards={n_shards} must be in [1, n_clusters={k}]"
        )
    sizes = np.bincount(lab, minlength=k)
    shard_of = np.zeros(k, np.int32)
    load = np.zeros(n_shards, np.int64)
    filled = 0
    for c in np.argsort(-sizes, kind="stable"):
        # until every shard holds a cluster, seed the empty ones in order
        s = filled if filled < n_shards else int(np.argmin(load))
        shard_of[c] = s
        load[s] += sizes[c]
        filled += 1
    return shard_of


@functools.partial(jax.jit, static_argnames=("kind",))
def point_mass_bound(y: jnp.ndarray, meta: TileMeta, inv2h2,
                     *, kind: str = "kde") -> jnp.ndarray:
    """Per-query upper bound on the unnormalized kernel mass of an
    *entire absent point set* summarized by ``meta``.

    Same certified geometry as ``tile_map``, applied per query row instead
    of per row tile: each tile of the absent set contributes at most
    ``count · w(arg) · exp(-arg)`` with ``arg = MARGIN·max(0, ‖y−c‖−r)²/
    (2h²)`` — so summing over tiles bounds what a missing shard *would
    have added* to the accumulator.  The resilience layer turns this into
    the certified relative-error bound attached to degraded (partial-
    shard) answers.  For ``laplace`` the bound also caps the magnitude of
    *negative* missing contributions (|1 + d/2 − sq/2h²| ≤ 1 + d/2 + arg
    on the tile), so it is a two-sided envelope.
    """
    assert kind in KINDS, kind
    y32 = jnp.asarray(y, jnp.float32)
    d = y32.shape[-1]
    dist = jnp.sqrt(_sqdist(y32, meta.centroids))             # (m, t)
    dmin = jnp.maximum(dist - meta.radii[None, :], 0.0)
    arg = MARGIN * dmin * dmin * jnp.asarray(inv2h2, jnp.float32).reshape(())
    if kind == "laplace":
        w = 1.0 + d / 2.0 + arg
    elif kind == "score":
        w = jnp.maximum(1.0, meta.max_abs)[None, :]
    else:
        w = 1.0
    per = meta.counts[None, :].astype(jnp.float32) * w * jnp.exp(-arg)
    return jnp.sum(per, axis=1)                               # (m,)


def epsilon_for_density_error(abs_err: float, d: int, h: float) -> float:
    """Per-point epsilon giving |Δdensity| ≤ abs_err (normalization undone).

    density = sums / (n·(2π)^{d/2}·h^d) and the dropped unnormalized mass
    is ≤ n·epsilon, so epsilon = abs_err · (2π)^{d/2} · h^d.
    """
    return float(abs_err * (2.0 * math.pi) ** (d / 2.0) * h**d)


__all__ = [
    "PAD_VALUE", "UNDERFLOW_ARG", "MARGIN", "KINDS", "SpatialIndex",
    "ClusterLayout", "TileMeta", "TileMap", "VisitLists",
    "default_n_clusters", "build_index", "assign", "cluster_capacities",
    "cluster_slots", "place_points", "cluster_layout", "tile_metadata",
    "tile_meta_from_rows", "merge_tile_meta", "tile_metadata_update",
    "tile_map", "visit_lists", "partition_clusters", "point_mass_bound",
    "epsilon_for_density_error",
]
