"""Input-precision tiers for the Flash-SD-KDE kernels.

The paper's speedup is "make the hot loop tensor-core matmuls"; on TPU the
MXU runs bf16×bf16→f32 at full rate while f32×f32 costs multiple passes
through the systolic array.  SD-KDE's statistical guarantees survive
reduced-precision *pairwise distances* as long as the sensitive scalar work
stays f32, so the kernels expose three operand tiers:

  * ``f32``    — operands as given (the seed behavior, full precision);
  * ``bf16``   — Gram / φ@[X|1] operands cast to bfloat16 (~1e-2 relative
                 on the densities, full MXU rate, half the operand HBM
                 traffic and VMEM footprint);
  * ``bf16x2`` — split-hi–lo compensated bf16: each f32 operand A becomes
                 ``A_hi = bf16(A)`` and ``A_lo = bf16(A − A_hi)``, and each
                 GEMM runs as the four-product sum
                 ``A_hi·B_hi + A_hi·B_lo + A_lo·B_hi + A_lo·B_lo``.
                 ~16 mantissa bits → within 1e-4 of the f32 reference at 4×
                 the bf16 GEMM count — the same family as XLA's own
                 f32-as-bf16 emulation (``BF16_3X``/``BF16_6X`` passes),
                 sitting between them, and still cheaper than the 6-pass
                 exact lowering a full-f32 MXU GEMM costs.

Invariant across every tier (tested in tests/test_precision_autotune.py):
squared norms, ``sq = ‖y‖² + ‖x‖² − 2g``, the exponential, the Laplace
correction, and all accumulators stay f32 — only GEMM *operands* shrink.
One subtlety makes the tiers well-behaved: at a reduced tier the f32 norms
are computed from the *tier-cast* operands (ŷ = cast(y)), so
``sq = ‖ŷ‖² + ‖x̂‖² − 2·ŷ·x̂ = ‖ŷ − x̂‖²`` is an exact nonnegative squared
distance of slightly perturbed points — precision loss acts as a data
perturbation (the regime SD-KDE's guarantees tolerate) instead of a
catastrophic-cancellation error in the exponent.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

PRECISIONS = ("f32", "bf16", "bf16x2")
Precision = str  # one of PRECISIONS; plain str keeps it jit-static-friendly


def validate(precision: Precision) -> Precision:
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision tier {precision!r} (choose from {PRECISIONS})"
        )
    return precision


def operand_bytes(precision: Precision) -> int:
    """Effective bytes/element of GEMM operand storage and HBM streaming.

    bf16x2 stores *two* bf16 planes per operand, so its footprint matches
    f32 — the win there is MXU rate, not bytes.
    """
    validate(precision)
    return {"f32": 4, "bf16": 2, "bf16x2": 4}[precision]


def gram_products(precision: Precision) -> int:
    """MXU product count per logical GEMM (bf16x2 runs the 4-product sum)."""
    validate(precision)
    return 4 if precision == "bf16x2" else 1


def split_hi_lo(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compensated split: f32 ``x`` → (bf16 hi, bf16 lo) with x ≈ hi + lo."""
    x32 = x.astype(jnp.float32)
    hi = x32.astype(jnp.bfloat16)
    lo = (x32 - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def cast_operand(
    x: jnp.ndarray, precision: Precision
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """(hi, lo) GEMM operand pair for a tier; ``lo`` is None below bf16x2.

    ``f32`` keeps the array's own dtype (bf16 *data* stays bf16, matching
    the seed kernels' behavior of computing in whatever the caller supplies).
    """
    validate(precision)
    if precision == "f32":
        return x, None
    if precision == "bf16":
        return x.astype(jnp.bfloat16), None
    return split_hi_lo(x)


def reconstruct(hi: jnp.ndarray, lo: Optional[jnp.ndarray]) -> jnp.ndarray:
    """The f32 points a (hi, lo) operand pair actually represents."""
    r = hi.astype(jnp.float32)
    if lo is not None:
        r = r + lo.astype(jnp.float32)
    return r


def dot_f32(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def gram_compensated(
    a_hi: jnp.ndarray, a_lo: jnp.ndarray,
    b_hi: jnp.ndarray, b_lo: jnp.ndarray,
) -> jnp.ndarray:
    """Four-product compensated GEMM with f32 accumulation (bf16x2 tier).

    Keeping the ``a_lo·b_lo`` term makes the result the exact (to f32
    rounding) Gram of the reconstructed operands ``(a_hi+a_lo)·(b_hi+b_lo)``
    — required for ``sq = ‖ŷ−x̂‖²`` to stay a true squared distance when
    norms are computed from the same reconstruction (see module docstring).
    """
    g = dot_f32(a_hi, b_hi)
    g = g + dot_f32(a_hi, b_lo)
    g = g + dot_f32(a_lo, b_hi)
    g = g + dot_f32(a_lo, b_lo)
    return g


def weighted_accum(phi: jnp.ndarray, w_hi: jnp.ndarray,
                   w_lo: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The φ@[X|1] accumulator GEMM at the tier implied by the operands.

    ``phi`` arrives f32 (it is exp output); the weight matrix's dtype (plus
    the presence of a lo plane) selects the tier, so kernel bodies need no
    explicit precision flag.
    """
    if w_lo is not None:                       # bf16x2: split φ too
        p_hi, p_lo = split_hi_lo(phi)
        return gram_compensated(p_hi, p_lo, w_hi, w_lo)
    if w_hi.dtype == jnp.bfloat16:             # bf16: both operands bf16
        return dot_f32(phi.astype(jnp.bfloat16), w_hi)
    return dot_f32(phi, w_hi.astype(jnp.float32))


__all__ = [
    "PRECISIONS", "Precision", "validate", "operand_bytes", "gram_products",
    "split_hi_lo", "cast_operand", "reconstruct", "dot_f32",
    "gram_compensated", "weighted_accum",
]
