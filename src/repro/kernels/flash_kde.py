"""Flash KDE evaluation kernel: Gaussian kernel sums at query points.

Computes p_j = Σ_i exp(-‖y_j - x_i‖²/(2h²)) for query rows y_j against the
(debiased) train set, streaming train column tiles through VMEM and
accumulating the (BLOCK_M, 1) partial sums in place across the innermost
grid dimension (sequential-grid accumulation — see flash_score.py).

The Gram tile (BLOCK_M×d)@(d×BLOCK_N) runs on the MXU; the exponential and
row reduction run on the VPU.  Normalization (1/(n (2π)^{d/2} h^d)) is
applied by the ops.py wrapper.

Mixed precision (kernels/precision.py): the Gram operands may arrive bf16
(full-rate MXU) or as split hi–lo bf16 pairs (``y_lo``/``xt_lo`` — the
compensated bf16x2 tier).  Norms, ``sq``, the exponential, and the
accumulator are f32 at every tier; ``sq`` is clamped at 0 so low-precision
Gram round-off can never turn a self-distance into exp overflow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.precision import dot_f32, gram_compensated


def _kde_kernel(y_m_ref, nrm_m_ref, xt_n_ref, nrm_n_ref, inv2h2_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = dot_f32(y_m_ref[...], xt_n_ref[...])
    sq = jnp.maximum(nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g, 0.0)
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    out_ref[...] += jnp.sum(phi, axis=1, keepdims=True)


def _kde_kernel_x2(y_hi_ref, y_lo_ref, nrm_m_ref, xt_hi_ref, xt_lo_ref,
                   nrm_n_ref, inv2h2_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = gram_compensated(y_hi_ref[...], y_lo_ref[...],
                         xt_hi_ref[...], xt_lo_ref[...])
    sq = jnp.maximum(nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g, 0.0)
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    out_ref[...] += jnp.sum(phi, axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_kde_pallas(
    y: jnp.ndarray,        # (m, d)  queries, padded to block_m multiple
    nrm_y: jnp.ndarray,    # (m, 1)  f32
    xt: jnp.ndarray,       # (d, n)  train (transposed), padded to block_n
    nrm_x: jnp.ndarray,    # (1, n)  f32
    inv2h2: jnp.ndarray,   # (1, 1)  f32
    y_lo: jnp.ndarray | None = None,    # (m, d) bf16 lo plane (bf16x2)
    xt_lo: jnp.ndarray | None = None,   # (d, n) bf16 lo plane (bf16x2)
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw kernel launch; returns unnormalized sums (m, 1) f32."""
    m, d = y.shape
    n = xt.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    assert (y_lo is None) == (xt_lo is None), "bf16x2 needs both lo planes"
    grid = (m // block_m, n // block_n)

    row = pl.BlockSpec((block_m, d), lambda i, j: (i, 0))
    nrm_row = pl.BlockSpec((block_m, 1), lambda i, j: (i, 0))
    col = pl.BlockSpec((d, block_n), lambda i, j: (0, j))
    nrm_col = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))

    if y_lo is None:
        kernel, in_specs = _kde_kernel, [row, nrm_row, col, nrm_col, scalar]
        args = (y, nrm_y, xt, nrm_x, inv2h2)
    else:
        kernel = _kde_kernel_x2
        in_specs = [row, row, nrm_row, col, col, nrm_col, scalar]
        args = (y, y_lo, nrm_y, xt, xt_lo, nrm_x, inv2h2)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(*args)
