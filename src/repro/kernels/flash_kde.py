"""Flash KDE evaluation kernel: Gaussian kernel sums at query points.

Computes p_j = Σ_i exp(-‖y_j - x_i‖²/(2h²)) for query rows y_j against the
(debiased) train set, streaming train column tiles through VMEM and
accumulating the (BLOCK_M, 1) partial sums in place across the innermost
grid dimension (sequential-grid accumulation — see flash_score.py).

The Gram tile (BLOCK_M×d)@(d×BLOCK_N) runs on the MXU; the exponential and
row reduction run on the VPU.  Normalization (1/(n (2π)^{d/2} h^d)) is
applied by the ops.py wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kde_kernel(y_m_ref, nrm_m_ref, xt_n_ref, nrm_n_ref, inv2h2_ref, out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = jnp.dot(y_m_ref[...], xt_n_ref[...],
                preferred_element_type=jnp.float32)
    sq = nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    out_ref[...] += jnp.sum(phi, axis=1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_kde_pallas(
    y: jnp.ndarray,        # (m, d)  queries, padded to block_m multiple
    nrm_y: jnp.ndarray,    # (m, 1)  f32
    xt: jnp.ndarray,       # (d, n)  train (transposed), padded to block_n
    nrm_x: jnp.ndarray,    # (1, n)  f32
    inv2h2: jnp.ndarray,   # (1, 1)  f32
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw kernel launch; returns unnormalized sums (m, 1) f32."""
    m, d = y.shape
    n = xt.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)

    return pl.pallas_call(
        _kde_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(y, nrm_y, xt, nrm_x, inv2h2)
