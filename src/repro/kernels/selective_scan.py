"""Chunked selective-scan (Mamba1) Pallas kernel — the SSM hot spot.

Why: the roofline table (EXPERIMENTS.md §Roofline) shows the SSM/hybrid
prefill cells memory-bound by the XLA path's materialization of the
(B, S, d_inner, N) decay/drive tensors — 83 s of HBM time for hymba
prefill_32k.  This kernel applies the SAME insight as the paper's flash
kernels — keep the quadratic-in-state intermediate in VMEM, stream the
sequence — to the SSM recurrence:

    h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t x_t) · B_t
    y_t = C_t · h_t + D ⊙ x_t

Layout: grid = (batch, d_inner blocks, seq chunks); the seq-chunk axis is
LAST, i.e. sequential on TPU, so the (block_d, N) carry state lives in a
VMEM output ref that is revisited across chunks (index_map ignores the
sequential dim — the same sequential-grid accumulation trick as
flash_score.py).  Within a chunk the recurrence runs as a log-depth
associative scan over (chunk, block_d, N) ENTIRELY in VMEM/registers; only
x/Δ/B/C stream in (O(S·(d+N)) HBM bytes) and y streams out.

HBM traffic: O(B·S·(2·d_inner + 2·N)) vs the XLA path's
O(B·S·d_inner·N) — ~8× less at falcon-mamba's d_inner=8192, N=16
(kernels/tuning.py:selective_scan_bytes).

GPU→TPU adaptation note: CUDA Mamba runs a per-thread sequential scan in
registers/smem; the TPU-idiomatic form is chunkwise associative scan on
the VPU with the carry in VMEM — log-depth inside the chunk, sequential
across chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(xi_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                 y_ref, hout_ref):
    """One (batch, d-block, seq-chunk) step.

    Block shapes (VMEM):
      xi, dt : (chunk, bd)     — pre-activation inputs and Δ
      b, c   : (chunk, N)      — input-dependent SSM matrices
      a      : (bd, N)         — continuous-time A (negative)
      h0     : (bd, N)         — initial state for THIS batch row
      y      : (chunk, bd)     — output block
      hout   : (bd, N)         — carry state, revisited across chunks
    """
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        hout_ref[...] = h0_ref[...]

    # blocks carry a leading singleton batch dim: index it away
    xi = xi_ref[0].astype(jnp.float32)           # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)
    b = b_ref[0].astype(jnp.float32)             # (chunk, N)
    c = c_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)           # (bd, N)

    # decay_t = exp(Δ_t ⊗ A)  (chunk, bd, N); drive_t = (Δ_t x_t) ⊗ B_t
    decay = jnp.exp(dt[:, :, None] * a[None])
    drive = (dt * xi)[:, :, None] * b[:, None, :]

    # log-depth associative scan within the chunk (VMEM-resident)
    def combine(lhs, rhs):
        dl, vl = lhs
        dr, vr = rhs
        return dl * dr, vr + dr * vl

    pdecay, hloc = jax.lax.associative_scan(combine, (decay, drive), axis=0)

    h_in = hout_ref[0]                           # (bd, N) carry
    h = hloc + pdecay * h_in[None]               # carry-in contribution
    y_ref[0, :, :] = jnp.einsum(
        "tdn,tn->td", h, c, preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)
    hout_ref[0, :, :] = h[-1]


@functools.partial(
    jax.jit,
    static_argnames=("block_d", "chunk", "interpret"),
)
def selective_scan_pallas(
    xi: jnp.ndarray,      # (B, S, d_inner)  post-conv pre-gate inputs
    dt: jnp.ndarray,      # (B, S, d_inner)  softplus'd Δ
    b: jnp.ndarray,       # (B, S, N)
    c: jnp.ndarray,       # (B, S, N)
    a: jnp.ndarray,       # (d_inner, N)     negative continuous-time A
    h0: jnp.ndarray,      # (B, d_inner, N)  initial state
    *,
    block_d: int = 256,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B, S, d_inner) f32 pre-D/gate, h_final (B, d_inner, N)).

    S must divide ``chunk`` and d_inner ``block_d`` (ops.py pads).
    """
    bsz, s, d = xi.shape
    n = b.shape[-1]
    assert s % chunk == 0 and d % block_d == 0, (s, d, chunk, block_d)
    grid = (bsz, d // block_d, s // chunk)

    y, h_out = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, chunk, block_d), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, t: (i, t, 0)),
            pl.BlockSpec((block_d, n), lambda i, j, t: (j, 0)),
            pl.BlockSpec((1, block_d, n), lambda i, j, t: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, t: (i, t, j)),
            # carry state: revisited across the sequential chunk axis
            pl.BlockSpec((1, block_d, n), lambda i, j, t: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        interpret=interpret,
    )(xi, dt, b, c, a, h0)
    return y, h_out


