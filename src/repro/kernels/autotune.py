"""Launch-parameter autotuning: cost-model shortlist → optional device timing.

This is the §6.2 hillclimb wired into the dispatch path.  The seed carried
the performance model (kernels/tuning.py) but every kernel still launched at
one hardcoded tile shape; here every public wrapper may say
``block_m="auto"`` / ``block_n="auto"`` and gets, per (rows, cols, d,
out_width, precision):

  1. a **model shortlist** — every candidate tile under the (dtype-aware)
     VMEM budget, costed on the *padded* problem (padding a 300-row query
     batch to a 2048-row tile is real work the plain model can't see) with
     the MXU derated for the precision tier (f32 runs the systolic array in
     multiple passes; bf16x2 issues 4 GEMM products per logical GEMM);
  2. optionally, **device timing of the top-k** shortlisted configs
     (``measure=True``, or automatically on a real TPU backend) — the model
     ranks, the hardware votes;
  3. a **process-level winner cache** keyed by padded shape buckets
     (next-power-of-two rows/cols), so steady-state serving and repeated
     benchmark cells never re-tune.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro import obs
from repro.kernels import precision as prec
from repro.kernels import tuning

# Candidate tiles.  block_n is the lane-major streamed axis (multiples of
# 128 lanes); block_m is the sublane axis (multiples of 8).  Small sizes are
# included so tiny problems (tests, CPU-scaled cells) don't get padded into
# oblivion — the padded-shape cost makes the model reject oversized tiles
# for them automatically.
DEFAULT_BLOCK_MS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
DEFAULT_BLOCK_NS = (128, 256, 512, 1024, 2048, 4096)

# MXU throughput derate per tier.  The MXU natively multiplies bf16
# operands (f32 accumulate); XLA lowers an exact f32×f32 GEMM to a 6-pass
# bf16 expansion (the BF16_6X algorithm), so the f32 tier runs at ~1/6 of
# bf16 peak.  bf16 and bf16x2 run at full rate — bf16x2 instead issues 4
# products per logical GEMM (the compensated hi–lo expansion), which
# ``precision.gram_products`` accounts for, landing it between XLA's
# BF16_3X and BF16_6X in both cost and accuracy.
MXU_DERATE = {"f32": 1.0 / 6.0, "bf16": 1.0, "bf16x2": 1.0}

# Per-grid-step launch cost: Pallas grid-loop bookkeeping + DMA issue for
# the next column tile.  The roofline terms in tuning.py are totals over
# the pass and assume perfect pipelining; this is the constant the tile
# sweep actually trades against VMEM — at d=16 the pass is exp(VPU)-bound,
# so the *only* modeled difference between launch configs is how many grid
# steps they spend (fixed 128×512 on the 32k cell: 2048 steps; the tuned
# 1024-row tiles: a few dozen).
STEP_OVERHEAD_S = 150e-9

BlockArg = Union[int, str]  # an int or the literal "auto"


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One costed launch candidate (modeled on the padded problem)."""

    block_m: int
    block_n: int
    step_time: float           # modeled seconds for the full padded pass
    bound: str                 # which resource the model says saturates
    precision: str
    vmem_bytes: int

    @property
    def blocks(self) -> Tuple[int, int]:
        return self.block_m, self.block_n


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def modeled_cost(
    rows: int, cols: int, d: int, *, block_m: int, block_n: int,
    out_width: int = 1, precision: str = "f32",
    vmem_itemsize: Optional[int] = None,
    occupancy: float = 1.0,
) -> Optional[TunedConfig]:
    """Precision-derated, padding-aware cost; None if over the VMEM budget.

    ``vmem_itemsize`` overrides the operand byte width used for the VMEM
    feasibility gate only (cost terms still use the tier's own width).  The
    serving registry passes 4 so a tile tuned at the bf16 tier stays
    feasible when a per-request override later serves f32/bf16x2 traffic
    through the same prepared layout.

    ``occupancy`` is the expected fraction of column tiles each row block
    actually visits under cluster pruning (kernels/spatial.py): the
    streamed-tile HBM traffic, the pairwise MXU/VPU work and the grid-step
    overhead all scale with it, so the tile sweep can trade tile size
    against skip granularity.  1.0 models the dense pass.
    """
    prec.validate(precision)
    pr, pc = _pad_up(rows, block_m), _pad_up(cols, block_n)
    # A pruned pass streams ceil(occupancy · n_tiles) column tiles per row
    # block — identical cost structure to a dense pass over that many
    # columns (the row-tile and writeback terms don't shrink).
    visits = max(1, math.ceil(occupancy * (pc // block_n)))
    pc_eff = visits * block_n
    c = tuning.pair_pass_cost(
        pr, pc_eff, d, block_m=block_m, block_n=block_n, out_width=out_width,
        itemsize=prec.operand_bytes(precision),
    )
    vmem = c.vmem_bytes
    if vmem_itemsize is not None:
        vmem = tuning.pair_pass_cost(
            pr, pc_eff, d, block_m=block_m, block_n=block_n,
            out_width=out_width, itemsize=vmem_itemsize,
        ).vmem_bytes
    if vmem > tuning.VMEM_BUDGET:
        return None
    t_mxu = (c.mxu_flops * prec.gram_products(precision)
             / (tuning.MXU_FLOPS * MXU_DERATE[precision]))
    terms = {"hbm": c.t_hbm, "mxu": t_mxu, "vpu": c.t_vpu}
    grid_steps = (pr // block_m) * visits
    return TunedConfig(
        block_m, block_n,
        max(terms.values()) + grid_steps * STEP_OVERHEAD_S,
        max(terms, key=terms.get),
        precision, vmem,
    )


def shortlist(
    rows: int, cols: int, d: int, *, out_width: int = 1,
    precision: str = "f32",
    block_ms: Sequence[int] = DEFAULT_BLOCK_MS,
    block_ns: Sequence[int] = DEFAULT_BLOCK_NS,
    vmem_itemsize: Optional[int] = None,
    occupancy: float = 1.0,
    occupancy_fn: Optional[Callable[[int], float]] = None,
) -> List[TunedConfig]:
    """All feasible candidates, best modeled step time first.

    ``occupancy_fn`` maps a candidate ``block_n`` to its expected
    occupancy (tile-width-dependent — see ``expected_occupancy``); when
    given it overrides the flat ``occupancy``.
    """
    cands = []
    for bm in block_ms:
        for bn in block_ns:
            occ = occupancy_fn(bn) if occupancy_fn is not None else occupancy
            c = modeled_cost(rows, cols, d, block_m=bm, block_n=bn,
                             out_width=out_width, precision=precision,
                             vmem_itemsize=vmem_itemsize,
                             occupancy=occ)
            if c is not None:
                cands.append(c)
    return sorted(cands, key=lambda c: c.step_time)


# ---------------------------------------------------------------------------
# Winner cache + the tuning entry point.
# ---------------------------------------------------------------------------

_CACHE: Dict[tuple, Tuple[int, int]] = {}
_OCCUPANCY: Dict[tuple, Dict[int, float]] = {}
_LOCK = threading.Lock()

#: Reference column-tile width the pruned wrappers probe occupancy at (in
#: addition to their launch width).  A fine-granularity record is what lets
#: ``expected_occupancy`` extrapolate to ANY candidate tile, so the tuner
#: can discover that smaller tiles prune better even when the first launch
#: ran at a dense-optimal (huge) tile.
FINE_PROBE_BLOCK = 128


def clear_cache() -> None:
    with _LOCK:
        _CACHE.clear()
        _OCCUPANCY.clear()


def cache_info() -> Dict[tuple, Tuple[int, int]]:
    with _LOCK:
        return dict(_CACHE)


def _shape_bucket(x: int) -> int:
    """Next power of two ≥ x: the cache key granularity for rows/cols."""
    return 1 << max(int(math.ceil(math.log2(max(x, 1)))), 0)


def record_occupancy(rows: int, cols: int, d: int, occupancy: float,
                     block_n: int, alpha: float = 0.5) -> None:
    """Feed one measured tile-map occupancy back into the tuner.

    The pruned wrappers call this after every bounds prepass — once at the
    launch ``block_n`` and once at ``FINE_PROBE_BLOCK`` — keeping an EMA
    per (padded-shape bucket, block_n).  ``resolve_blocks(pruned=True)``
    consults the profile on the *next* resolve for that regime, so
    tile-shape choice learns the workload's actual skip rate instead of
    assuming a dense pass.
    """
    key = (_shape_bucket(rows), _shape_bucket(cols), d)
    occupancy = min(max(float(occupancy), 0.0), 1.0)
    obs.counter("autotune.occupancy_updates",
                "occupancy-profile EMA feeds").inc()
    obs.histogram("autotune.occupancy",
                  "measured tile-map occupancies fed to the tuner",
                  lo=1e-3, hi=1.0).observe(occupancy)
    with _LOCK:
        prof = _OCCUPANCY.setdefault(key, {})
        old = prof.get(block_n)
        prof[block_n] = occupancy if old is None else (
            (1.0 - alpha) * old + alpha * occupancy
        )


def has_occupancy(rows: int, cols: int, d: int, block_n: int) -> bool:
    """Whether a measured occupancy exists for this regime and tile width."""
    key = (_shape_bucket(rows), _shape_bucket(cols), d)
    with _LOCK:
        return block_n in _OCCUPANCY.get(key, {})


def expected_occupancy(rows: int, cols: int, d: int,
                       block_n: Optional[int] = None,
                       default: float = 1.0) -> float:
    """The learned occupancy for a shape regime (``default`` when unseen).

    Occupancy depends on tile width: a column tile wider than a cluster
    can never be skipped, so the keep fraction grows roughly linearly with
    tile span until it saturates.  A query at an unrecorded ``block_n``
    extrapolates linearly from the nearest recorded width below it (the
    fine probe, usually), capped at 1.
    """
    key = (_shape_bucket(rows), _shape_bucket(cols), d)
    with _LOCK:
        prof = dict(_OCCUPANCY.get(key, {}))
    if not prof:
        return default
    if block_n is None:
        return min(prof.values())
    if block_n in prof:
        return prof[block_n]
    below = [b for b in prof if b < block_n]
    ref = max(below) if below else min(prof)
    return min(1.0, prof[ref] * block_n / ref)


def _probe_time_fn(rows: int, cols: int, d: int, out_width: int,
                   precision: str) -> Callable[[int, int], float]:
    """Device-timing probe: best-of-3 wall clock of the real kernel shape
    on synthetic data at the candidate tile — the score kernel (with its
    second φ@[X|1] GEMM and (block_m, d+1) accumulator) when out_width > 1,
    the KDE kernel otherwise.  Only built when timing is requested (TPU
    present / measure=True) — never in interpret mode."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (cols, d), jnp.float32)
    y = jax.random.normal(ky, (rows, d), jnp.float32)

    def time_blocks(bm: int, bn: int) -> float:
        # prune="off": the probe times the DENSE kernel on synthetic
        # gaussian data — letting it prune would both time the wrong
        # pipeline and pollute the workload's learned occupancy profile
        if out_width > 1:
            fn = lambda: ops.flash_score_stats(  # noqa: E731
                x, 1.0, precision=precision, block_m=bm, block_n=bn,
                prune="off",
            )
        else:
            fn = lambda: ops.flash_kde(  # noqa: E731
                x, y, 1.0, precision=precision, block_m=bm, block_n=bn,
                prune="off",
            )
        jax.block_until_ready(fn())          # compile outside timing
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    return time_blocks


def autotune_blocks(
    rows: int, cols: int, d: int, *, out_width: int = 1,
    precision: str = "f32",
    block_ms: Sequence[int] = DEFAULT_BLOCK_MS,
    block_ns: Sequence[int] = DEFAULT_BLOCK_NS,
    measure: Optional[bool] = None,
    time_fn: Optional[Callable[[int, int], float]] = None,
    topk: int = 3,
    vmem_itemsize: Optional[int] = None,
    occupancy: float = 1.0,
    occupancy_fn: Optional[Callable[[int], float]] = None,
    occupancy_key: tuple = (),
) -> Tuple[int, int]:
    """The tuned (block_m, block_n) for one streaming pairwise pass.

    ``measure=None`` (default) times the shortlist's top-``topk`` on device
    only when a custom ``time_fn`` is supplied or a real TPU backend is
    attached; ``measure=False`` forces model-only; ``measure=True`` forces
    timing (building a synthetic probe if no ``time_fn`` is given).
    Winners are memoized process-wide, keyed by next-power-of-two padded
    shape buckets, so a serving process tunes each regime once.
    """
    prec.validate(precision)
    key = (_shape_bucket(rows), _shape_bucket(cols), d, out_width, precision,
           tuple(block_ms), tuple(block_ns), vmem_itemsize,
           round(occupancy, 2), occupancy_key)
    with _LOCK:
        if key in _CACHE:
            obs.counter("autotune.cache_hits",
                        "winner-cache hits (no re-tune)").inc()
            return _CACHE[key]

    with obs.span("autotune.resolve", rows=rows, cols=cols, d=d,
                  out_width=out_width, precision=precision) as sp:
        cands = shortlist(rows, cols, d, out_width=out_width,
                          precision=precision, block_ms=block_ms,
                          block_ns=block_ns, vmem_itemsize=vmem_itemsize,
                          occupancy=occupancy, occupancy_fn=occupancy_fn)
        if not cands:
            raise ValueError(
                f"no feasible launch config for rows={rows} cols={cols} "
                f"d={d} precision={precision} under the VMEM budget"
            )

        if measure is None:
            import jax

            measure = time_fn is not None or jax.default_backend() == "tpu"
        best = cands[0]
        if measure and len(cands) > 1:
            fn = time_fn or _probe_time_fn(rows, cols, d, out_width,
                                           precision)

            def timed(c: TunedConfig) -> float:
                t = fn(c.block_m, c.block_n)
                obs.counter("autotune.probes",
                            "device-timed candidate launches").inc()
                obs.histogram("autotune.probe_s",
                              "measured candidate launch times (s)",
                              lo=1e-6, hi=1e2).observe(t)
                return t

            best = min(cands[:topk], key=timed)
        obs.counter(
            "autotune.resolves", "fresh tuner decisions",
            labels={"mode": "measured" if measure else "model"},
        ).inc()
        sp.set(block_m=best.block_m, block_n=best.block_n,
               bound=best.bound, measured=bool(measure),
               candidates=len(cands))

    with _LOCK:
        _CACHE[key] = best.blocks
    return best.blocks


def resolve_blocks(
    block_m: BlockArg, block_n: BlockArg, rows: int, cols: int, d: int, *,
    out_width: int = 1, precision: str = "f32",
    row_multiple: Optional[int] = None,
    col_multiple: Optional[int] = None,
    measure: Optional[bool] = None,
    vmem_itemsize: Optional[int] = None,
    pruned: bool = False,
) -> Tuple[int, int]:
    """Turn ``"auto"`` block args into tuned ints (ints pass through).

    ``row_multiple`` / ``col_multiple`` constrain the tile to divide an
    already-padded row/column count (the prepared serving path, where the
    train tensors were padded at fit time and queries arrive pre-padded to
    a shape bucket — the tile sweep must respect those layouts).
    ``vmem_itemsize`` widens the VMEM feasibility gate (see modeled_cost)
    for callers that will reuse the tile across precision tiers.
    ``pruned`` costs candidates at the learned expected occupancy for this
    shape regime (``record_occupancy``) instead of a dense pass.
    """
    m_auto, n_auto = block_m == "auto", block_n == "auto"
    if not m_auto and not n_auto:
        return block_m, block_n

    def _fitting(cands, multiple):
        if multiple is None:
            return tuple(cands)
        fit = tuple(b for b in cands if multiple % b == 0)
        # fall back to the largest power of two dividing the padded count
        return fit or (math.gcd(multiple, 1 << 30),)

    block_ms = _fitting(DEFAULT_BLOCK_MS, row_multiple) if m_auto \
        else (block_m,)
    block_ns = _fitting(DEFAULT_BLOCK_NS, col_multiple) if n_auto \
        else (block_n,)
    occ_fn = None
    occ_key: tuple = ()
    if pruned:
        occ_fn = lambda bn: expected_occupancy(rows, cols, d, bn)  # noqa: E731
        key = (_shape_bucket(rows), _shape_bucket(cols), d)
        with _LOCK:
            prof = _OCCUPANCY.get(key, {})
            occ_key = tuple(sorted(
                (bn, round(o, 3)) for bn, o in prof.items()
            ))
    return autotune_blocks(
        rows, cols, d, out_width=out_width, precision=precision,
        block_ms=block_ms, block_ns=block_ns, measure=measure,
        vmem_itemsize=vmem_itemsize, occupancy_fn=occ_fn,
        occupancy_key=occ_key,
    )


__all__ = [
    "DEFAULT_BLOCK_MS", "DEFAULT_BLOCK_NS", "MXU_DERATE", "TunedConfig",
    "FINE_PROBE_BLOCK", "modeled_cost", "shortlist", "autotune_blocks",
    "resolve_blocks", "clear_cache", "cache_info", "record_occupancy",
    "expected_occupancy", "has_occupancy",
]
