"""Flash-Laplace-KDE kernels (fused + the non-fused second pass).

Fused kernel: applies the Laplace correction factor inside the same
distance/exponential pass as the plain KDE —

    out_j += Σ_i φ_ij · (1 + d/2 − sqd_ij/(2h²))

reusing the already-computed scaled distances, exactly the "kernel fusion
opportunity" of Section 5.  The non-fused baseline (Fig. 4) instead runs the
plain KDE kernel and then ``_sq_moment_kernel`` below, which *recomputes*
the distances to form Σ φ·sqd — a second full quadratic pass with its own
HBM traffic and launch, combined on the host as

    (1 + d/2)·S − M/(2h²),   S = Σφ,  M = Σφ·sqd.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _laplace_kernel(y_m_ref, nrm_m_ref, xt_n_ref, nrm_n_ref, inv2h2_ref,
                    out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = xt_n_ref.shape[0]
    g = jnp.dot(y_m_ref[...], xt_n_ref[...],
                preferred_element_type=jnp.float32)
    sq = nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g
    scaled = sq * inv2h2_ref[0, 0]            # ‖u‖²/(2h²), reused twice
    phi = jnp.exp(-scaled)
    corr = phi * (1.0 + d / 2.0 - scaled)     # fused Laplace factor
    out_ref[...] += jnp.sum(corr, axis=1, keepdims=True)


def _sq_moment_kernel(y_m_ref, nrm_m_ref, xt_n_ref, nrm_n_ref, inv2h2_ref,
                      out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = jnp.dot(y_m_ref[...], xt_n_ref[...],
                preferred_element_type=jnp.float32)
    sq = nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    out_ref[...] += jnp.sum(phi * sq, axis=1, keepdims=True)


def _launch(kernel, y, nrm_y, xt, nrm_x, inv2h2, block_m, block_n, interpret):
    m, d = y.shape
    n = xt.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(y, nrm_y, xt, nrm_x, inv2h2)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_laplace_pallas(y, nrm_y, xt, nrm_x, inv2h2, *,
                         block_m: int = 128, block_n: int = 512,
                         interpret: bool = False):
    """Fused Laplace-corrected sums (m, 1) f32 — one quadratic pass."""
    return _launch(_laplace_kernel, y, nrm_y, xt, nrm_x, inv2h2,
                   block_m, block_n, interpret)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def sq_moment_pallas(y, nrm_y, xt, nrm_x, inv2h2, *,
                     block_m: int = 128, block_n: int = 512,
                     interpret: bool = False):
    """Second pass of the non-fused baseline: Σ φ·sqd (m, 1) f32."""
    return _launch(_sq_moment_kernel, y, nrm_y, xt, nrm_x, inv2h2,
                   block_m, block_n, interpret)
