"""Flash-Laplace-KDE kernels (fused + the non-fused second pass).

Fused kernel: applies the Laplace correction factor inside the same
distance/exponential pass as the plain KDE —

    out_j += Σ_i φ_ij · (1 + d/2 − sqd_ij/(2h²))

reusing the already-computed scaled distances, exactly the "kernel fusion
opportunity" of Section 5.  The non-fused baseline (Fig. 4) instead runs the
plain KDE kernel and then ``_sq_moment_kernel`` below, which *recomputes*
the distances to form Σ φ·sqd — a second full quadratic pass with its own
HBM traffic and launch, combined on the host as

    (1 + d/2)·S − M/(2h²),   S = Σφ,  M = Σφ·sqd.

Mixed precision: the Gram operands may arrive bf16 or as split hi–lo bf16
pairs (the ``*_lo`` planes — kernels/precision.py); the correction factor,
exponential, and accumulators stay f32 at every tier.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.precision import dot_f32, gram_compensated


def _sq_tile(y_ref, nrm_m_ref, xt_ref, nrm_n_ref, y_lo_ref=None,
             xt_lo_ref=None):
    """The f32 squared-distance tile at whatever operand tier the refs carry."""
    if y_lo_ref is None:
        g = dot_f32(y_ref[...], xt_ref[...])
    else:
        g = gram_compensated(y_ref[...], y_lo_ref[...],
                             xt_ref[...], xt_lo_ref[...])
    return jnp.maximum(nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g, 0.0)


def _make_laplace_kernel(compensated: bool):
    def kernel(*refs):
        if compensated:
            (y_ref, y_lo_ref, nrm_m_ref, xt_ref, xt_lo_ref, nrm_n_ref,
             inv2h2_ref, out_ref) = refs
        else:
            y_ref, nrm_m_ref, xt_ref, nrm_n_ref, inv2h2_ref, out_ref = refs
            y_lo_ref = xt_lo_ref = None

        @pl.when(pl.program_id(1) == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        d = xt_ref.shape[0]
        sq = _sq_tile(y_ref, nrm_m_ref, xt_ref, nrm_n_ref, y_lo_ref,
                      xt_lo_ref)
        scaled = sq * inv2h2_ref[0, 0]            # ‖u‖²/(2h²), reused twice
        phi = jnp.exp(-scaled)
        corr = phi * (1.0 + d / 2.0 - scaled)     # fused Laplace factor
        out_ref[...] += jnp.sum(corr, axis=1, keepdims=True)

    return kernel


def _make_sq_moment_kernel(compensated: bool):
    def kernel(*refs):
        if compensated:
            (y_ref, y_lo_ref, nrm_m_ref, xt_ref, xt_lo_ref, nrm_n_ref,
             inv2h2_ref, out_ref) = refs
        else:
            y_ref, nrm_m_ref, xt_ref, nrm_n_ref, inv2h2_ref, out_ref = refs
            y_lo_ref = xt_lo_ref = None

        @pl.when(pl.program_id(1) == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        sq = _sq_tile(y_ref, nrm_m_ref, xt_ref, nrm_n_ref, y_lo_ref,
                      xt_lo_ref)
        phi = jnp.exp(-sq * inv2h2_ref[0, 0])
        out_ref[...] += jnp.sum(phi * sq, axis=1, keepdims=True)

    return kernel


_LAPLACE = {False: _make_laplace_kernel(False), True: _make_laplace_kernel(True)}
_SQ_MOMENT = {False: _make_sq_moment_kernel(False),
              True: _make_sq_moment_kernel(True)}


def _launch(kernels, y, nrm_y, xt, nrm_x, inv2h2, y_lo, xt_lo,
            block_m, block_n, interpret):
    m, d = y.shape
    n = xt.shape[1]
    assert m % block_m == 0 and n % block_n == 0, (m, n, block_m, block_n)
    assert (y_lo is None) == (xt_lo is None), "bf16x2 needs both lo planes"
    grid = (m // block_m, n // block_n)

    row = pl.BlockSpec((block_m, d), lambda i, j: (i, 0))
    nrm_row = pl.BlockSpec((block_m, 1), lambda i, j: (i, 0))
    col = pl.BlockSpec((d, block_n), lambda i, j: (0, j))
    nrm_col = pl.BlockSpec((1, block_n), lambda i, j: (0, j))
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))

    if y_lo is None:
        in_specs = [row, nrm_row, col, nrm_col, scalar]
        args = (y, nrm_y, xt, nrm_x, inv2h2)
    else:
        in_specs = [row, row, nrm_row, col, col, nrm_col, scalar]
        args = (y, y_lo, nrm_y, xt, xt_lo, nrm_x, inv2h2)

    return pl.pallas_call(
        kernels[y_lo is not None],
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(*args)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_laplace_pallas(y, nrm_y, xt, nrm_x, inv2h2, y_lo=None, xt_lo=None,
                         *, block_m: int = 128, block_n: int = 512,
                         interpret: bool = False):
    """Fused Laplace-corrected sums (m, 1) f32 — one quadratic pass."""
    return _launch(_LAPLACE, y, nrm_y, xt, nrm_x, inv2h2, y_lo, xt_lo,
                   block_m, block_n, interpret)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def sq_moment_pallas(y, nrm_y, xt, nrm_x, inv2h2, y_lo=None, xt_lo=None,
                     *, block_m: int = 128, block_n: int = 512,
                     interpret: bool = False):
    """Second pass of the non-fused baseline: Σ φ·sqd (m, 1) f32."""
    return _launch(_SQ_MOMENT, y, nrm_y, xt, nrm_x, inv2h2, y_lo, xt_lo,
                   block_m, block_n, interpret)
