"""Random-Fourier-feature fast tier: one small GEMM per query, banded.

Every exact serving tier answers a query by streaming the *whole* train
set through the pairwise kernel — O(n·d) MXU work plus n exponentials per
query row, however well tiled.  Random Fourier features (Rahimi–Recht;
Gallego et al.'s RFF/density-matrix KDE, PAPERS.md arxiv 2208.01206)
collapse that to a train-independent cost: with frequencies
``w_j ~ N(0, I/h²)`` the Gaussian kernel is the expectation
``k(y,x) = E_w[cos(w·y)cos(w·x) + sin(w·y)sin(w·x)]``, so the kernel sum
``S(y) = Σ_i k(y, x_i)`` is estimated from per-dataset *feature sums*

    z_cos[j] = Σ_i cos(w_j·x_i),   z_sin[j] = Σ_i sin(w_j·x_i)

as ``Ŝ(y) = mean_j [cos(w_j·y)·z_cos[j] + sin(w_j·y)·z_sin[j]]`` — one
(m×d)@(d×D/2) feature GEMM plus trig per query batch, independent of n.

Two additions make this a *certifiable* serving tier rather than a heuristic:

**Pilot control variate.**  The vanilla estimator's variance is hopeless
for tight targets (relative error ~1/√(D·k̄), orders of magnitude above
1e-2 at practical D).  We therefore fit per-cluster Gaussian moments
(counts, means, mean per-dim variances over the k-means cells of
``kernels.spatial`` — the same geometry the pruning certificates use) and
split the kernel sum into an *analytic* pilot term plus an RFF-estimated
*residual*: a mixture of isotropic Gaussians has a closed-form Gaussian
convolution AND a closed-form characteristic function, so

    S(y) ≈ S_pilot(y) + mean_j [cos(w_j·y)·rc[j] + sin(w_j·y)·rs[j]]

with ``rc = z_cos − z_pilot_cos`` the residual feature sums.  The RFF
noise now scales with the residual mass (how non-Gaussian each cell is),
typically 1–2 orders below the raw sums — that is what brings 1e-2
certificates into reach at D ≈ 8192.

**Per-query uncertainty band.**  The D/2 frequencies are split into
``groups`` independent batches; the spread of the per-group estimates
gives a standard error, and the certified relative band is

    band(y) = Z · stderr(y) / max(p̂(y), TAIL_FRAC · p_scale)

with the same tail floor the realized-error metric uses (``p_scale`` is a
high-percentile train density fitted once).  The serving cascade
(``serve/cascade.py``) answers a query at this tier only when ``band``
fits the request's accuracy target, so the band being *honest* — never
exceeded by realized error — is the acceptance-gated contract
(``benchmarks/rff_cascade.py``).

Fit is O(n·D·d/2) once per dataset generation — amortized alongside the
debias pass in the serving registry — and the accumulators are exact
sums, so streaming append/evict folds in as an O(b·D·d/2) delta
(:func:`update`) with a full refit only on layout-epoch rebuilds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandwidth import gaussian_norm_const
from repro.kernels import precision as prec
from repro.kernels import spatial

#: Total feature count D (cos+sin pair per frequency → D/2 frequencies).
DEFAULT_FEATURES = 8192
#: Pilot mixture size (k-means cells whose Gaussian moments we fit).
DEFAULT_PILOT = 256
#: Independent frequency groups for the per-query standard error.  The
#: group count is the *degrees of freedom* behind the band: with G
#: groups the band is effectively a (G−1)-dof t-statistic scaled by Z,
#: and P(|t₇| > 5) ≈ 2e-3 — a few violations per thousand queries at
#: G=8, observed in practice at acceptance scale.  G=32 pushes the same
#: Z=5 to P(|t₃₁| > 5) ≈ 1e-5 while leaving the band width itself
#: unchanged in expectation (the overall stderr does not depend on how
#: the D/2 frequencies are grouped).
DEFAULT_GROUPS = 32
#: Band factor Z: certified band = Z · group stderr (empirically Z=4
#: still shows rare violations; Z=5 held with margin across sweeps at
#: :data:`DEFAULT_GROUPS`-many groups).
BAND_Z = 5.0
#: Relative-error tail floor, as a fraction of the fitted density scale:
#: band and realized error are both measured against
#: ``max(p, TAIL_FRAC·p_scale)`` so near-zero tails don't blow up ratios.
TAIL_FRAC = 0.01
#: Bandwidth scale for the frequency distribution.  MUST stay 1.0 for a
#: sound cascade: sampling from a widened 1/(s·h) distribution estimates
#: the kernel sum at bandwidth s·h — a *different estimand* than the
#: exact tier the cascade escalates to, and the group-spread band only
#: certifies Monte-Carlo error, never that smoothing bias.  (Importance
#: weights can't rescue it either: the weight's second moment diverges
#: for s ≥ √2 and inflates variance ~30× already at s=1.3.)  The
#: variance a widened kernel used to hide is bought back with a finer
#: pilot mixture instead (:data:`DEFAULT_PILOT`).
H_SCALE = 1.0

_FIT_BLOCK = 16384
_P_SCALE_SAMPLE = 512
_P_SCALE_PCT = 99.0


@dataclasses.dataclass(frozen=True)
class RFFServing:
    """Immutable per-generation serving tensors (a jit-friendly pytree).

    Everything :func:`eval_density` needs, finalized from the exact
    accumulators of :class:`RFFState`: f32 frequencies/residuals for the
    feature GEMM, the pilot mixture in query-evaluation form, and the
    normalization/floor scalars.  Registered as a pytree with ``groups``
    as *static* aux data — it shapes the reshape inside
    :func:`eval_density`, so it must stay concrete under jit.
    """

    wt: jnp.ndarray        # (d, D/2) f32 — feature GEMM operand
    res_cos: jnp.ndarray   # (D/2,) f32 residual feature sums
    res_sin: jnp.ndarray   # (D/2,) f32
    mu: jnp.ndarray        # (K, d) f32 live pilot means
    beta: jnp.ndarray      # (K,) f32 pilot amplitudes n_k·(h²/s²_k)^{d/2}
    inv2s2: jnp.ndarray    # (K,) f32 1/(2s²_k), s²_k = h² + var_k
    norm: jnp.ndarray      # () f32 n · (2π)^{d/2} h^d
    p_floor: jnp.ndarray   # () f32 TAIL_FRAC · p_scale
    groups: int            # static: frequency groups for the stderr


_SERVING_LEAVES = ("wt", "res_cos", "res_sin", "mu", "beta", "inv2s2",
                   "norm", "p_floor")

jax.tree_util.register_pytree_node(
    RFFServing,
    lambda s: (tuple(getattr(s, f) for f in _SERVING_LEAVES), s.groups),
    lambda groups, leaves: RFFServing(*leaves, groups=groups),
)


@dataclasses.dataclass
class RFFState:
    """Exact fit-time accumulators of the RFF tier (streaming-updatable).

    All sums are float64 and *exact* for the frequencies ``w`` actually
    used, so append/evict deltas commute with refits; the derived serving
    tensors are cached and invalidated on every update.
    """

    h: float               # the serving bandwidth (== the exact tier's h)
    d: int
    n: int                 # live train count the sums cover
    groups: int
    seed: int
    npp: float             # per-point normalizer (2π)^{d/2} h^d
    w: np.ndarray          # (D/2, d) f64 frequencies (fixed per fit)
    z_cos: np.ndarray      # (D/2,) f64 train feature sums
    z_sin: np.ndarray
    centroids: np.ndarray  # (K, d) f64 pilot anchors (fixed per fit)
    pilot_n: np.ndarray    # (K,) f64 per-cell counts
    pilot_s1: np.ndarray   # (K, d) f64 per-cell coordinate sums
    pilot_ss: np.ndarray   # (K,) f64 per-cell Σ‖x‖²
    p_scale: float = 0.0   # high-percentile fit density (band floor scale)
    _serving: Optional[RFFServing] = dataclasses.field(
        default=None, repr=False)

    @property
    def n_features(self) -> int:
        return 2 * self.w.shape[0]

    def serving(self) -> RFFServing:
        """Finalized serving tensors (cached until the next update)."""
        if self._serving is None:
            self._serving = _finalize(self)
        return self._serving


def supports(method: str, backend: str) -> bool:
    """Whether the RFF tier can serve this estimator configuration.

    sd-kde serves its *debiased* points as a plain Gaussian KDE, so the
    tier covers kde and sdkde alike; the Laplace-corrected kernel's
    spectral weight (1 + h²‖w‖²/2) inflates exactly the high-frequency
    residuals the pilot cannot absorb, and the ring backend shards points
    at fit time — both fall back to their exact tiers.
    """
    return method in ("kde", "sdkde") and backend in ("jnp", "pallas")


def fit(points, h: float, *, n_features: int = DEFAULT_FEATURES,
        n_pilot: int = DEFAULT_PILOT, groups: int = DEFAULT_GROUPS,
        h_scale: float = H_SCALE, seed: int = 0) -> RFFState:
    """Fit the RFF tier over a (debiased) train set — once per generation.

    ``h`` is the exact tier's bandwidth and (with ``h_scale`` at its 1.0
    default) the tier's estimand too — the same kernel sum the cascade's
    escalation tier computes, which is what makes the band a certificate
    rather than a heuristic (see :data:`H_SCALE`).  O(n·D·d/2) feature
    sums in f64 plus one O(n·K·d) pilot pass.
    """
    x = np.asarray(points, np.float64)
    n, d = x.shape
    if n_features % (2 * groups):
        raise ValueError(
            f"n_features must be a multiple of 2·groups, got "
            f"{n_features} with groups={groups}")
    h_rff = float(h) * float(h_scale)
    n_half = n_features // 2

    # frequencies are drawn once and stored at f32 *values* (in f64 for
    # the fit math): serving casts them per tier, and using the identical
    # values at fit and query time keeps the accumulators exact for the
    # frequencies actually served
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((n_half, d)) / h_rff).astype(
        np.float32).astype(np.float64)

    # pilot anchors: the same k-means machinery the pruning certificates
    # use; labels ARE argmin-to-centroid, so streaming updates assigning
    # deltas to their nearest anchor stay consistent with the fit
    idx = spatial.build_index(jnp.asarray(x, jnp.float32),
                              n_clusters=max(1, min(n_pilot, n)), seed=seed)
    labels = np.asarray(idx.labels)
    # the anchors ARE the full centroid set: fit labels and streaming
    # deltas then share one assignment rule (argmin-to-anchor), so an
    # evicted point subtracts from exactly the cell its append filled
    centroids = np.asarray(idx.centroids, np.float64)
    k = centroids.shape[0]
    pilot_n = np.bincount(labels, minlength=k).astype(np.float64)
    pilot_s1 = np.zeros((k, d))
    np.add.at(pilot_s1, labels, x)
    pilot_ss = np.bincount(labels, weights=(x * x).sum(1),
                           minlength=k).astype(np.float64)

    # the O(n·D/2·d) feature-sum pass runs f32 under jit with f64 block
    # accumulation: phase rounding perturbs the sums orders of magnitude
    # below the pilot residuals the band measures, and the XLA path is
    # what the paper's "fit is one featurization GEMM" story models
    feat = jax.jit(lambda xb, wt: (jnp.cos(xb @ wt).sum(0),
                                   jnp.sin(xb @ wt).sum(0)))
    x32 = jnp.asarray(x, jnp.float32)
    w32 = jnp.asarray(w.T, jnp.float32)
    z_cos = np.zeros(n_half)
    z_sin = np.zeros(n_half)
    for off in range(0, n, _FIT_BLOCK):
        blk = x32[off:off + _FIT_BLOCK]
        if blk.shape[0] != _FIT_BLOCK:       # ragged tail: pad to one shape
            pad = _FIT_BLOCK - blk.shape[0]
            zc, zs = feat(jnp.pad(blk, ((0, pad), (0, 0))), w32)
            # padded rows contribute cos(0)=1 per frequency — subtract
            z_cos += np.asarray(zc, np.float64) - pad
            z_sin += np.asarray(zs, np.float64)
        else:
            zc, zs = feat(blk, w32)
            z_cos += np.asarray(zc, np.float64)
            z_sin += np.asarray(zs, np.float64)

    state = RFFState(
        h=h_rff, d=d, n=n, groups=groups, seed=seed,
        npp=gaussian_norm_const(d, 1.0) * h_rff ** d,
        w=w, z_cos=z_cos, z_sin=z_sin, centroids=centroids,
        pilot_n=pilot_n, pilot_s1=pilot_s1, pilot_ss=pilot_ss,
    )
    # band floor scale: the tier's own density at a train subsample — the
    # high percentile is the "typical peak" the tail floor is relative to
    sample = x[rng.choice(n, size=min(_P_SCALE_SAMPLE, n), replace=False)]
    p, _ = eval_density(state.serving(),
                        jnp.asarray(sample, jnp.float32))
    state.p_scale = float(np.percentile(np.asarray(p), _P_SCALE_PCT))
    state._serving = None          # rebuild with the real floor
    return state


def update(state: RFFState, added=None, removed=None) -> None:
    """Fold a streaming delta into the accumulators — O(b·D·d/2).

    ``added``/``removed`` are (b, d) point batches.  Sums are exact, so
    updates commute; eviction subtracts exactly what an earlier append
    (or the fit) added, because pilot assignment is argmin-to-anchor on
    both sides.  Invalidates the cached serving tensors.
    """
    for sign, pts in ((1.0, added), (-1.0, removed)):
        if pts is None:
            continue
        p = np.asarray(pts, np.float64)
        if p.size == 0:
            continue
        p = np.atleast_2d(p)
        for off in range(0, p.shape[0], _FIT_BLOCK):
            blk = p[off:off + _FIT_BLOCK]
            t = blk @ state.w.T
            state.z_cos += sign * np.cos(t).sum(0)
            state.z_sin += sign * np.sin(t).sum(0)
            d2 = ((blk[:, None, :] - state.centroids[None]) ** 2).sum(-1)
            lab = d2.argmin(1)
            state.pilot_n += sign * np.bincount(
                lab, minlength=state.centroids.shape[0])
            np.add.at(state.pilot_s1, lab, sign * blk)
            state.pilot_ss += sign * np.bincount(
                lab, weights=(blk * blk).sum(1),
                minlength=state.centroids.shape[0])
            state.n += int(sign * blk.shape[0])
    state.pilot_n = np.maximum(state.pilot_n, 0.0)
    state._serving = None


def _finalize(state: RFFState) -> RFFServing:
    """Exact accumulators → f32 serving tensors (residuals, pilot form)."""
    nk = state.pilot_n
    live = nk > 0
    mu = np.zeros_like(state.pilot_s1)
    mu[live] = state.pilot_s1[live] / nk[live, None]
    var = np.zeros_like(nk)
    var[live] = np.maximum(
        state.pilot_ss[live] / nk[live] - (mu[live] ** 2).sum(1), 0.0
    ) / state.d
    h2 = state.h * state.h
    s2 = h2 + var
    beta = np.where(live, nk * (h2 / s2) ** (state.d / 2.0), 0.0)

    # analytic pilot characteristic-function sums → residual feature sums
    w2 = (state.w ** 2).sum(1)                       # (D/2,)
    att = np.exp(-var[None, :] * w2[:, None] / 2.0)  # (D/2, K)
    tm = state.w @ mu.T                              # (D/2, K)
    amp = np.where(live, nk, 0.0)[None, :] * att
    zpc = (amp * np.cos(tm)).sum(1)
    zps = (amp * np.sin(tm)).sum(1)

    return RFFServing(
        wt=jnp.asarray(state.w.T, jnp.float32),
        res_cos=jnp.asarray(state.z_cos - zpc, jnp.float32),
        res_sin=jnp.asarray(state.z_sin - zps, jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
        beta=jnp.asarray(beta, jnp.float32),
        inv2s2=jnp.asarray(1.0 / (2.0 * s2), jnp.float32),
        norm=jnp.float32(max(state.n, 1) * state.npp),
        p_floor=jnp.float32(TAIL_FRAC * max(state.p_scale, 0.0)),
        groups=state.groups,
    )


def _feature_phases(y: jnp.ndarray, wt: jnp.ndarray,
                    precision: str) -> jnp.ndarray:
    """The (m, D/2) phase GEMM ``y @ wt`` at a GEMM-operand tier.

    The one MXU-shaped op of the tier — same operand-cast discipline as
    the exact kernels (``kernels/precision.py``): reduced tiers perturb
    the phases like a data perturbation; trig and everything after stay
    f32.
    """
    y_hi, y_lo = prec.cast_operand(y, precision)
    w_hi, w_lo = prec.cast_operand(wt, precision)
    if y_lo is not None:
        return prec.gram_compensated(y_hi, y_lo, w_hi, w_lo)
    return prec.dot_f32(y_hi, w_hi)


def eval_density(serving: RFFServing, y: jnp.ndarray, *,
                 precision: str = "f32",
                 z: float = BAND_Z) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Densities and certified relative bands for a query batch.

    Pure in ``(serving, y)`` — safe to close over nothing and jit with
    ``serving`` passed as a pytree argument.  Returns ``(p, band)``, both
    (m,): ``p`` clipped at 0, ``band`` the Z-sigma relative band against
    the tail-floored denominator (the cascade compares it to the
    request's accuracy target).
    """
    y = jnp.asarray(y, jnp.float32)
    t = _feature_phases(y, serving.wt, precision)      # (m, D/2)
    contrib = (jnp.cos(t) * serving.res_cos
               + jnp.sin(t) * serving.res_sin)         # (m, D/2)
    m = contrib.shape[0]
    g = serving.groups
    per_group = contrib.reshape(m, g, -1).mean(axis=2)  # (m, g)

    # analytic pilot kernel sum at the queries: tiny (m, K) pass
    d2 = (jnp.sum(y * y, axis=1, keepdims=True)
          + jnp.sum(serving.mu * serving.mu, axis=1)[None, :]
          - 2.0 * prec.dot_f32(y, serving.mu.T))
    s_pilot = jnp.sum(serving.beta[None, :]
                      * jnp.exp(-jnp.maximum(d2, 0.0)
                                * serving.inv2s2[None, :]), axis=1)

    p_g = (s_pilot[:, None] + per_group) / serving.norm
    p_hat = jnp.mean(p_g, axis=1)
    stderr = jnp.std(p_g, axis=1, ddof=1) / np.sqrt(g)
    denom = jnp.maximum(jnp.abs(p_hat), serving.p_floor)
    band = z * stderr / denom
    return jnp.maximum(p_hat, 0.0), band


def realized_error(p_hat, p_exact, p_scale: float) -> np.ndarray:
    """The tail-floored relative error the band certifies against.

    One definition, used by the cascade tests and the acceptance
    benchmark alike: ``|p̂ − p| / max(p, TAIL_FRAC·p_scale)``.
    """
    p_hat = np.asarray(p_hat, np.float64)
    p_exact = np.asarray(p_exact, np.float64)
    return np.abs(p_hat - p_exact) / np.maximum(
        p_exact, TAIL_FRAC * max(p_scale, 0.0))


def modeled_query_cost_us(rows: int, d: int, *,
                          n_features: int = DEFAULT_FEATURES,
                          n_pilot: int = 0,
                          precision: str = "f32") -> float:
    """Modeled per-batch step time of the RFF tier, microseconds.

    Reuses the autotune pair-pass cost model with the feature matrix as
    the "train" operand — the tier's hot loop IS a (m×d)@(d×D/2) pass
    with an elementwise plane on top.  The ×2 covers the cos+sin planes
    (two VPU transcendental passes over the (m, D/2) phase plane where
    the exact kernel runs one exp).  ``n_pilot`` adds one (m, K)
    pilot-mixture pass — negligible at the K≈256 default (the planner
    omits it), but a real fraction of the feature GEMM once K rivals
    D/2, so cost-sensitive callers pass their pilot size.
    """
    from repro.kernels import autotune

    def _pass(cols: int) -> float:
        block_n = min(512, max(128, cols))
        c = autotune.modeled_cost(rows, cols, d, block_m=128,
                                  block_n=block_n, precision=precision)
        if c is None:                   # over VMEM: model at minimum tile
            c = autotune.modeled_cost(rows, cols, d, block_m=8,
                                      block_n=128, precision=precision)
        return c.step_time

    t = 2.0 * _pass(n_features // 2)
    if n_pilot > 0:
        t += _pass(n_pilot)
    return 1e6 * t


__all__ = [
    "DEFAULT_FEATURES", "DEFAULT_PILOT", "DEFAULT_GROUPS", "BAND_Z",
    "TAIL_FRAC", "H_SCALE", "RFFServing", "RFFState", "supports", "fit",
    "update", "eval_density", "realized_error", "modeled_query_cost_us",
]
