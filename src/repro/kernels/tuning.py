"""Flash-kernel performance model + block-size hillclimb (paper §4.1/§6.2 on TPU).

The compiled dry-run measures the XLA-GEMM path, where the φ matrix spills
to HBM between the Gram dot, the exponential, and the S1 GEMM (the measured
memory-bound baseline of the flash_sdkde_* cells).  The Pallas kernels keep
φ in VMEM — their HBM traffic is the PAPER'S tile model (§4.1), which this
module evaluates per (block_m, block_n) under the v5e VMEM budget, exactly
the launch-parameter hillclimb of §6.2 with TPU constraints instead of
warps/stages.

Compute is a TWO-resource model — the TPU analogue of the paper's
SFU-budget accounting (1 exp = 8 FP32 flops on the A6000's 128:16 ratio):

    t_mxu = GEMM flops / 197 TFLOP/s        (systolic array)
    t_vpu = (exp ops × EXP_VPU_OPS + scalar flops) / VPU throughput
    t_hbm = tile-model bytes / 819 GB/s

    step  ≥ max(t_mxu, t_vpu, t_hbm)

Validated against the paper's own coefficients in tests/test_analysis.py
(FLOPs 81.5 k², bytes 1.13 k² at the paper's blocks).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

# v5e per-chip constants
MXU_FLOPS = 197e12
HBM_BW = 819e9
VMEM_BYTES = 16 * 2**20
VMEM_BUDGET = 12 * 2**20           # headroom for double buffering
# VPU: 8 sublanes × 128 lanes × 2 issue × ~940 MHz  ≈ 1.9e12 elementwise op/s
VPU_OPS = 1.9e12
EXP_VPU_OPS = 10                   # ~ops per transcendental on the VPU


@dataclasses.dataclass(frozen=True)
class KernelCost:
    block_m: int
    block_n: int
    hbm_bytes: float
    mxu_flops: float
    exp_count: float
    vpu_flops: float               # non-exp elementwise work
    vmem_bytes: int

    @property
    def t_hbm(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_mxu(self) -> float:
        return self.mxu_flops / MXU_FLOPS

    @property
    def t_vpu(self) -> float:
        return (self.exp_count * EXP_VPU_OPS + self.vpu_flops) / VPU_OPS

    @property
    def step_time(self) -> float:
        return max(self.t_hbm, self.t_mxu, self.t_vpu)

    @property
    def bound(self) -> str:
        terms = {"hbm": self.t_hbm, "mxu": self.t_mxu, "vpu": self.t_vpu}
        return max(terms, key=terms.get)


def pair_pass_cost(
    rows: int, cols: int, d: int, *, block_m: int, block_n: int,
    out_width: Optional[int] = None, itemsize: int = 4,
) -> KernelCost:
    """One streaming pairwise pass (score OR kde OR laplace kernel).

    ``rows`` — resident row tile set (queries / eval points, per device);
    ``cols`` — streamed column points (per device, over the full ring);
    ``out_width`` — accumulator width (d+1 for score S1aug, 1 for KDE sums);
    ``itemsize`` — bytes/element of the GEMM *operands* (4 for f32, 2 for
    bf16, 4 for the two-plane bf16x2 split — kernels/precision.py).  Norms,
    the φ tile, and the accumulator are f32 at every tier.

    HBM per (row-tile × col-tile), the paper's §4.1 ledger: row tile loaded
    once per row block (amortized over the column sweep), column tile
    streamed per tile, partial output written once per row block.
    """
    ow = out_width if out_width is not None else 1
    m_tiles = -(-rows // block_m)
    n_tiles = -(-cols // block_n)
    per_tile = (itemsize * block_n * d               # streamed cols
                + 4 * block_n)                       # + f32 norms
    per_row_block = (itemsize * block_m * d          # row tile
                     + 4 * block_m                   # + f32 norms
                     + 4 * block_m * ow)             # accumulator writeback
    hbm = m_tiles * n_tiles * per_tile + m_tiles * per_row_block

    pairs = float(rows) * cols
    gram = 2.0 * d * pairs                           # MXU
    accum = 2.0 * ow * pairs if ow > 1 else 0.0      # φ @ [X|1] MXU GEMM
    exps = pairs
    scalar = 4.0 * pairs + (2.0 * pairs if ow == 1 else 0.0)

    # VMEM working set: matches ops.vmem_tile_bytes (operands at itemsize,
    # f32 norms / φ tile / accumulator at 4 bytes).  The xaug column tile
    # exists only on the score path (ow > 1); KDE/Laplace accumulate a
    # single column, so budgeting the (block_n, d+1) tile and a (d+1)-wide
    # accumulator for them would shrink the feasible tile space for no
    # reason.
    vmem = itemsize * (
        block_m * d + d * block_n + (block_n * (d + 1) if ow > 1 else 0)
    ) + 4 * (
        block_m + block_n + block_m * block_n + block_m * ow
    )
    return KernelCost(block_m, block_n, hbm, gram + accum, exps, scalar, vmem)


def sdkde_device_cost(
    n: int, m: int, d: int, *, chips: int = 256, model_shards: int = 16,
    block_m: int = 1024, block_n: int = 2048,
) -> Tuple[KernelCost, KernelCost]:
    """(score pass, kde pass) per-device costs under the block-partitioned
    2-D decomposition (distributed/ring2d.py): eval rows over ``model``
    (n/16, m/16), train columns over the remaining chips/16 shards —
    n²/chips pairs per device, no redundancy."""
    col_shards = max(chips // model_shards, 1)
    score = pair_pass_cost(n // model_shards, n // col_shards, d,
                           block_m=block_m, block_n=block_n, out_width=d + 1)
    kde = pair_pass_cost(m // model_shards, n // col_shards, d,
                         block_m=block_m, block_n=block_n, out_width=1)
    return score, kde


def selective_scan_bytes(bsz: int, s: int, d: int, n: int,
                         itemsize: int = 2) -> Tuple[float, float]:
    """(kernel HBM bytes, XLA-path HBM bytes) for the Mamba selective scan.

    Kernel (kernels/selective_scan.py): stream xi/Δ/B/C in, y out — the
    (S, d, N) state tensor never leaves VMEM.
    XLA path (models/ssm.py): the associative scan materializes decay and
    drive (B,S,d,N) f32 and re-reads them ~log passes; we count the
    minimal 2 tensors × (write + read) — a LOWER bound on its traffic.
    """
    kernel = bsz * s * (2 * d * itemsize + 2 * n * itemsize + 4 * d)
    xla = 2 * 2 * bsz * s * d * n * 4
    return float(kernel), float(xla)


def sweep_blocks(
    rows: int, cols: int, d: int, *,
    block_ms: Iterable[int] = (64, 128, 256, 512, 1024, 2048, 4096),
    block_ns: Iterable[int] = (256, 512, 1024, 2048, 4096),
    out_width: Optional[int] = None, itemsize: int = 4,
):
    """The §6.2 hillclimb: every launch config under the VMEM budget,
    sorted by modeled step time.  (kernels/autotune.py layers padding-aware,
    precision-derated costs and a winner cache on top of this sweep.)"""
    rows_aligned = []
    for bm in block_ms:
        for bn in block_ns:
            c = pair_pass_cost(rows, cols, d, block_m=bm, block_n=bn,
                               out_width=out_width, itemsize=itemsize)
            if c.vmem_bytes <= VMEM_BUDGET:
                rows_aligned.append(c)
    return sorted(rows_aligned, key=lambda c: c.step_time)


def best_blocks(rows: int, cols: int, d: int, **kw) -> KernelCost:
    return sweep_blocks(rows, cols, d, **kw)[0]
