"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function computes exactly what the corresponding kernel
computes (same inputs, same outputs, same padding semantics), with no tiling
— the ground truth for the allclose sweeps in ``tests/test_kernels_*.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def _sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    an = jnp.sum(a.astype(jnp.float32) ** 2, axis=-1)[:, None]
    bn = jnp.sum(b.astype(jnp.float32) ** 2, axis=-1)[None, :]
    g = a.astype(jnp.float32) @ b.astype(jnp.float32).T
    return an + bn - 2.0 * g


def ref_score_stats(x: jnp.ndarray, h: float):
    """(S0, S1): S0_i = Σ_j φ_ij, S1_i = Σ_j φ_ij x_j (train×train)."""
    sq = _sqdist(x, x)
    phi = jnp.exp(-sq / (2.0 * h * h))
    s0 = jnp.sum(phi, axis=1)
    s1 = phi @ x.astype(jnp.float32)
    return s0, s1


def ref_kde_sums(x: jnp.ndarray, y: jnp.ndarray, h: float) -> jnp.ndarray:
    """Unnormalized KDE sums at queries: p_j = Σ_i φ(y_j, x_i)."""
    sq = _sqdist(y, x)
    return jnp.sum(jnp.exp(-sq / (2.0 * h * h)), axis=1)


def ref_laplace_sums(x: jnp.ndarray, y: jnp.ndarray, h: float) -> jnp.ndarray:
    """Unnormalized Laplace-corrected sums: Σ_i φ·(1 + d/2 − sqd/(2h²))."""
    d = x.shape[-1]
    sq = _sqdist(y, x)
    phi = jnp.exp(-sq / (2.0 * h * h))
    return jnp.sum(phi * (1.0 + d / 2.0 - sq / (2.0 * h * h)), axis=1)


def ref_sdkde_shift(x: jnp.ndarray, h: float, score_h: float | None = None):
    """Debiased samples via the empirical score (matches ops.flash_sdkde_shift)."""
    sh = h if score_h is None else score_h
    s0, s1 = ref_score_stats(x, sh)
    score = (s1 - x.astype(jnp.float32) * s0[:, None]) / (
        sh * sh * s0[:, None]
    )
    return x.astype(jnp.float32) + 0.5 * h * h * score


def ref_selective_scan(xi, dt, b, c, a, h0):
    """Oracle for kernels/selective_scan.py: plain sequential recurrence.

    h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t x_t)·B_t ;  y_t = C_t · h_t.
    Shapes: xi/dt (B,S,D), b/c (B,S,N), a (D,N), h0 (B,D,N).
    Returns (y (B,S,D) f32, h_final (B,D,N) f32).
    """
    xi = xi.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    a = a.astype(jnp.float32)

    def step(h, inputs):
        xi_t, dt_t, b_t, c_t = inputs
        decay = jnp.exp(dt_t[:, :, None] * a[None])        # (B,D,N)
        h = decay * h + (dt_t * xi_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    import jax

    h, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xi.swapaxes(0, 1), dt.swapaxes(0, 1),
         b.swapaxes(0, 1), c.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), h
