"""Flash score kernel: the SD-KDE empirical-score hot spot on the TPU MXU.

Computes, for every training row i, the fused statistics

    S1aug_i = Σ_j φ_ij · [x_j | 1]  ∈ R^{d+1}

i.e. the score-numerator GEMM ``T = Φ X`` and the denominator row-sum
``S0 = Φ·1`` in a single MXU matmul against the ones-augmented train matrix.
φ_ij = exp(-‖x_i - x_j‖² / (2h²)) is never materialized globally: column
tiles of the train set are streamed through VMEM and the (BLOCK_M, d+1)
output block is accumulated in place across the innermost grid dimension —
the TPU-idiomatic replacement for the paper's atomic-add streaming
accumulation (TPU Pallas grids execute sequentially per core, so revisiting
the same output block is race-free and deterministic).

Tile layout (one grid step, all in VMEM):
    x_m    (BLOCK_M, d)      row tile of X
    nrm_m  (BLOCK_M, 1)      precomputed ‖x_i‖²
    xt_n   (d, BLOCK_N)      column tile of Xᵀ  (lane axis = BLOCK_N)
    xaug_n (BLOCK_N, d+1)    column tile of [X | 1]
    nrm_n  (1, BLOCK_N)      precomputed ‖x_j‖²
    out    (BLOCK_M, d+1)    accumulator (f32)

MXU work per step: (BLOCK_M×d)@(d×BLOCK_N) Gram + (BLOCK_M×BLOCK_N)@(BLOCK_N×(d+1)).
VPU work: broadcasted adds + one exp per pair.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(x_m_ref, nrm_m_ref, xt_n_ref, xaug_n_ref, nrm_n_ref,
                  inv2h2_ref, out_ref):
    # Initialize the accumulator on the first column tile of each row block.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Gram tile on the MXU; accumulate in f32 regardless of input dtype.
    g = jnp.dot(x_m_ref[...], xt_n_ref[...],
                preferred_element_type=jnp.float32)
    sq = nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g        # (BM, BN) via VPU
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    # Fused numerator + denominator GEMM against [X | 1].
    out_ref[...] += jnp.dot(phi, xaug_n_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_score_pallas(
    x: jnp.ndarray,        # (n, d)   padded to block_m/block_n multiples
    nrm: jnp.ndarray,      # (n, 1)   f32 squared norms
    xt: jnp.ndarray,       # (d, n)
    xaug: jnp.ndarray,     # (n, d+1) [X | 1]
    inv2h2: jnp.ndarray,   # (1, 1)   1/(2h²), f32
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw kernel launch; returns S1aug (n, d+1) f32.  See ops.flash_score_stats
    for the padded/normalized public wrapper."""
    n, d = x.shape
    assert n % block_m == 0 and n % block_n == 0, (n, block_m, block_n)
    grid = (n // block_m, n // block_n)

    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda m, j: (m, 0)),
            pl.BlockSpec((block_m, 1), lambda m, j: (m, 0)),
            pl.BlockSpec((d, block_n), lambda m, j: (0, j)),
            pl.BlockSpec((block_n, d + 1), lambda m, j: (j, 0)),
            pl.BlockSpec((1, block_n), lambda m, j: (0, j)),
            pl.BlockSpec((1, 1), lambda m, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d + 1), lambda m, j: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d + 1), jnp.float32),
        interpret=interpret,
    )(x, nrm, xt, xaug, jnp.broadcast_to(nrm.reshape(1, -1), (1, n)), inv2h2)
