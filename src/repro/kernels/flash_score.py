"""Flash score kernel: the SD-KDE empirical-score hot spot on the TPU MXU.

Computes, for every training row i, the fused statistics

    S1aug_i = Σ_j φ_ij · [x_j | 1]  ∈ R^{d+1}

i.e. the score-numerator GEMM ``T = Φ X`` and the denominator row-sum
``S0 = Φ·1`` in a single MXU matmul against the ones-augmented train matrix.
φ_ij = exp(-‖x_i - x_j‖² / (2h²)) is never materialized globally: column
tiles of the train set are streamed through VMEM and the (BLOCK_M, d+1)
output block is accumulated in place across the innermost grid dimension —
the TPU-idiomatic replacement for the paper's atomic-add streaming
accumulation (TPU Pallas grids execute sequentially per core, so revisiting
the same output block is race-free and deterministic).

Tile layout (one grid step, all in VMEM):
    x_m    (BLOCK_M, d)      row tile of X
    nrm_m  (BLOCK_M, 1)      precomputed ‖x_i‖²
    xt_n   (d, BLOCK_N)      column tile of Xᵀ  (lane axis = BLOCK_N)
    xaug_n (BLOCK_N, d+1)    column tile of [X | 1]
    nrm_n  (1, BLOCK_N)      precomputed ‖x_j‖²
    out    (BLOCK_M, d+1)    accumulator (f32)

MXU work per step: (BLOCK_M×d)@(d×BLOCK_N) Gram + (BLOCK_M×BLOCK_N)@(BLOCK_N×(d+1)).
VPU work: broadcasted adds + one exp per pair.

Mixed precision (kernels/precision.py): BOTH MXU GEMMs — the Gram and the
φ@[X|1] accumulator — take low-precision operands when the wrapper selects
the bf16 / bf16x2 tiers (the ``*_lo`` planes carry the compensated split).
φ itself is exp output and is split/cast on the fly; norms, ``sq``, exp,
and the accumulator stay f32 at every tier.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.precision import dot_f32, gram_compensated, weighted_accum


def _score_kernel(x_m_ref, nrm_m_ref, xt_n_ref, xaug_n_ref, nrm_n_ref,
                  inv2h2_ref, out_ref):
    # Initialize the accumulator on the first column tile of each row block.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Gram tile on the MXU; accumulate in f32 regardless of input dtype.
    g = dot_f32(x_m_ref[...], xt_n_ref[...])
    sq = jnp.maximum(nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g, 0.0)
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    # Fused numerator + denominator GEMM against [X | 1]; the tier is
    # implied by xaug's dtype (f32 → f32 GEMM, bf16 → φ cast to bf16).
    out_ref[...] += weighted_accum(phi, xaug_n_ref[...])


def _score_kernel_x2(x_hi_ref, x_lo_ref, nrm_m_ref, xt_hi_ref, xt_lo_ref,
                     xaug_hi_ref, xaug_lo_ref, nrm_n_ref, inv2h2_ref,
                     out_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = gram_compensated(x_hi_ref[...], x_lo_ref[...],
                         xt_hi_ref[...], xt_lo_ref[...])
    sq = jnp.maximum(nrm_m_ref[...] + nrm_n_ref[...] - 2.0 * g, 0.0)
    phi = jnp.exp(-sq * inv2h2_ref[0, 0])
    out_ref[...] += weighted_accum(phi, xaug_hi_ref[...], xaug_lo_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def flash_score_pallas(
    x: jnp.ndarray,        # (n, d)   padded to block_m/block_n multiples
    nrm: jnp.ndarray,      # (n, 1)   f32 squared norms
    xt: jnp.ndarray,       # (d, n)
    xaug: jnp.ndarray,     # (n, d+1) [X | 1]
    inv2h2: jnp.ndarray,   # (1, 1)   1/(2h²), f32
    x_lo: jnp.ndarray | None = None,     # (n, d)   bf16 lo plane (bf16x2)
    xt_lo: jnp.ndarray | None = None,    # (d, n)   bf16 lo plane (bf16x2)
    xaug_lo: jnp.ndarray | None = None,  # (n, d+1) bf16 lo plane (bf16x2)
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw kernel launch; returns S1aug (n, d+1) f32.  See ops.flash_score_stats
    for the padded/normalized public wrapper."""
    n, d = x.shape
    assert n % block_m == 0 and n % block_n == 0, (n, block_m, block_n)
    los = (x_lo, xt_lo, xaug_lo)
    assert all(v is None for v in los) or all(v is not None for v in los), \
        "bf16x2 needs all three lo planes"
    grid = (n // block_m, n // block_n)

    row = pl.BlockSpec((block_m, d), lambda m, j: (m, 0))
    nrm_row = pl.BlockSpec((block_m, 1), lambda m, j: (m, 0))
    col = pl.BlockSpec((d, block_n), lambda m, j: (0, j))
    aug = pl.BlockSpec((block_n, d + 1), lambda m, j: (j, 0))
    nrm_col = pl.BlockSpec((1, block_n), lambda m, j: (0, j))
    scalar = pl.BlockSpec((1, 1), lambda m, j: (0, 0))

    nrm_bcast = jnp.broadcast_to(nrm.reshape(1, -1), (1, n))
    if x_lo is None:
        kernel = _score_kernel
        in_specs = [row, nrm_row, col, aug, nrm_col, scalar]
        args = (x, nrm, xt, xaug, nrm_bcast, inv2h2)
    else:
        kernel = _score_kernel_x2
        in_specs = [row, row, nrm_row, col, col, aug, aug, nrm_col, scalar]
        args = (x, x_lo, nrm, xt, xt_lo, xaug, xaug_lo, nrm_bcast, inv2h2)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, d + 1), lambda m, j: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d + 1), jnp.float32),
        interpret=interpret,
    )(*args)
