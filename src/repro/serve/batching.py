"""Micro-batching: coalesce ragged query traffic into jit-stable shapes.

Online KDE traffic is ragged — one request asks for 3 densities, the next
for 700.  Under jit, every distinct batch shape is a fresh compile, so naive
serving turns ragged traffic into a recompilation storm.  This module fixes
that with two pieces:

  * **shape buckets** — pad each batch up to a geometric ladder of sizes
    (multiples of the Pallas ``block_m`` tile / ring size), bounding the
    number of compiled programs per estimator;
  * **an LRU of bucket executables** — the engine's per-(estimator, bucket)
    callables, evicted least-recently-used so a long-lived server with many
    registered datasets keeps a bounded compile cache.

Padding uses the same far-away sentinel as the kernels (``PAD_VALUE``):
padded query rows see kernel weight exactly 0.0 from every real train point,
so their densities are garbage-but-harmless and are sliced off before the
response is split back per request.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Hashable, List, Sequence, Tuple

import jax.numpy as jnp

from repro import obs
from repro.core.kde import PAD_VALUE, pad_rows  # noqa: F401 - PAD_VALUE is
# re-exported for serve users building their own padded batches.


def pad_queries(y: jnp.ndarray, bucket: int) -> jnp.ndarray:
    """Pad a (m, d) query batch up to ``bucket`` rows with sentinel points."""
    if y.shape[0] > bucket:
        raise ValueError(
            f"batch of {y.shape[0]} rows does not fit bucket {bucket}"
        )
    return pad_rows(y, bucket)


def coalesce(
    batches: Sequence[jnp.ndarray],
) -> Tuple[jnp.ndarray, List[int]]:
    """Concatenate per-request query batches into one dispatch.

    Returns the fused (Σm_i, d) array and the per-request row counts used by
    ``split`` to shard the fused result back out.
    """
    if not batches:
        raise ValueError("no query batches to coalesce")
    arrs = [jnp.atleast_2d(jnp.asarray(b, jnp.float32)) for b in batches]
    d = arrs[0].shape[-1]
    for a in arrs:
        if a.shape[-1] != d:
            raise ValueError(f"dimension mismatch: {a.shape[-1]} != {d}")
    sizes = [a.shape[0] for a in arrs]
    return jnp.concatenate(arrs, axis=0), sizes


def split(fused: jnp.ndarray, sizes: Sequence[int]) -> List[jnp.ndarray]:
    """Inverse of ``coalesce`` for the fused density vector."""
    out, off = [], 0
    for s in sizes:
        out.append(fused[off:off + s])
        off += s
    return out


class ShapeBucketCache:
    """LRU cache of compiled per-(estimator, bucket) executables.

    Keys are arbitrary hashables (the engine uses ``(estimator_key,
    bucket_rows)``).  ``hits`` / ``misses`` / ``evictions`` are exposed so
    tests and the throughput benchmark can assert cache behavior on ragged
    traffic.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Callable]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get_or_build(self, key: Hashable, build: Callable[[], Callable]):
        """Return the cached executable for ``key``, building on miss.

        Hits/misses/evictions also feed the process-wide obs counters
        (``serve.bucket_cache.*``), so a recompile storm — e.g.
        layout-epoch churn under streaming — is distinguishable from
        normal traffic in any metrics snapshot, not just on the engine
        instance that happened to own this cache.
        """
        if key in self._entries:
            self.hits += 1
            obs.counter("serve.bucket_cache.hits").inc()
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        obs.counter("serve.bucket_cache.misses").inc()
        fn = build()
        self._entries[key] = fn
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
            obs.counter("serve.bucket_cache.evictions").inc()
        return fn

    def invalidate(self, predicate: Callable[[Hashable], bool]) -> None:
        """Drop entries whose key matches (e.g. after an estimator refit)."""
        for k in [k for k in self._entries if predicate(k)]:
            del self._entries[k]


__all__ = ["pad_queries", "coalesce", "split", "ShapeBucketCache"]
