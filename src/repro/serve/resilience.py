"""Resilient dispatch: replicated shards, deadlines, hedging, degradation.

The ``ServeEngine`` is a single synchronous process: one dead device, one
slow compile, one NaN and the request is gone.  This layer puts a
production dispatch policy in front of it without touching the math:

**Sharding.**  ``register`` runs the expensive fit ONCE on the full set
(for sd-kde, the O(n²·d) debias — sharding *before* debiasing would
change the estimator, since each point's score shift depends on every
other point), then k-means-partitions the fitted points with
``kernels.spatial``: whole clusters go to shards
(``partition_clusters``), so each shard is a self-contained
cluster-aligned tile set with its own ``TileMeta`` — the error
certificate a *missing* shard's contribution is bounded by.  Each of the
S shards is served by R independent ``ServeEngine`` replicas (own
registry, own bucket-executable cache: a poisoned compile cache on one
replica cannot infect its sibling).  Density is linear in per-point
contributions, so the exact answer recombines as
``Σ_s (n_s / n_tot) · dens_s`` for every method (kde / debiased-sdkde /
laplace).

**Dispatch policy**, per shard, inside a per-request deadline:

  * retry with exponential backoff + deterministic jitter, rotating
    across replicas;
  * hedged dispatch — when the p99-informed hedge timer expires before
    the primary answers, a duplicate fires at another replica and the
    first success wins (``distributed/straggler.py``'s duplicate-dispatch
    idiom, promoted to the serve path);
  * a circuit breaker per (shard, replica, bucket-executable) that opens
    after repeated failures (compile storms included — the bucket is part
    of the key) and routes traffic around the broken executable until a
    cooldown probe closes it;
  * NaN guard: a non-finite result is a *failure* (retried), never an
    answer;
  * health: every successful attempt heartbeats a ``fault.Supervisor``
    host (host = shard·R + replica); hosts past the heartbeat timeout are
    fenced through ``restart_plan(fence=True)`` — late zombie beats are
    rejected by the fencing epoch — and the routing table shrinks
    ``elastic.plan_mesh``-style; periodic probes re-admit recovered
    replicas.

**Graceful degradation.**  When every replica of some shard is gone and
the deadline still stands, the surviving shards' partial sum is
renormalized into an estimate whose certified relative-error bound comes
from the missing shards' tile metadata (``spatial.point_mass_bound`` —
the same certified-geometry machinery as ``flash_pruned``): the true
density provably lies in ``[S_live − U⁻, S_live + U] / (n_tot·c)`` with
``U`` the per-query missing-mass bound.  The answer is returned *only*
when the bound clears the configured accuracy target; otherwise the
caller gets a typed ``Degraded`` error.  Under repeated deadline misses
the engine sheds load by downgrading the precision tier along the PR-7
planner's accuracy ladder (``TIER_RTOL``) instead of rejecting.

Every decision emits ``repro.obs`` spans/counters: retries, hedges fired
and won, breaker transitions, fenced/readmitted hosts, shed and degraded
requests.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import fault_injection, obs
from repro.core.bandwidth import gaussian_norm_const
from repro.distributed import elastic
from repro.distributed.fault import Supervisor
from repro.fault_injection import ChaosConfig, FaultInjector, InjectedFailure
from repro.kernels import spatial
from repro.plan.planner import TIER_ORDER, TIER_RTOL
from repro.serve import cascade
from repro.serve.api import RFF_TIER, Answer, QueryRequest, warn_legacy
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.errors import (BadRequest, DeadlineExceeded, Degraded,
                                Overloaded, UnknownKey)
from repro.serve.registry import EstimatorRegistry
from repro.serve.stats import LatencyRecorder
from repro.obs.metrics import Histogram


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Dispatch policy of the resilient layer (the math lives in
    ``ServeConfig``; this only decides *where and when* to run it)."""

    shards: int = 2              # S self-contained cluster groups
    replicas: int = 2            # R independent engines per shard
    deadline_ms: float = 5000.0  # default per-request deadline
    max_retries: int = 3         # per shard, within the deadline
    backoff_ms: float = 5.0
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.5  # ± fraction of the backoff step
    hedge_after_ms: Optional[float] = None   # None → p99-informed
    hedge_p99_factor: float = 2.0
    hedge_min_ms: float = 25.0
    breaker_threshold: int = 3   # consecutive failures before OPEN
    breaker_cooldown_s: float = 1.0
    heartbeat_timeout_s: float = 2.0
    probe_every: int = 16        # requests between fenced-host probes
    allow_degraded: bool = True
    degraded_accuracy: float = 0.5   # certified rel-err budget, degraded
    shed_after_misses: int = 3   # deadline misses before tier shedding
    shed_requests: int = 16      # how long a shed episode lasts
    shed_accuracy: float = 5e-2  # ladder budget while shedding (→ bf16)
    meta_block: int = 128        # certificate tile rows per shard
    seed: int = 0

    def __post_init__(self):
        if self.shards < 1 or self.replicas < 1:
            raise ValueError(
                f"need shards >= 1 and replicas >= 1, got "
                f"{self.shards}x{self.replicas}"
            )
        for name in ("deadline_ms", "backoff_ms", "hedge_min_ms",
                     "breaker_cooldown_s", "heartbeat_timeout_s",
                     "degraded_accuracy", "shed_accuracy", "meta_block"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        if self.max_retries < 0 or self.breaker_threshold < 1:
            raise ValueError("max_retries >= 0, breaker_threshold >= 1")


# The resilient layer returns the same typed Answer as everything else
# (serve/api.py) — its ``densities``/``precision`` properties keep the
# old field names alive; the old class name stays as an alias.
ResilientAnswer = Answer


class CircuitBreaker:
    """CLOSED → (threshold failures) → OPEN → (cooldown) → HALF_OPEN →
    one probe → CLOSED or back to OPEN."""

    def __init__(self, threshold: int, cooldown_s: float,
                 clock: Callable[[], float]):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0
        self._lock = threading.Lock()

    def allow(self) -> bool:
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                if self.clock() - self.opened_at >= self.cooldown_s:
                    self._transition("half_open")
                    return True          # this caller is the probe
                return False
            return False                 # half_open: probe already out

    def record_success(self) -> None:
        with self._lock:
            self.failures = 0
            if self.state != "closed":
                self._transition("closed")

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            if self.state == "half_open" or (
                    self.state == "closed"
                    and self.failures >= self.threshold):
                self._transition("open")
                self.opened_at = self.clock()

    def _transition(self, to: str) -> None:
        self.state = to
        obs.counter("resilience.breaker_transitions",
                    "circuit breaker state changes",
                    labels={"to": to}).inc()


class _ReplicaBusy(RuntimeError):
    """A replica engine was still busy with an abandoned dispatch."""


@dataclasses.dataclass
class _ShardTable:
    """One registered dataset, sharded and replicated."""

    key: str
    h: float
    d: int
    n_tot: int
    kind: str                            # bound kind: kde | laplace
    norm_c: float                        # (2π)^{d/2}·h^d per-point normalizer
    shard_n: List[int]                   # real points per shard
    shard_meta: List[spatial.TileMeta]   # per-shard certificate geometry
    engines: List[List[ServeEngine]]     # [shard][replica]
    skeys: List[str]
    # full-set RFF fast tier (lazy; the pre-shard cascade serves from it
    # and only escalated rows fan out to the shards).  Holding the fit
    # registry keeps the debiased full set alive for the lazy fit.
    rff_prep: object = None
    rff_reg: object = None

    @property
    def n_shards(self) -> int:
        return len(self.engines)

    @property
    def n_replicas(self) -> int:
        return len(self.engines[0])


class ResilientEngine:
    """Replicated-shard front end over ``ServeEngine`` (see module doc)."""

    def __init__(
        self,
        config: ServeConfig | None = None,
        resilience: ResilienceConfig | None = None,
        *,
        chaos: ChaosConfig | None = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        config = config or ServeConfig()
        if config.backend == "ring" or config.stream:
            raise ValueError(
                "ResilientEngine replicates static jnp/pallas engines; "
                "ring sharding and streaming estimators are their own "
                "distribution stories"
            )
        self.config = config
        self.rcfg = resilience or ResilienceConfig()
        self._clock = clock
        self._sleep = sleep
        self.injector: Optional[FaultInjector] = (
            fault_injection.install(FaultInjector(chaos))
            if chaos is not None else None
        )
        self._tables: Dict[str, _ShardTable] = {}
        self.supervisor: Optional[Supervisor] = None
        self._pool = ThreadPoolExecutor(
            max_workers=max(4, 2 * self.rcfg.shards),
            thread_name_prefix="resilient-serve",
        )
        self._breakers: Dict[tuple, CircuitBreaker] = {}
        self._eng_locks: Dict[tuple, threading.Lock] = {}
        self._requests = 0
        self._miss_streak = 0
        self._shed_left = 0
        self.latency = LatencyRecorder()
        self._attempt_hist = Histogram("resilience.attempt_s",
                                       lo=1e-5, hi=1e3)
        self.stats: Dict[str, int] = {
            k: 0 for k in ("requests", "dropped", "degraded", "shed",
                           "retries", "hedges", "hedge_wins", "probes",
                           "readmits", "fenced", "last_resort")
        }
        self.service_plan: Optional[elastic.MeshPlan] = None
        self._lock = threading.Lock()

    # -- fit path ---------------------------------------------------------

    def register(self, key: str, x: jnp.ndarray,
                 h: Optional[float] = None, *,
                 prewarm: bool = True) -> _ShardTable:
        """Fit once on the full set, then shard + replicate (see module
        doc for why the debias must happen before the split)."""
        cfg = self.config
        # the quadratic debias runs on the jnp reference path — it is
        # fit-time work, and its output feeds every shard backend equally
        fit_reg = EstimatorRegistry(dataclasses.replace(
            cfg, backend="jnp", stream=False, plan="off"))
        prep = fit_reg.fit(key, x, h)
        points = np.asarray(prep.points, np.float32)
        n, d = points.shape

        index = spatial.build_index(points, seed=self.rcfg.seed)
        labels = np.asarray(index.labels)
        n_clusters = int(labels.max()) + 1
        S = min(self.rcfg.shards, n_clusters)
        R = self.rcfg.replicas
        shard_of = spatial.partition_clusters(labels, S)
        point_shard = shard_of[labels]

        # each shard serves the ALREADY-debiased slice, so sdkde becomes a
        # plain kde over its shard — recombination is exact by linearity
        shard_cfg = dataclasses.replace(
            cfg, method="kde" if cfg.method == "sdkde" else cfg.method,
            stream=False, plan="off",
        )
        kind = "laplace" if cfg.method == "laplace" else "kde"

        engines: List[List[ServeEngine]] = []
        shard_n: List[int] = []
        shard_meta: List[spatial.TileMeta] = []
        skeys: List[str] = []
        block = self.rcfg.meta_block
        for s in range(S):
            mask = point_shard == s
            pts = points[mask]
            shard_n.append(int(pts.shape[0]))
            skeys.append(f"{key}::s{s}")
            # certificate geometry: the shard's own cluster-aligned tile
            # set (local relabel keeps the layout dense)
            local = np.unique(labels[mask], return_inverse=True)[1]
            layout = spatial.cluster_layout(jnp.asarray(pts), local, block)
            shard_meta.append(spatial.tile_metadata(
                layout.points, layout.real, block=block))
            row = []
            for r in range(R):
                eng = ServeEngine(shard_cfg)
                eng.register(skeys[s], jnp.asarray(pts), h=prep.h,
                             prewarm=False)
                row.append(eng)
            engines.append(row)

        table = _ShardTable(
            key=key, h=prep.h, d=d, n_tot=n, kind=kind,
            norm_c=gaussian_norm_const(d, 1.0) * prep.h ** d,
            shard_n=shard_n, shard_meta=shard_meta, engines=engines,
            skeys=skeys,
            # the RFF tier is fit on the FULL debiased set (the registry
            # attached it during fit_reg.fit) — the cascade answers whole
            # queries before any shard is touched, so it must see the
            # same estimator the recombined shards serve
            rff_prep=prep, rff_reg=fit_reg,
        )
        self._tables[key] = table
        if self.supervisor is None:
            self.supervisor = Supervisor(
                S * R, timeout=self.rcfg.heartbeat_timeout_s,
                clock=self._clock,
            )
        if prewarm:
            for s in range(S):
                for r in range(R):
                    engines[s][r].prewarm(skeys[s])
        # registration is proof of life: without an initial beat, a slow
        # prewarm (compile storm) outlives the heartbeat timeout and the
        # first query finds every host already fenced
        for hid in range(S * R):
            self.supervisor.beat(hid, 0)
        obs.counter("resilience.registered",
                    "datasets sharded for resilient serving").inc()
        return table

    # -- query path -------------------------------------------------------

    def query(self, request, y: Optional[jnp.ndarray] = None, *,
              precision: Optional[str] = None,
              deadline_ms: Optional[float] = None,
              allow_degraded: Optional[bool] = None) -> Answer:
        """Densities for one request under the full dispatch policy.

        Typed API: pass a :class:`~repro.serve.api.QueryRequest` —
        ``deadline_s`` is relative seconds, ``accuracy_target`` engages
        the pre-shard RFF cascade (whole rows answered from the full-set
        fast tier never touch a shard; only escalated rows fan out), and
        ``allow_degraded`` overrides the engine default.  Returns an
        :class:`~repro.serve.api.Answer`; degraded answers compose per
        row — fast-tier rows keep their RFF band, escalated rows carry
        the degraded certificate.

        Legacy API (deprecated): ``query(key, y, precision=,
        deadline_ms=, allow_degraded=)`` — exact shard dispatch only,
        as before the typed API existed (the returned Answer's
        ``densities``/``precision`` properties keep old field names
        alive).
        """
        if isinstance(request, QueryRequest):
            if y is not None or precision is not None \
                    or deadline_ms is not None or allow_degraded is not None:
                raise BadRequest(
                    "pass either a QueryRequest or the legacy "
                    "(key, y, ...) arguments, not both")
            return self._query_request(request, legacy=False)
        warn_legacy("ResilientEngine.query(key, y, ...)",
                    "ResilientEngine.query(QueryRequest(...)) -> Answer")
        req = QueryRequest(
            key=request, points=y, precision=precision,
            deadline_s=(deadline_ms / 1e3 if deadline_ms is not None
                        else None),
            allow_degraded=allow_degraded)
        return self._query_request(req, legacy=True)

    def _query_request(self, req: QueryRequest, *, legacy: bool) -> Answer:
        table = self._tables.get(req.key)
        if table is None:
            raise UnknownKey(
                f"estimator {req.key!r} not registered with the resilient "
                f"engine (have {list(self._tables)})"
            )
        y = jnp.atleast_2d(jnp.asarray(req.points, jnp.float32))
        if y.shape[0] == 0 or y.shape[-1] != table.d:
            raise BadRequest(
                f"query batch {tuple(y.shape)} does not match registered "
                f"dimensionality d={table.d} (or is empty)"
            )
        allow_degraded = (req.allow_degraded
                          if req.allow_degraded is not None
                          else self.rcfg.allow_degraded)
        if self.injector is not None:
            self.injector.begin_request()
        with self._lock:
            self._requests += 1
            req_no = self._requests
            shed = self._shed_left > 0
            if shed:
                self._shed_left -= 1
        pin = req.precision
        tier = pin or self.config.precision
        if shed and pin is None:
            tier = _cheapest_tier(self.rcfg.shed_accuracy)
            self.stats["shed"] += 1
            obs.counter("resilience.shed",
                        "requests served at a downgraded tier").inc()
        t0 = self._clock()
        deadline = t0 + (req.deadline_s if req.deadline_s is not None
                         else self.rcfg.deadline_ms / 1e3)
        self._refresh_health(table)
        self._maybe_probe(table, req_no)

        target = None
        if not legacy:
            target = (req.accuracy_target
                      if req.accuracy_target is not None
                      else self.config.accuracy_target)
        m = int(y.shape[0])
        pinned = tier == RFF_TIER
        p = band = None
        esc = np.ones(m, bool)
        if pinned or (not legacy and pin is None and target is not None):
            serving = self._rff_serving(table)
            if serving is None and pinned:
                raise BadRequest(
                    f"precision='rff' pinned but the RFF tier is "
                    f"unavailable for method={self.config.method!r} "
                    f"(rff={self.config.rff!r})")
            if serving is not None:
                bucket = table.engines[0][0].config.bucket_for(m)
                p, band = cascade.evaluate(self.config, serving, y, bucket)
                esc = np.zeros(m, bool) if pinned else band > target
                obs.counter("serve.cascade_hits",
                            "query rows answered at the RFF fast "
                            "tier").inc(int(m - esc.sum()))
                if esc.any():
                    obs.counter("serve.cascade_escalations",
                                "query rows escalated to the exact "
                                "tier").inc(int(esc.sum()))
        exact_tier = "f32" if tier == RFF_TIER else tier

        counters = {"retries": 0, "hedges": 0, "hedge_wins": 0}
        sub = None
        sp = obs.span("resilience.request", key=req.key, rows=m,
                      tier=tier, shed=shed)
        with sp:
            if p is not None:
                sp.set(cascade=True, hits=int(m - esc.sum()))
            if esc.any():
                idx = np.flatnonzero(esc)
                y_esc = (y if esc.all()
                         else jnp.asarray(np.asarray(y)[idx]))
                sub = self._dispatch_shards(table, y_esc, exact_tier,
                                            deadline, t0, shed,
                                            allow_degraded, counters, sp)
            else:
                # the whole batch resolved at the fast tier: no shard was
                # touched, but the request still counts as served
                self.stats["requests"] += 1
                obs.counter("resilience.requests",
                            "resilient requests").inc()
                self._note_done(t0, m, deadline_hit=False)

        if p is None:
            sub.latency_s = self._clock() - t0
            return sub
        value = p.copy()
        bounds = band.copy()
        hits = int(m - esc.sum())
        if sub is not None:
            idx = np.flatnonzero(esc)
            value[idx] = np.asarray(sub.value, np.float64)
            bounds[idx] = (sub.rel_err_bounds
                           if sub.degraded and sub.rel_err_bounds is not None
                           else cascade.exact_bound(exact_tier,
                                                    self.config.prune))
        path = (RFF_TIER,) if sub is None else (RFF_TIER, exact_tier)
        return Answer(
            value=jnp.asarray(value, jnp.float32), key=req.key,
            tier=path[-1], path=path,
            rel_err_bound=float(bounds.max()) if m else 0.0,
            rel_err_bounds=bounds, rff_hits=hits,
            escalated=int(esc.sum()),
            degraded=bool(sub.degraded) if sub is not None else False,
            shed=shed,
            live_shards=sub.live_shards if sub is not None else (),
            missing_shards=sub.missing_shards if sub is not None else (),
            retries=counters["retries"], hedges=counters["hedges"],
            hedge_wins=counters["hedge_wins"],
            latency_s=self._clock() - t0,
        )

    def _rff_serving(self, table: _ShardTable):
        """The full-set RFF serving tensors, or None when the tier is off
        or unsupported (lazy fit happens inside the registry)."""
        if table.rff_prep is None or table.rff_prep.rff is None:
            return None
        return table.rff_reg.rff_serving(table.rff_prep)

    def _dispatch_shards(self, table: _ShardTable, y, tier: str,
                         deadline: float, t0: float, shed: bool,
                         allow_degraded: bool, counters, sp) -> Answer:
        """Fan the (sub)batch out to every shard under the dispatch
        policy; recombine, or certify a degraded partial answer.  Raises
        the typed errors when neither is possible."""
        m = int(y.shape[0])
        results: List[Optional[jnp.ndarray]] = []
        for s in range(table.n_shards):
            results.append(
                self._shard_query(table, s, y, deadline, tier, counters)
            )
        missing = tuple(s for s, r in enumerate(results) if r is None)
        live = tuple(s for s, r in enumerate(results) if r is not None)
        sp.set(missing=len(missing), retries=counters["retries"],
               hedges=counters["hedges"])
        self.stats["requests"] += 1
        self.stats["retries"] += counters["retries"]
        self.stats["hedges"] += counters["hedges"]
        self.stats["hedge_wins"] += counters["hedge_wins"]
        obs.counter("resilience.requests", "resilient requests").inc()
        if counters["retries"]:
            obs.counter("resilience.retries",
                        "shard dispatch retries").inc(counters["retries"])

        if not missing:
            dens = sum(
                (table.shard_n[s] / table.n_tot) * results[s]
                for s in live
            )
            self._note_done(t0, m, deadline_hit=False)
            b = cascade.exact_bound(tier, self.config.prune)
            return Answer(
                value=dens, key=table.key, tier=tier, path=(tier,),
                rel_err_bound=b, rel_err_bounds=np.full(m, b),
                shed=shed, live_shards=live,
                latency_s=self._clock() - t0, **counters,
            )

        if live and allow_degraded:
            ans = self._degraded_answer(table, y, results, live,
                                        missing, tier, shed, counters)
            ans.latency_s = self._clock() - t0
            sp.set(degraded=True, rel_err_bound=ans.rel_err_bound)
            if ans.rel_err_bound <= self.rcfg.degraded_accuracy:
                self.stats["degraded"] += 1
                obs.counter("resilience.degraded",
                            "certified partial-shard answers").inc()
                obs.histogram("resilience.degraded_bound",
                              "certified rel-err bound of degraded "
                              "answers", lo=1e-6, hi=1e2).observe(
                    max(ans.rel_err_bound, 1e-6))
                self._note_done(t0, m, deadline_hit=False)
                return ans
            self._drop(table.key, "degraded_uncertifiable")
            raise Degraded(
                f"partial answer from shards {live} has certified "
                f"rel-err bound {ans.rel_err_bound:.3g} > target "
                f"{self.rcfg.degraded_accuracy:.3g}",
                bound=ans.rel_err_bound,
                target=self.rcfg.degraded_accuracy,
            )

        timed_out = self._clock() >= deadline
        self._note_done(t0, m, deadline_hit=timed_out)
        self._drop(table.key, "deadline" if timed_out else "no_live_shards")
        if timed_out:
            raise DeadlineExceeded(
                f"deadline expired with shards {missing} unanswered "
                f"(retries={counters['retries']})"
            )
        raise Overloaded(
            f"no live replica for shards {missing} "
            f"(fenced={self.supervisor.fenced()})"
        )

    # -- per-shard dispatch ----------------------------------------------

    def _shard_query(self, table: _ShardTable, s: int, y, deadline: float,
                     tier: str, counters) -> Optional[jnp.ndarray]:
        rcfg = self.rcfg
        bucket = table.engines[s][0].config.bucket_for(int(y.shape[0]))
        backoff = rcfg.backoff_ms / 1e3
        for attempt in range(rcfg.max_retries + 1):
            if self._clock() >= deadline:
                return None
            cands = self._candidates(table, s, bucket, attempt)
            if not cands:
                # every replica is fenced (or breaker-open).  Fencing is a
                # health *inference* from missed heartbeats — a stalled
                # supervisor clock fences replicas that are perfectly
                # alive — and a degraded answer is strictly worse than an
                # exact one, so probe the fenced replicas as a last
                # resort before giving the shard up for missing.
                cands = self._candidates(table, s, bucket, attempt,
                                         include_fenced=True)
                if cands:
                    self.stats["last_resort"] += 1
                    obs.counter(
                        "resilience.last_resort",
                        "dispatches to fenced replicas after every live "
                        "candidate was exhausted").inc()
            if not cands:
                return None
            dens = self._race(table, s, cands, y, deadline, tier, counters)
            if dens is not None:
                return dens
            counters["retries"] += 1
            if attempt < rcfg.max_retries:
                # deterministic jitter: a thundering herd of retries must
                # not re-synchronize, but a replayed soak must
                u = float(np.random.default_rng(
                    (rcfg.seed, self._requests, s, attempt)).random())
                step = backoff * (1.0 + rcfg.backoff_jitter * (2 * u - 1))
                self._sleep(min(step, max(deadline - self._clock(), 0.0)))
                backoff *= rcfg.backoff_factor
        return None

    def _candidates(self, table: _ShardTable, s: int, bucket: int,
                    attempt: int, *,
                    include_fenced: bool = False) -> List[int]:
        """Live, breaker-admitted replicas of shard ``s``, primary first.

        With ``include_fenced`` the fenced replicas are offered too
        (still breaker-gated) — the last-resort pass when the shard has
        no live candidate at all.
        """
        R = table.n_replicas
        sup = self.supervisor
        # rotate the primary per REQUEST, not per call: a per-call counter
        # advances by n_shards each request, which for R | n_shards aliases
        # to a fixed primary per shard (replica 0 of shard 0 would never
        # see traffic)
        order = [(r + self._requests + s + attempt) % R for r in range(R)]
        out = []
        for r in order:
            host = sup.hosts[s * R + r]
            if host.fenced and not include_fenced:
                continue
            if self._breaker(table.key, s, r, bucket).allow():
                out.append(r)
        return out

    def _race(self, table, s: int, cands: List[int], y, deadline: float,
              tier: str, counters) -> Optional[jnp.ndarray]:
        """One hedged round: primary, then a duplicate when the hedge
        timer expires; first finite success wins."""
        bucket = table.engines[s][0].config.bucket_for(int(y.shape[0]))
        futures = {}
        primary = cands[0]
        futures[self._pool.submit(
            self._attempt, table, s, primary, y, tier, deadline)] = primary
        if len(cands) > 1:
            timer = min(self._hedge_timer(),
                        max(deadline - self._clock(), 0.0))
            done, _ = wait(list(futures), timeout=timer)
            if not done:
                counters["hedges"] += 1
                obs.counter("resilience.hedges",
                            "hedged duplicate dispatches fired").inc()
                futures[self._pool.submit(
                    self._attempt, table, s, cands[1], y, tier, deadline,
                )] = cands[1]
        remaining = set(futures)
        while remaining:
            budget = deadline - self._clock()
            if budget <= 0:
                break
            done, _ = wait(remaining, timeout=budget,
                           return_when=FIRST_COMPLETED)
            if not done:
                break
            for f in done:
                remaining.discard(f)
                r = futures[f]
                br = self._breaker(table.key, s, r, bucket)
                err = f.exception()
                if err is not None:
                    if not isinstance(err, (InjectedFailure, _ReplicaBusy)):
                        self._abandon(futures, remaining, table, s, bucket)
                        raise err        # a real bug is not chaos
                    br.record_failure()
                    obs.counter(
                        "resilience.attempt_failures",
                        "failed shard dispatch attempts",
                        labels={"kind": getattr(err, "kind", "busy")},
                    ).inc()
                    continue
                t_attempt, dens = f.result()
                if not np.isfinite(np.asarray(dens)).all():
                    br.record_failure()
                    obs.counter("resilience.attempt_failures",
                                "failed shard dispatch attempts",
                                labels={"kind": "nan"}).inc()
                    continue
                br.record_success()
                self.supervisor.beat(s * table.n_replicas + r,
                                     self._requests)
                self._attempt_hist.observe(t_attempt)
                if r != cands[0]:
                    counters["hedge_wins"] += 1
                    obs.counter("resilience.hedge_wins",
                                "hedged duplicates that answered "
                                "first").inc()
                self._abandon(futures, remaining, table, s, bucket)
                return dens
        self._abandon(futures, remaining, table, s, bucket)
        return None

    def _abandon(self, futures, remaining, table, s: int, bucket) -> None:
        """Liveness bookkeeping for futures a race leaves behind: a lost
        hedge that still completes successfully proves its replica alive
        (beat + breaker close) — without this, replicas that keep losing
        races decay into fenced state while perfectly healthy."""
        for f in remaining:
            r = futures[f]
            f.add_done_callback(
                lambda fut, r=r: self._absorb(table, s, r, bucket, fut))

    def _absorb(self, table, s: int, r: int, bucket, f) -> None:
        err = f.exception()
        br = self._breaker(table.key, s, r, bucket)
        if err is not None:
            if isinstance(err, (InjectedFailure, _ReplicaBusy)):
                br.record_failure()
            else:
                # callbacks cannot re-raise; make real bugs on abandoned
                # attempts visible instead of silently swallowed
                obs.counter("resilience.abandoned_errors",
                            "non-chaos exceptions on abandoned attempts",
                            labels={"type": type(err).__name__}).inc()
            return
        t_attempt, dens = f.result()
        if np.isfinite(np.asarray(dens)).all():
            br.record_success()
            self.supervisor.beat(s * table.n_replicas + r, self._requests)
            self._attempt_hist.observe(t_attempt)

    def _attempt(self, table, s: int, r: int, y, tier: str,
                 deadline: float):
        """One dispatch on replica engine (s, r) under injection scope.

        The per-engine lock serializes against abandoned earlier attempts
        (ServeEngine is not reentrant); failing fast as busy is better
        than silently corrupting a bucket cache.
        """
        lock = self._eng_lock(table.key, s, r)
        budget = max(deadline - self._clock(), 0.0)
        if not lock.acquire(timeout=budget if budget > 0 else 0.001):
            raise _ReplicaBusy(f"replica ({s},{r}) busy past deadline")
        try:
            t0 = self._clock()
            ctx = (self.injector.scope(s, r) if self.injector is not None
                   else _null_ctx())
            with ctx:
                dens = table.engines[s][r].query(QueryRequest(
                    key=table.skeys[s], points=y, precision=tier)).value
            return self._clock() - t0, dens
        finally:
            lock.release()

    def _hedge_timer(self) -> float:
        rcfg = self.rcfg
        if rcfg.hedge_after_ms is not None:
            return rcfg.hedge_after_ms / 1e3
        if self._attempt_hist.count >= 16:
            return max(rcfg.hedge_min_ms / 1e3,
                       rcfg.hedge_p99_factor
                       * self._attempt_hist.quantile(0.99))
        return rcfg.hedge_min_ms / 1e3

    # -- degradation ------------------------------------------------------

    def _degraded_answer(self, table, y, results, live, missing, tier,
                         shed, counters) -> ResilientAnswer:
        """Renormalized partial sum + certified relative-error bound.

        Let c = (2π)^{d/2}h^d, S = Σ_live n_s·dens_s·c the live
        unnormalized mass and U(y) the certified upper bound on what the
        missing shards could have added (``spatial.point_mass_bound`` over
        their tile metadata; two-sided for laplace, one-sided ≥0 for
        kde).  The true density lies in [lo, hi] = [S − U⁻, S + U] /
        (n_tot·c); the returned estimate is f̂ = S / (n_live·c) and its
        relative error against ANY f in [lo, hi] is maximized at an
        endpoint — that maximum is the certified bound (∞ when lo ≤ 0:
        an uncertifiable query)."""
        n_live = sum(table.shard_n[s] for s in live)
        sums_live = sum(
            float(table.shard_n[s]) * np.asarray(results[s], np.float64)
            for s in live
        )                                        # Σ n_s·dens_s  (per query)
        f_hat = sums_live / n_live
        inv2h2 = jnp.float32(1.0 / (2.0 * table.h * table.h))
        u = np.zeros_like(f_hat)
        for s in missing:
            u += np.asarray(spatial.point_mass_bound(
                y, table.shard_meta[s], inv2h2, kind=table.kind,
            ), np.float64)
        u /= table.norm_c                        # same units as n·dens
        u_neg = u if table.kind == "laplace" else 0.0
        lo = (sums_live - u_neg) / table.n_tot
        hi = (sums_live + u) / table.n_tot
        with np.errstate(divide="ignore", invalid="ignore"):
            rel = np.maximum(np.abs(f_hat - lo) / lo,
                             np.abs(f_hat - hi) / hi)
        rel = np.where(lo > 0, rel, np.inf)
        dens = jnp.asarray(f_hat, jnp.float32)
        return Answer(
            value=dens, key=table.key, degraded=True, shed=shed,
            tier=tier, path=(tier,),
            rel_err_bound=float(np.max(rel)) if rel.size else 0.0,
            rel_err_bounds=rel, live_shards=live, missing_shards=missing,
            **counters,
        )

    # -- health -----------------------------------------------------------

    def _refresh_health(self, table) -> None:
        sup = self.supervisor
        before = set(sup.fenced())
        plan = sup.restart_plan(fence=True)
        if plan is None:
            return
        newly = [h for h in plan["dead"] if h not in before]
        if not newly:
            return
        self.stats["fenced"] += len(newly)
        obs.counter("resilience.fenced",
                    "replica hosts fenced after missed heartbeats").inc(
            len(newly))
        n_live = len(sup.hosts) - len(sup.fenced())
        live_shards = {
            s for s in range(table.n_shards)
            for r in range(table.n_replicas)
            if not sup.hosts[s * table.n_replicas + r].fenced
        }
        # the routing table shrinks the same way an elastic mesh would:
        # surviving hosts re-planned as (data=replica, model=shard)
        self.service_plan = elastic.plan_mesh(
            max(n_live, 1), model_parallel=max(len(live_shards), 1))
        obs.gauge("resilience.live_hosts",
                  "replica hosts currently serving").set(n_live)

    def _maybe_probe(self, table, req: int) -> None:
        """Every ``probe_every`` requests, health-probe one fenced host;
        success re-admits it (supervisor epoch bump + breaker reset)."""
        if req % self.rcfg.probe_every:
            return
        fenced = self.supervisor.fenced()
        if not fenced:
            return
        hid = fenced[(req // self.rcfg.probe_every) % len(fenced)]
        R = table.n_replicas
        s, r = divmod(hid, R)
        if s >= table.n_shards:
            return
        self.stats["probes"] += 1
        obs.counter("resilience.probes", "fenced-host health probes").inc()
        probe = jnp.zeros((1, table.d), jnp.float32)
        try:
            _, dens = self._attempt(table, s, r, probe,
                                    self.config.exact_precision,
                                    self._clock() + 1.0)
            if not np.isfinite(np.asarray(dens)).all():
                return
        except (InjectedFailure, _ReplicaBusy):
            return
        self.supervisor.readmit(hid)
        for bk, br in list(self._breakers.items()):
            if bk[:3] == (table.key, s, r):
                br.record_success()
        self.stats["readmits"] += 1
        obs.counter("resilience.readmits",
                    "fenced hosts re-admitted after a probe").inc()

    # -- bookkeeping ------------------------------------------------------

    def _note_done(self, t0: float, rows: int, *, deadline_hit: bool):
        self.latency.record(self._clock() - t0, rows, 1)
        with self._lock:
            if deadline_hit:
                self._miss_streak += 1
                if self._miss_streak >= self.rcfg.shed_after_misses \
                        and self._shed_left == 0:
                    self._shed_left = self.rcfg.shed_requests
                    self._miss_streak = 0
                    obs.counter("resilience.shed_episodes",
                                "tier-downgrade episodes entered").inc()
            else:
                self._miss_streak = 0

    def _drop(self, key: str, reason: str) -> None:
        self.stats["dropped"] += 1
        obs.counter("resilience.dropped", "requests that got no answer",
                    labels={"reason": reason}).inc()

    def _breaker(self, key, s, r, bucket) -> CircuitBreaker:
        bk = (key, s, r, bucket)
        with self._lock:
            if bk not in self._breakers:
                self._breakers[bk] = CircuitBreaker(
                    self.rcfg.breaker_threshold,
                    self.rcfg.breaker_cooldown_s, self._clock)
            return self._breakers[bk]

    def _eng_lock(self, key, s, r) -> threading.Lock:
        lk = (key, s, r)
        with self._lock:
            if lk not in self._eng_locks:
                self._eng_locks[lk] = threading.Lock()
            return self._eng_locks[lk]

    # -- telemetry / lifecycle -------------------------------------------

    def breaker_states(self) -> Dict[str, str]:
        return {f"{k[0]}/s{k[1]}r{k[2]}b{k[3]}": br.state
                for k, br in self._breakers.items()}

    def metrics(self) -> dict:
        out = {
            "latency": self.latency.summary().as_dict(),
            "stats": dict(self.stats),
            "breakers": self.breaker_states(),
            "fenced": self.supervisor.fenced() if self.supervisor else [],
            "rejected_beats": (self.supervisor.rejected_beats
                               if self.supervisor else 0),
            "service_plan": (dataclasses.asdict(self.service_plan)
                             if self.service_plan else None),
            "registry": obs.metrics_snapshot(),
        }
        if self.injector is not None:
            out["chaos"] = self.injector.snapshot()
        return out

    def close(self) -> None:
        self._pool.shutdown(wait=True, cancel_futures=True)
        if self.injector is not None and fault_injection.active() \
                is self.injector:
            fault_injection.uninstall()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _cheapest_tier(accuracy: float) -> str:
    """Cheapest precision tier whose rtol clears ``accuracy`` — the
    planner's accuracy ladder, reused for load-shed downgrades."""
    admissible = [t for t in TIER_ORDER if TIER_RTOL[t] <= accuracy]
    return admissible[-1] if admissible else TIER_ORDER[0]


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


__all__ = ["ResilienceConfig", "ResilientAnswer", "ResilientEngine",
           "CircuitBreaker"]
