"""Serving telemetry: per-request latency percentiles and throughput.

The recorder is backed by an ``obs.Histogram`` — fixed log-spaced buckets
from 10µs to 1000s — so a long-lived server's telemetry state is bounded
regardless of request count (the seed kept an ever-growing sample list).
Percentiles are therefore bucket estimates: exact for 0/1 samples,
within one bucket-edge ratio (10^(1/6) ≈ 1.47×) otherwise.

Summaries are JSON-safe by construction: an empty recorder reports zeros,
never ``NaN`` (bare NaN is invalid JSON and breaks downstream parsers).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict

from repro.obs.metrics import Histogram

#: Latency histogram range: 10µs .. 1000s, 6 buckets per decade.
LATENCY_LO_S = 1e-5
LATENCY_HI_S = 1e3


@dataclasses.dataclass
class LatencySummary:
    count: int
    queries: int
    qps: float
    p50_ms: float
    p99_ms: float
    mean_ms: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class LatencyRecorder:
    """Accumulates (seconds, n_queries) samples; summarizes on demand.

    A coalesced dispatch records one sample per *request* it served (each
    request in the fused batch observed the full dispatch latency — that
    is what the client sees); the histogram's weighted ``observe`` folds
    all of them in O(log buckets), not O(requests).
    """

    def __init__(self):
        # A private (unregistered) histogram: engines reset their recorder
        # freely without zeroing the process-wide obs registry.
        self._hist = Histogram("serve.latency_s",
                               lo=LATENCY_LO_S, hi=LATENCY_HI_S)
        self._lock = threading.Lock()
        self._queries = 0

    def record(self, seconds: float, n_queries: int, n_requests: int = 1):
        self._hist.observe(seconds, k=n_requests)
        with self._lock:
            self._queries += n_queries

    def reset(self) -> None:
        self._hist.reset()
        with self._lock:
            self._queries = 0

    def summary(self) -> LatencySummary:
        h = self._hist
        n = h.count
        busy_s = h.sum
        return LatencySummary(
            count=n,
            queries=self._queries,
            qps=self._queries / busy_s if busy_s > 0 else 0.0,
            p50_ms=1e3 * h.quantile(0.50),
            p99_ms=1e3 * h.quantile(0.99),
            mean_ms=1e3 * h.mean,
        )

    def histogram_snapshot(self) -> dict:
        """The underlying bounded histogram (for ``ServeEngine.metrics``)."""
        return self._hist.snapshot()


__all__ = ["LatencyRecorder", "LatencySummary"]
