"""Serving telemetry: per-request latency percentiles and throughput."""

from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class LatencySummary:
    count: int
    queries: int
    qps: float
    p50_ms: float
    p99_ms: float
    mean_ms: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class LatencyRecorder:
    """Accumulates (seconds, n_queries) samples; summarizes on demand.

    A coalesced dispatch records one sample per *request* it served (each
    request in the fused batch observed the full dispatch latency — that is
    what the client sees).
    """

    def __init__(self):
        self._lat_s: List[float] = []
        self._queries = 0
        self._busy_s = 0.0

    def record(self, seconds: float, n_queries: int, n_requests: int = 1):
        self._lat_s.extend([seconds] * n_requests)
        self._queries += n_queries
        self._busy_s += seconds

    def reset(self) -> None:
        self._lat_s.clear()
        self._queries = 0
        self._busy_s = 0.0

    def _percentile(self, q: float) -> float:
        xs = sorted(self._lat_s)
        if not xs:
            return float("nan")
        idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
        return xs[idx]

    def summary(self) -> LatencySummary:
        n = len(self._lat_s)
        return LatencySummary(
            count=n,
            queries=self._queries,
            qps=self._queries / self._busy_s if self._busy_s > 0 else 0.0,
            p50_ms=1e3 * self._percentile(0.50),
            p99_ms=1e3 * self._percentile(0.99),
            mean_ms=1e3 * (sum(self._lat_s) / n) if n else float("nan"),
        )


__all__ = ["LatencyRecorder", "LatencySummary"]
